"""Train-step factory: one code path for Full FT, LIFT, sparse-FT baselines
and PEFT adapters (LoRA / PiSSA / DoRA).

Key property for LIFT: gradients are computed ONLY w.r.t. the trainable
subtree (planned tensors), so frozen-parameter backward work (e.g. the
embedding table) is dead-code-eliminated by XLA; optimizer state is the
sparse (k,)-vector state of core/sparse_adam.py.

The mask-refresh program (LIFT's update_interval) is a *separate* jitted
function — the host loop calls it every N steps (paper App. B.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import peft as peftmod
from repro.core import sparse_adam as sa
from repro.core.lift import (LiftConfig, get_by_path, make_plan, set_by_path)
from repro.core.peft import PeftConfig
from repro.core.selection import SelectionEngine


@dataclasses.dataclass(frozen=True)
class MethodConfig:
    """How the model is tuned."""
    kind: str = "full"        # full | lift | sparse | lora | pissa | dora
    lift: LiftConfig = LiftConfig()
    peft: PeftConfig = PeftConfig()

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def warmup_linear(total_steps: int, warmup_ratio: float = 0.03,
                  peak: float = 1e-4):
    warm = max(1, int(total_steps * warmup_ratio))

    def sched(step):
        s = step.astype(jnp.float32)
        up = s / warm
        down = jnp.maximum(0.0, (total_steps - s) / max(1, total_steps - warm))
        return peak * jnp.minimum(up, down)

    return sched


def constant_lr(peak: float = 1e-4):
    return lambda step: jnp.full((), peak, jnp.float32)


# -------------------------------------------------------------- partition
def subtree(params, paths):
    return {p: get_by_path(params, p) for p in paths}


def merge_subtree(params, sub):
    out = params
    for p, leaf in sub.items():
        out = set_by_path(out, p, leaf)
    return out


# ------------------------------------------------------------------ setup
def selection_engine(model, method: MethodConfig,
                     mesh=None) -> Optional[SelectionEngine]:
    """The (lift/sparse) method's SelectionEngine; None for other methods.

    Build this ONCE per run and pass it to `init_train_state` /
    `make_refresh_step` so init and every refresh share one jitted
    selection program (and one plan fingerprint for checkpoints).

    `mesh` (optional) builds the engine under that sharding ctx so
    selection runs as a shard_map collective where the weights live
    (per-shard histograms -> psum'd threshold search -> shard-local
    compaction -> O(k) all-gather; DESIGN.md §3).  Without it the engine
    snapshots whatever ctx is already active."""
    if method.kind not in ("lift", "sparse"):
        return None
    if mesh is not None:
        from repro.parallel.sharding import sharding_ctx
        with sharding_ctx(mesh):
            return SelectionEngine.from_spec(model.spec(), method.lift)
    return SelectionEngine.from_spec(model.spec(), method.lift)


def init_train_state(model, params, method: MethodConfig, key,
                     sample_grads=None,
                     engine: Optional[SelectionEngine] = None):
    """Build the initial TrainState dict for any method."""
    mcfg = method
    state: dict[str, Any] = {"step": jnp.zeros((), jnp.int32)}
    if mcfg.kind == "full":
        state["opt"] = sa.dense_init(params)
    elif mcfg.kind in ("lift", "sparse"):
        lcfg = mcfg.lift
        if engine is None:
            engine = selection_engine(model, mcfg)
        plan = engine.plan
        idx, stats = engine.select_with_stats(params, key,
                                              grads=sample_grads)
        if lcfg.overflow_retry:
            idx, retried, unresolved = engine.retry_overflow(
                params, key, idx, stats)
            if retried:
                print(f"[lift] init selection overflow: retried "
                      f"{len(retried)} tensor(s) with doubled "
                      f"compact_factor: {', '.join(retried)}"
                      + (f" (STILL overflowing: {unresolved})"
                         if unresolved else ""))
        use_master = params_dtype_isnt_f32(params)
        state["opt"] = sa.init_state(params, idx, plan,
                                     use_master=use_master)
        if lcfg.train_other:
            other = other_paths(model, plan)
            state["opt_other"] = sa.dense_init(subtree(params, other))
    elif mcfg.kind in ("lora", "pissa", "dora"):
        pcfg = mcfg.peft.replace(kind=mcfg.kind)
        plan = make_plan(model.spec(),
                         LiftConfig(scope=mcfg.lift.scope,
                                    min_dim=mcfg.lift.min_dim))
        adapters, params = peftmod.init_adapters(params, plan, pcfg, key)
        state["adapters"] = adapters
        state["opt"] = sa.dense_init(adapters)
    else:
        raise ValueError(mcfg.kind)
    return params, state


def params_dtype_isnt_f32(params) -> bool:
    leaf = jax.tree.leaves(params)[0]
    return leaf.dtype != jnp.float32


def other_paths(model, plan):
    """Paths of non-planned trainable extras (norms, biases...)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(model.spec())
    from repro.core.lift import _path_str
    out = []
    for path, _ in flat:
        ps = _path_str(path)
        if ps not in plan and "embed" not in ps:
            out.append(ps)
    return out


# ------------------------------------------------------------- train step
def make_train_step(model, method: MethodConfig, adam: sa.AdamConfig,
                    lr_sched: Callable, microbatch: int = 0):
    """Returns train_step(params, state, batch) -> (params, state, metrics)."""
    mcfg = method

    def loss_for(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def value_and_grad(f2, tree, batch):
        """(loss, metrics), grads of f2(tree, batch); optional microbatch
        gradient accumulation (scan over batch splits, one psum total —
        grads sum locally across microbatches before the data-parallel
        reduction)."""
        if not microbatch or microbatch <= 1:
            return jax.value_and_grad(lambda t: f2(t, batch),
                                      has_aux=True)(tree)
        n = microbatch
        B = jax.tree.leaves(batch)[0].shape[0]
        assert B % n == 0, (B, n)
        mbatch = jax.tree.map(
            lambda x: x.reshape(n, B // n, *x.shape[1:]), batch)
        gf = jax.value_and_grad(f2, has_aux=True)

        def body(carry, mb):
            (ls, ms, gs) = carry
            (loss, metrics), g = gf(tree, mb)
            gs = jax.tree.map(jnp.add, gs, g)
            ms = jax.tree.map(jnp.add, ms, metrics)
            return (ls + loss, ms, gs), None

        zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)
        (loss0, metrics0), g0 = gf(tree, jax.tree.map(lambda x: x[0], mbatch))
        (loss, metrics, g), _ = jax.lax.scan(
            body, (loss0, metrics0, jax.tree.map(
                lambda a, b: a.astype(jnp.float32) + b, g0, zero_g)),
            jax.tree.map(lambda x: x[1:], mbatch))
        inv = 1.0 / n
        return ((loss * inv, jax.tree.map(lambda x: x * inv, metrics)),
                jax.tree.map(lambda x: (x * inv).astype(jnp.float32), g))

    if mcfg.kind == "full":
        def train_step(params, state, batch):
            lr = lr_sched(state["step"])
            (loss, metrics), g = value_and_grad(
                lambda p, b: loss_for(p, b), params, batch)
            if adam.grad_clip:
                g, gn = sa.clip_by_global_norm(g, adam.grad_clip)
            else:
                gn = sa.global_norm(g)
            params, opt = sa.dense_apply(params, g, state["opt"], adam, lr)
            new_state = {"step": state["step"] + 1, "opt": opt}
            metrics = dict(metrics, loss=loss, grad_norm=gn, lr=lr)
            return params, new_state, metrics
        return train_step

    if mcfg.kind in ("lift", "sparse"):
        lcfg = mcfg.lift
        plan = make_plan(model.spec(), lcfg)
        paths = sorted(plan.keys())
        extra = other_paths(model, plan) if lcfg.train_other else []

        def train_step(params, state, batch):
            lr = lr_sched(state["step"])
            train_tree = subtree(params, paths + extra)
            (loss, metrics), g = value_and_grad(
                lambda t, b: loss_for(merge_subtree(params, t), b),
                train_tree, batch)
            if adam.grad_clip:
                g, gn = sa.clip_by_global_norm(g, adam.grad_clip)
            else:
                gn = sa.global_norm(g)
            new_sub, opt = sa.apply_updates(
                subtree(train_tree, paths), subtree(g, paths), state["opt"],
                plan, adam, lr)
            new_state = dict(state, step=state["step"] + 1, opt=opt)
            if extra:  # dense AdamW on norms/biases (LIFT extension)
                dense_sub, opt_o = sa.dense_apply(
                    subtree(train_tree, extra), subtree(g, extra),
                    state["opt_other"], adam, lr)
                new_sub = dict(new_sub, **dense_sub)
                new_state["opt_other"] = opt_o
            params = merge_subtree(params, new_sub)
            metrics = dict(metrics, loss=loss, grad_norm=gn, lr=lr)
            return params, new_state, metrics
        return train_step

    # PEFT adapters
    pcfg = mcfg.peft.replace(kind=mcfg.kind)
    plan = make_plan(model.spec(), LiftConfig(scope=mcfg.lift.scope,
                                              min_dim=mcfg.lift.min_dim))

    def train_step(params, state, batch):
        lr = lr_sched(state["step"])

        def f(adapters, b):
            eff = peftmod.merge(params, adapters, plan, pcfg)
            return loss_for(eff, b)

        (loss, metrics), g = value_and_grad(f, state["adapters"], batch)
        if adam.grad_clip:
            g, gn = sa.clip_by_global_norm(g, adam.grad_clip)
        else:
            gn = sa.global_norm(g)
        adapters, opt = sa.dense_apply(state["adapters"], g, state["opt"],
                                       adam, lr)
        new_state = dict(state, step=state["step"] + 1, opt=opt,
                         adapters=adapters)
        metrics = dict(metrics, loss=loss, grad_norm=gn, lr=lr)
        return params, new_state, metrics

    return train_step


# ------------------------------------------------------------ mask refresh
def make_refresh_step(model, method: MethodConfig,
                      engine: Optional[SelectionEngine] = None):
    """LIFT mask refresh: selection + optimizer-state migration fused into
    the SelectionEngine's single jitted program (App. B.1).  The returned
    callable is already jitted — do not re-wrap it in jax.jit.

    After each call, `refresh.last_stats` holds the engine's stats dict
    ({"overflow": i32 scalar, "overflow_by_path": {...}}, *async* device
    values — reading them does not force a sync) and
    `refresh.overflow_history` accumulates the overflow scalar of EVERY
    refresh.  With `LiftConfig.overflow_retry` (default on), a nonzero
    overflow triggers `SelectionEngine.retry_overflow` right here: the
    affected tensors are re-selected with a doubled compact_factor and
    their moments re-migrated from the pre-refresh state, so an
    overflowing refresh no longer degrades the mask for good — at the
    cost of one scalar D2H sync per refresh (refreshes are rare;
    update_interval steps apart).  Retried path names accumulate in
    `refresh.retried_history` for the launcher to log.

    Gradient/movement selections need a gradient sample, which the refresh
    program doesn't carry — those baselines keep their initial mask (the
    paper treats them as fixed-mask baselines)."""
    assert method.kind in ("lift", "sparse")
    lcfg = method.lift
    if engine is None:
        engine = selection_engine(model, method)
    if lcfg.selection in ("gradient", "movement"):
        def refresh(params, state, key):
            return state
        refresh.engine = engine
        refresh.last_stats = None
        refresh.overflow_history = []
        refresh.retried_history = []
        return refresh

    def refresh(params, state, key):
        from repro import obs as obs_mod
        tr = obs_mod.default().tracer
        sub = subtree(params, engine.paths)
        # phase spans (DESIGN.md §11): "dispatch" is the fused
        # select+migrate program's async dispatch; "retry" includes the
        # one scalar D2H overflow_retry pays anyway — no NEW syncs here
        sp = tr.begin("refresh.dispatch", "refresh")
        opt, stats = engine.refresh_opt(sub, state["opt"], key)
        tr.end(sp)
        if not isinstance(stats["overflow"], jax.core.Tracer):
            refresh.last_stats = stats  # skipped under an outer jit trace
            refresh.overflow_history.append(stats["overflow"])
            if lcfg.overflow_retry:
                sp = tr.begin("refresh.retry", "refresh")
                opt = _refresh_overflow_retry(engine, sub, state["opt"],
                                              opt, stats, key, refresh)
                tr.end(sp, retried=len(refresh.retried_history))
        return dict(state, opt=opt)

    refresh.engine = engine
    refresh.last_stats = None
    refresh.overflow_history = []
    refresh.retried_history = []
    return refresh


def _refresh_overflow_retry(engine, params_sub, old_opt, new_opt, stats,
                            key, refresh):
    """Recover overflow-degraded refreshes: re-select the affected tensors
    at doubled capacity (engine.retry_overflow) and re-migrate their
    moments from the PRE-refresh optimizer state, exactly as the fused
    program would have with enough capacity."""
    idx = {p: new_opt["tensors"][p]["idx"] for p in engine.paths}
    fixed, retried, unresolved = engine.retry_overflow(
        params_sub, key, idx, stats)
    if not retried:
        return new_opt
    refresh.retried_history.append((tuple(retried), tuple(unresolved)))
    mini_plan = {p: engine.plan[p] for p in retried}
    mini_state = {"step": old_opt["step"],
                  "tensors": {p: old_opt["tensors"][p] for p in retried}}
    migrated = sa.migrate(params_sub, mini_state,
                          {p: fixed[p] for p in retried}, mini_plan)
    tensors = dict(new_opt["tensors"])
    tensors.update(migrated["tensors"])
    return dict(new_opt, tensors=tensors)


def effective_params(model, params, state, method: MethodConfig):
    """Inference-time params for any method (merges adapters if present)."""
    if method.kind in ("lora", "pissa", "dora"):
        pcfg = method.peft.replace(kind=method.kind)
        plan = make_plan(model.spec(), LiftConfig(scope=method.lift.scope,
                                                  min_dim=method.lift.min_dim))
        return peftmod.merge(params, state["adapters"], plan, pcfg)
    return params
