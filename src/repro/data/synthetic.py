"""Synthetic SFT corpora (offline container: no dataset downloads).

Reproduces the *structure* of the paper's data regimes:
  * "arith"  — arithmetic-reasoning SFT in the MATH-10K style: a word
               problem, a short chain of calculation steps, final answer.
               (target domain)
  * "common" — commonsense-style cloze Q/A templates. (source domain)
  * "lm"     — plain next-token text (wikitext stand-in for perplexity).

A small deterministic word-level tokenizer covers all corpora; everything is
seeded and reproducible across hosts.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_WORDS = (
    "<pad> <bos> <eos> <sep> what is plus minus times equals if has gives "
    "then so answer : the a and of are more less left total first second "
    "third apple box book coin ball star tree fish bird cat dog sum "
    "difference product result john mary tom anna buys sells finds loses "
    "start with end now count how many because therefore step compute "
    "true false not all some most city country capital located in water "
    "fire air earth big small fast slow hot cold".split()
)
_DIGITS = [str(d) for d in range(10)]
VOCAB = _WORDS + _DIGITS
TOK = {w: i for i, w in enumerate(VOCAB)}
PAD, BOS, EOS, SEP = TOK["<pad>"], TOK["<bos>"], TOK["<eos>"], TOK["<sep>"]
VOCAB_SIZE = len(VOCAB)


def encode(text: str) -> list[int]:
    out = []
    for w in text.split():
        if w in TOK:
            out.append(TOK[w])
        else:
            for ch in w:  # digits of numbers
                out.append(TOK.get(ch, PAD))
    return out


def decode(ids) -> str:
    inv = {i: w for w, i in TOK.items()}
    return " ".join(inv.get(int(i), "?") for i in ids)


def _num(rng, lo=2, hi=99) -> int:
    return int(rng.integers(lo, hi))


def make_arith_example(rng: np.random.Generator) -> tuple[str, str]:
    """(prompt, answer-with-reasoning)."""
    kind = rng.integers(0, 4)
    a, b = _num(rng), _num(rng)
    c = _num(rng, 2, 9)
    who = rng.choice(["john", "mary", "tom", "anna"])
    thing = rng.choice(["apple", "coin", "book", "ball", "star"])
    if kind == 0:
        q = f"{who} has {a} {thing} and buys {b} more how many now"
        r = f"step {a} plus {b} equals {a + b} answer : {a + b}"
    elif kind == 1:
        q = f"{who} has {a} {thing} and loses {min(a, b)} how many left"
        r = f"step {a} minus {min(a, b)} equals {a - min(a, b)} " \
            f"answer : {a - min(a, b)}"
    elif kind == 2:
        q = f"{who} has {c} box of {a} {thing} how many total"
        r = f"step {c} times {a} equals {c * a} answer : {c * a}"
    else:
        q = f"what is {a} plus {b} times {c}"
        r = f"step {b} times {c} equals {b * c} step {a} plus {b * c} " \
            f"equals {a + b * c} answer : {a + b * c}"
    return q, r


def make_common_example(rng: np.random.Generator) -> tuple[str, str]:
    pairs = [
        ("fire is hot true or false", "answer : true"),
        ("water is hot true or false", "answer : false"),
        ("a tree is big and a coin is small true or false",
         "answer : true"),
        ("all fish are birds true or false", "answer : false"),
        ("some dog are fast true or false", "answer : true"),
        ("the capital city is located in the country true or false",
         "answer : true"),
        ("cold is more hot than fire true or false", "answer : false"),
        ("a ball is more big than a city true or false", "answer : false"),
    ]
    q, r = pairs[int(rng.integers(0, len(pairs)))]
    return q, r


def make_lm_text(rng: np.random.Generator) -> str:
    w = [VOCAB[4 + int(rng.integers(0, VOCAB_SIZE - 14))] for _ in range(24)]
    return " ".join(w)


@dataclasses.dataclass
class SftExample:
    tokens: np.ndarray      # (S,) int32
    loss_mask: np.ndarray   # (S,) float32 (1 on answer tokens)


def build_sft_example(prompt: str, answer: str, seq_len: int) -> SftExample:
    p = [BOS] + encode(prompt) + [SEP]
    r = encode(answer) + [EOS]
    toks = (p + r)[:seq_len]
    mask = ([0.0] * len(p) + [1.0] * len(r))[:seq_len]
    pad = seq_len - len(toks)
    toks = np.asarray(toks + [PAD] * pad, np.int32)
    mask = np.asarray(mask + [0.0] * pad, np.float32)
    return SftExample(toks, mask)


def generate(task: str, n: int, seq_len: int, seed: int = 0):
    """-> dict of stacked arrays {tokens, labels, loss_mask}."""
    rng = np.random.default_rng(seed)
    toks, masks = [], []
    for _ in range(n):
        if task == "arith":
            q, r = make_arith_example(rng)
        elif task == "common":
            q, r = make_common_example(rng)
        elif task == "lm":
            t = make_lm_text(rng)
            q, r = t, make_lm_text(rng)
        else:
            raise ValueError(task)
        ex = build_sft_example(q, r, seq_len + 1)
        toks.append(ex.tokens)
        masks.append(ex.loss_mask)
    toks = np.stack(toks)
    masks = np.stack(masks)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].astype(np.int32),
        "loss_mask": masks[:, 1:],
    }


def eval_accuracy(model, params, task: str, n: int = 64, seq_len: int = 48,
                  seed: int = 10_000) -> float:
    """Teacher-forced per-token accuracy on held-out answer tokens.

    (Reduced-scale models never reach exact-match accuracy in a few hundred
    steps; token-level accuracy preserves the method ORDERING the paper's
    tables measure, which is the reproduction target — DESIGN.md §9.)"""
    import jax
    import jax.numpy as jnp
    data = generate(task, n, seq_len, seed=seed)
    logits_fn = jax.jit(model.logits)
    lg = logits_fn(params, {"tokens": jnp.asarray(data["tokens"])})
    pred = np.asarray(jnp.argmax(lg, -1))
    mask = data["loss_mask"] > 0
    hit = (pred == data["labels"]) & mask
    return float(hit.sum() / max(mask.sum(), 1))


def eval_exact_match(model, params, task: str, n: int = 32,
                     seq_len: int = 48, seed: int = 10_000) -> float:
    """Greedy-decode exact final-answer match (strict; for larger runs)."""
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    correct = 0
    logits_fn = jax.jit(model.logits)
    for _ in range(n):
        if task == "arith":
            q, r = make_arith_example(rng)
        else:
            q, r = make_common_example(rng)
        p = [BOS] + encode(q) + [SEP]
        gold = encode(r) + [EOS]
        ctx = list(p)
        ok = True
        for gt in gold:
            x = np.full((1, seq_len), PAD, np.int32)
            x[0, :min(len(ctx), seq_len)] = ctx[-seq_len:]
            lg = logits_fn(params, {"tokens": jnp.asarray(x)})
            nxt = int(jnp.argmax(lg[0, min(len(ctx), seq_len) - 1]))
            if nxt != gt:
                ok = False
                break
            ctx.append(nxt)
        correct += int(ok)
    return correct / n
