"""Deterministic, shardable, checkpointable data loader.

Design requirements at cluster scale:
  * every data-parallel host must read a disjoint shard,
  * a restart (possibly with a DIFFERENT number of hosts — elastic) must
    resume mid-epoch without replaying or skipping examples,
  * iteration order must be a pure function of (seed, epoch).

The loader is index-based over an in-memory (or memory-mapped) array store:
a permutation of example indices is derived per epoch from
`PRNG(seed, epoch)`; host h of H takes indices with `i % H == h`.  The
cursor state is just (epoch, step) — two ints — which is what the
checkpoint stores; elastic restarts recompute shards from the new H.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class LoaderState:
    epoch: int = 0
    step: int = 0  # batches already emitted this epoch (global count)

    def to_dict(self):
        return {"epoch": self.epoch, "step": self.step}

    @staticmethod
    def from_dict(d):
        return LoaderState(int(d["epoch"]), int(d["step"]))


class ShardedLoader:
    def __init__(self, arrays: dict[str, np.ndarray], batch_size: int,
                 seed: int = 0, shard_id: int = 0, num_shards: int = 1,
                 drop_last: bool = True,
                 state: Optional[LoaderState] = None):
        n = len(next(iter(arrays.values())))
        assert all(len(v) == n for v in arrays.values())
        assert batch_size % num_shards == 0, (batch_size, num_shards)
        self.arrays = arrays
        self.n = n
        self.global_batch = batch_size
        self.local_batch = batch_size // num_shards
        self.seed = seed
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.state = state or LoaderState()
        self.batches_per_epoch = n // batch_size if drop_last \
            else -(-n // batch_size)

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 20) + epoch)
        return rng.permutation(self.n)

    def next_batch(self) -> dict[str, np.ndarray]:
        st = self.state
        if st.step >= self.batches_per_epoch:
            st.epoch += 1
            st.step = 0
        perm = self._perm(st.epoch)
        lo = st.step * self.global_batch
        idx = perm[lo:lo + self.global_batch]
        if len(idx) < self.global_batch:  # wrap (drop_last=False tail)
            idx = np.concatenate([idx, perm[:self.global_batch - len(idx)]])
        local = idx[self.shard_id::self.num_shards]
        st.step += 1
        return {k: v[local] for k, v in self.arrays.items()}

    def __iter__(self):
        while True:
            yield self.next_batch()
