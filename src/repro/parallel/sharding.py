"""Logical-axis based sharding.

Every parameter / activation in the framework is annotated with *logical* axis
names ("embed", "mlp", "heads", "vocab", "batch", ...).  A rule table maps the
logical names onto physical mesh axes.  Model code never mentions physical
axes, so the same model definition runs on a laptop CPU (no mesh), a single
pod (data, model) or the multi-pod (pod, data, model) mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> tuple of mesh axes (in priority order).  A mesh axis that is
# absent from the active mesh is silently dropped, which is what makes the
# multi-pod rules degrade gracefully to the single-pod / single-device cases.
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # data-like
    "batch": ("pod", "data"),
    "seq": (),           # replicated by default; "seq_sharded" opts in
    "seq_sharded": ("model",),   # sequence parallelism for long prefill
    "cache_seq": ("model",),     # decode context parallelism for KV caches
    # weight-like
    "vocab": ("model",),
    "embed": (),
    "mlp": ("model",),
    "heads": ("model",),
    "heads_flat": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "experts": ("model",),
    "expert_mlp": (),
    "capacity": ("data",),   # MoE dispatch slots: data-parallel over tokens
    "layers": (),
    "state": (),
    "conv": (),
    "lora_rank": (),
    # LIFT sparse-state axes
    "shards": ("model",),
    "topk": ("model", "data"),
    None: (),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict[str, tuple[str, ...]] = dict(DEFAULT_RULES)


_CTX = _Ctx()


def set_sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None) -> None:
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES)
    if rules:
        _CTX.rules.update(rules)


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    set_sharding_ctx(mesh, rules)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def logical_to_spec(axes: Sequence[Union[str, None]],
                    mesh: Optional[Mesh] = None,
                    rules: Optional[dict] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for `mesh`."""
    mesh = mesh if mesh is not None else _CTX.mesh
    rules = rules if rules is not None else _CTX.rules
    if mesh is None:
        return P()
    mesh_axes = set(mesh.axis_names)
    used: set[str] = set()
    out = []
    for ax in axes:
        cand = rules.get(ax, ())
        picked = tuple(a for a in cand if a in mesh_axes and a not in used)
        used.update(picked)
        if len(picked) == 0:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return P(*out)


def named_sharding(axes: Sequence[Union[str, None]],
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(axes, mesh))


def shard_logical(x: jax.Array, axes: Sequence[Union[str, None]],
                  mesh: Optional[Mesh] = None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without an active mesh."""
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        return x
    spec = logical_to_spec(axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def mesh_axes_for(logical: Optional[str],
                  mesh: Optional[Mesh] = None,
                  rules: Optional[dict] = None) -> tuple:
    """Physical mesh axes a single logical axis resolves to (may be ())."""
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        return ()
    entry = logical_to_spec((logical,), mesh, rules)[0]
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def logical_axis_size(logical: Optional[str],
                      mesh: Optional[Mesh] = None,
                      rules: Optional[dict] = None) -> int:
    """Number of shards the logical axis spreads over on the mesh (1 when
    unmapped or no mesh is active).  The SelectionEngine uses
    logical_axis_size("shards") to size its per-shard selection quota."""
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        return 1
    size = 1
    for ax in mesh_axes_for(logical, mesh, rules):
        size *= mesh.shape[ax]
    return size


def shard_logical_if_divisible(x: jax.Array,
                               axes: Sequence[Union[str, None]],
                               mesh: Optional[Mesh] = None) -> jax.Array:
    """`shard_logical` that nulls any dim whose mapped mesh-axis product
    does not divide the dim size (e.g. a (ns, k) index set whose k is not
    a multiple of the "topk" axes) instead of tripping an XLA error."""
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        return x
    eff = []
    for dim, ax in zip(x.shape, axes):
        n = 1
        for a in mesh_axes_for(ax, mesh):
            n *= mesh.shape[a]
        eff.append(ax if (n > 1 and dim % n == 0) else None)
    return shard_logical(x, tuple(eff), mesh)


def tree_shardings(axes_tree, mesh: Optional[Mesh] = None):
    """Map an axes-tree (tuples of logical names at the leaves) to shardings."""
    mesh = mesh if mesh is not None else _CTX.mesh
    if mesh is None:
        return None
    return jax.tree.map(
        lambda axes: NamedSharding(mesh, logical_to_spec(axes, mesh)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, str) or a is None for a in x),
    )
