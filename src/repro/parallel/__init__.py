from repro.parallel.sharding import (  # noqa: F401
    DEFAULT_RULES,
    active_mesh,
    logical_to_spec,
    named_sharding,
    set_sharding_ctx,
    shard_logical,
    sharding_ctx,
    tree_shardings,
)
