"""Gradient compression for the cross-pod (DCI) hop.

Running compute in bf16 already halves the wire format (the in-graph
all-reduces are bf16 — see EXPERIMENTS.md §Dry-run); this module adds the
classic *error-feedback top-k* compressor for the slow pod-to-pod hop:

    residual += grad
    (vals, idx) = top-k(|residual|)          k = ratio * n
    residual   -= scatter(vals, idx)         (error feedback)
    wire        = all-reduce of the k-sparse representation

Error feedback guarantees every gradient coordinate is eventually applied
(the compressor is a contraction, Stich et al. 2018) — the unit tests assert
that contract.  `compressed_psum` expresses the exchange with
shard_map-friendly primitives; on the 2-pod mesh it cuts the DCI bytes to
~2*ratio of the dense all-reduce (indices + values).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: jax.Array  # same shape as the gradient


def init_ef(grad_like) -> EFState:
    return EFState(jnp.zeros_like(grad_like, jnp.float32))


def compress(g: jax.Array, ef: EFState, ratio: float):
    """-> (vals (k,), idx (k,), new_ef).  g is flattened internally."""
    flat = g.reshape(-1).astype(jnp.float32) + ef.residual.reshape(-1)
    k = max(1, int(ratio * flat.size))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    new_res = flat.at[idx].set(0.0)
    return vals, idx.astype(jnp.int32), EFState(new_res.reshape(g.shape))


def decompress(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    n = 1
    for d in shape:
        n *= d
    return jnp.zeros((n,), jnp.float32).at[idx].add(vals).reshape(shape)


def compressed_psum(g: jax.Array, ef: EFState, ratio: float,
                    axis_name: str):
    """Top-k + error-feedback all-reduce over `axis_name` (use inside
    shard_map).  Exchanges (vals, idx) via all_gather — 2*ratio*n words on
    the wire instead of n."""
    vals, idx, new_ef = compress(g, ef, ratio)
    all_vals = jax.lax.all_gather(vals, axis_name)    # (P, k)
    all_idx = jax.lax.all_gather(idx, axis_name)
    P = all_vals.shape[0]
    out = jnp.zeros((g.size,), jnp.float32)
    out = out.at[all_idx.reshape(-1)].add(all_vals.reshape(-1))
    return (out / P).reshape(g.shape).astype(g.dtype), new_ef


def wire_bytes(n: int, ratio: float, pods: int = 2) -> dict:
    """Modeled DCI traffic per step for an n-parameter gradient."""
    dense = 2 * (pods - 1) / pods * n * 2          # bf16 ring all-reduce
    k = int(ratio * n)
    sparse = (pods - 1) * k * (4 + 4)              # vals f32 + idx i32
    return {"dense_bf16": dense, "topk": sparse,
            "saving": 1.0 - sparse / dense}
