"""Roofline analysis over the dry-run results (EXPERIMENTS.md §Roofline).

Per (arch x shape) single-pod cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = link_bytes_per_device / ICI_bw
(the per-device formulation is identical to the assignment's fleet-total /
(chips * bw) form).  MODEL_FLOPS is the analytic useful work:
6·N_active·tokens for training, 2·N_active·tokens forward-only, plus the
attention / linear-recurrence terms — the MODEL/HLO ratio exposes remat and
padding waste.  The roofline fraction scored in §Perf is
useful-compute-time / dominant-term-time.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ----------------------------------------------------- analytic model flops
def _linear_params(cfg) -> float:
    """Matmul-visible params: all non-embedding linear weights (MoE experts
    scaled by the activated fraction) + one d*V head matmul."""
    from repro.models import build_model
    from repro.nn.core import is_spec
    import jax

    model = build_model(cfg)
    spec = model.spec()
    flat, _ = jax.tree_util.tree_flatten_with_path(spec, is_leaf=is_spec)
    total = 0.0
    for path, s in flat:
        if len(s.shape) < 2:
            continue
        n = float(np.prod(s.shape))
        if "vocab" in s.axes:
            continue  # embedding table / head counted separately
        if "experts" in s.axes:
            n *= cfg.num_experts_per_tok / max(cfg.num_experts, 1)
        total += n
    total += cfg.d_model * cfg.vocab_size  # head matmul (tied or not)
    return total


def _attn_flops_per_token(cfg, ctx_len: float) -> float:
    """qk + pv einsum flops per token per layer (forward)."""
    if cfg.family == "rwkv6":
        H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
        return 8.0 * H * K * K          # state update + readout
    if cfg.family == "hybrid":
        H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        base = 6.0 * H * N * P
        # shared attention every period, on width 2d with 32 heads
        attn = 4.0 * cfg.num_heads * cfg.head_dim * ctx_len \
            / max(cfg.shared_attn_period, 1)
        return base + attn
    w = cfg.sliding_window
    eff = min(ctx_len, w) if w else ctx_len
    return 4.0 * cfg.num_heads * cfg.head_dim * eff


def model_flops(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_lin = _linear_params(cfg)
    if shape.kind == "train":
        tokens = B * S
        mult = 3.0                       # fwd + bwd
        ctx = S / 2
        per_tok_attn = _attn_flops_per_token(cfg, ctx) * cfg.num_layers
        return mult * (2.0 * n_lin + per_tok_attn) * tokens
    if shape.kind == "prefill":
        tokens = B * S
        ctx = S / 2
        per_tok_attn = _attn_flops_per_token(cfg, ctx) * cfg.num_layers
        return (2.0 * n_lin + per_tok_attn) * tokens
    # decode: one token per sequence over a cache of length S
    per_tok_attn = _attn_flops_per_token(cfg, float(S)) * cfg.num_layers
    return (2.0 * n_lin + per_tok_attn) * B


# -------------------------------------------------- paged-decode roofline
def expected_tokens_per_step(accept_rate: float, draft_len: int) -> float:
    """Tokens a sequence advances per speculative verify dispatch when
    each draft is accepted i.i.d. with probability `accept_rate`: the
    accepted prefix K has P(K=k) = a^k (1-a) below draft_len, and the
    dispatch emits K+1 tokens (the correction, or the bonus token after
    a full accept) — E = (1 - a^(N+1)) / (1 - a), i.e. 1 at a=0 and
    N+1 at a=1."""
    a = min(max(float(accept_rate), 0.0), 1.0)
    n = max(int(draft_len), 0)
    if a >= 1.0:
        return float(n + 1)
    return (1.0 - a ** (n + 1)) / (1.0 - a)


def paged_decode_roofline(cfg, *, batch: int, live_tokens_per_seq: float,
                          page_size: int, draft_len: int = 0,
                          accept_rate: float = 0.0,
                          dtype_bytes: int = 2,
                          quantize_base: bool = False,
                          overlay_density: float = 0.05,
                          hbm_bw: float = HBM_BW) -> dict:
    """Memory-bound attainable tok/s for (speculative) paged decode.

    Decode is HBM-bound: every dispatch streams the weights once plus
    each sequence's LIVE KV pages — read at page granularity, so the
    traffic term is ceil(live / page_size) * page_size tokens of KV per
    sequence (the page-size parameterization: big pages waste bandwidth
    on the partial last page, tiny pages waste it on scattered reads
    the model below doesn't charge for).  Speculation amortizes that
    stream over `expected_tokens_per_step(accept_rate, draft_len)`
    tokens instead of one — same bytes, more tokens — which is the
    entire speculative speedup in the memory-bound regime; the bench
    reports measured tok/s next to this attainable bound.

    `quantize_base` models int8-resident projection weights with the
    fp32 principal-weight overlay (DESIGN.md §12): the planned
    projections stream 1 byte/weight plus `overlay_density` * 8 bytes
    of (int32 idx, fp32 val) overlay entries; the d*V head matmul is
    never quantized and streams at `dtype_bytes`.  Decode being
    weight-stream-bound, the residency ratio is also roughly the
    attainable-throughput gain.
    """
    n_lin = _linear_params(cfg)
    head = float(cfg.d_model * cfg.vocab_size)
    if quantize_base:
        n_planned = max(n_lin - head, 0.0)
        param_bytes = head * dtype_bytes \
            + n_planned * (1.0 + float(overlay_density) * 8.0)
    else:
        param_bytes = n_lin * dtype_bytes
    kv_per_token = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim
                    * dtype_bytes)
    pages = -(-max(live_tokens_per_seq, 1.0) // page_size)
    kv_read = batch * pages * page_size * kv_per_token
    kv_write = batch * (1 + draft_len) * kv_per_token
    step_bytes = param_bytes + kv_read + kv_write
    t_step = step_bytes / hbm_bw
    eff = expected_tokens_per_step(accept_rate, draft_len)
    return {
        "batch": batch,
        "page_size": page_size,
        "live_tokens_per_seq": live_tokens_per_seq,
        "draft_len": draft_len,
        "accept_rate": accept_rate,
        "effective_tokens_per_step": eff,
        "quantize_base": quantize_base,
        "param_bytes": param_bytes,
        "step_bytes": step_bytes,
        "t_step_s": t_step,
        "attainable_tok_s": batch * eff / t_step,
    }


# ------------------------------------------------------------- terms table
def load_results(mesh_tag: str = "single", method: str = "lift"):
    rows = {}
    suffix = "" if method == "lift" else f"_{method}"
    for fn in sorted(os.listdir(RESULTS_DIR)):
        if not fn.endswith(f"__{mesh_tag}{suffix}.json"):
            continue
        with open(os.path.join(RESULTS_DIR, fn)) as f:
            r = json.load(f)
        rows[(r["arch"], r["shape"])] = r
    return rows


def roofline_row(r: dict, chips: int = 256) -> Optional[dict]:
    if r.get("skipped") or "error" in r or "cost_extrapolated" not in r:
        return None
    from repro.configs import get_arch, LM_SHAPES
    cfg = get_arch(r["arch"]).full
    shape = LM_SHAPES[r["shape"]]
    ce = r["cost_extrapolated"]
    t_comp = ce["flops"] / PEAK_FLOPS_BF16
    t_mem = ce["bytes"] / HBM_BW
    t_coll = ce["coll_link_bytes"] / ICI_BW_PER_LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_fleet = ce["flops"] * chips
    t_useful = mf / (chips * PEAK_FLOPS_BF16)
    frac = t_useful / max(terms.values()) if max(terms.values()) > 0 else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_fleet": hlo_fleet,
        "useful_ratio": mf / hlo_fleet if hlo_fleet else 0.0,
        "roofline_fraction": frac,
        "in_gib_per_dev": r.get("per_device_input_gib"),
    }


_ADVICE = {
    "compute": ("compute-bound: cut HLO/MODEL flops gap — remat policy "
                "(recompute less), drop attention-pad waste, bf16 end-to-end"),
    "memory": ("memory-bound: fuse elementwise chains, shrink optimizer/"
               "cache dtypes, increase arithmetic intensity per HBM read "
               "(bigger tiles / batched decode)"),
    "collective": ("collective-bound: reshard (less TP / more DP+FSDP), "
                   "sequence-shard activations so psums shrink, overlap "
                   "collectives with compute (latency-hiding scheduler)"),
}


def advice(row: dict) -> str:
    return _ADVICE[row["dominant"]]


def table(method: str = "lift") -> list[dict]:
    rows = []
    for (arch, shape), r in sorted(load_results("single", method).items()):
        row = roofline_row(r)
        if row:
            rows.append(row)
    return rows


def markdown(method: str = "lift") -> str:
    rows = table(method)
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} "
            f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="lift")
    a = ap.parse_args()
    print(markdown(a.method))
