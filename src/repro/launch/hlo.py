"""Post-SPMD HLO text analysis: collective bytes per class.

`cost_analysis()` reports flops / bytes-accessed but NOT collective traffic,
so we parse `compiled.as_text()` (post-partitioning, shapes are per-device)
and charge each collective with ring-algorithm link bytes:

    all-reduce          2 (n-1)/n * buf        (reduce-scatter + all-gather)
    all-gather          (n-1)/n   * result     (result = gathered buffer)
    reduce-scatter      (n-1)     * result     (input = n * result)
    all-to-all          (n-1)/n   * buf
    collective-permute  1         * buf

Cost lowerings are UNROLLED (no while loops), so text counts are exact; the
parser still tracks computations and flags collectives living inside a
`while` body (sanity check for the methodology, DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*"
                      r"(?:->\s*[^{]*)?\{\s*$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [G, S] <= [N]: G groups of size S
        return int(m.group(2))
    return default


_FACTORS = {
    "all-reduce": lambda n, b: 2.0 * (n - 1) / n * b,
    "all-gather": lambda n, b: (n - 1) / n * b,
    "reduce-scatter": lambda n, b: float(n - 1) * b,
    "all-to-all": lambda n, b: (n - 1) / n * b,
    "collective-permute": lambda n, b: float(b),
}


@dataclasses.dataclass
class CollectiveStats:
    link_bytes: float = 0.0            # per-device bytes over ICI links
    by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    count: int = 0
    in_while: int = 0                  # collectives inside while bodies (bad
                                       # for the unrolled-cost methodology)


def analyze_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    current_comp = ""
    while_comps = set()

    # first pass: find computations referenced by while ops
    for line in hlo_text.splitlines():
        if " while(" in line:
            for m in re.finditer(r"(?:body|condition)=%?([\w.\-]+)", line):
                while_comps.add(m.group(1))

    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if mc:
            current_comp = mc.group(1)
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_text, kind = m.group(1), m.group(2)
        if f"{kind}-done" in line:
            continue
        buf = _shape_bytes(result_text)
        # XLA:CPU promotes bf16 all-reduce accumulation to f32
        # (to_apply=..._promoted); TPUs reduce in bf16 natively, so count
        # the un-promoted width.
        if kind == "all-reduce" and "promoted" in line and "f32[" in line \
                and "bf16[" not in result_text:
            buf = buf // 2
        n = _group_size(line, n_devices)
        if n <= 1:
            continue
        link = _FACTORS[kind](n, buf)
        stats.link_bytes += link
        stats.by_kind[kind] += link
        stats.count += 1
        if current_comp in while_comps or "while" in current_comp \
                or "body" in current_comp:
            stats.in_while += 1
    return stats


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
