"""Splice the generated §Dry-run and §Roofline tables into EXPERIMENTS.md."""

from repro.launch.report import dryrun_markdown
from repro.launch.roofline import markdown as roofline_markdown


def main():
    path = "EXPERIMENTS.md"
    with open(path) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_markdown(), 1)
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_markdown(), 1)
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
