"""End-to-end training launcher.

Runs the full production loop on whatever devices exist: data pipeline ->
jitted train_step (Full FT / LIFT / baselines) -> periodic LIFT mask refresh
-> async checkpointing -> preemption-safe auto-resume -> straggler
monitoring.  On the CPU container this drives the smoke/reduced configs
end-to-end; on a real fleet the same file is the per-host entrypoint (the
mesh comes from jax.devices()).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --method lift --ckpt-dir /tmp/ckpt [--crash-at 30]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--method", default="lift",
                    choices=["full", "lift", "sparse", "lora", "pissa",
                             "dora"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lift-rank", type=int, default=16)
    ap.add_argument("--lift-density", type=float, default=0.05)
    ap.add_argument("--update-interval", type=int, default=20)
    ap.add_argument("--use-kernel", action="store_true",
                    help="streaming Pallas selection (threshold + "
                         "compaction kernels; no (rows, cols) score "
                         "matrix is ever materialized)")
    ap.add_argument("--block-size", type=int, default=1,
                    help="structured LIFT (App. G.7): select whole "
                         "block_size x block_size tiles; with "
                         "--use-kernel the streaming pipeline block-sums "
                         "scores on the fly (no dense score matrix in "
                         "any engine mode)")
    ap.add_argument("--mesh", default="",
                    help="DATAxMODEL device mesh (e.g. 1x8): shards params "
                         "by logical axes and runs mask selection/refresh "
                         "as a shard_map collective over the model axis "
                         "(per-shard histograms + O(k) index all-gather)")
    ap.add_argument("--quota", default="global",
                    choices=["global", "local"],
                    help="'local' gives every model-parallel shard an "
                         "exact k/n_shards selection budget — "
                         "collective-free refresh (DESIGN.md §3)")
    ap.add_argument("--no-overflow-retry", action="store_true",
                    help="disable host-side auto-retry of compaction "
                         "overflow (doubled compact_factor per affected "
                         "tensor; default on)")
    ap.add_argument("--task", default="arith")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate preemption at this step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval", action="store_true")
    ap.add_argument("--data-size", type=int, default=2048)
    ap.add_argument("--trace-out", default="",
                    help="write step/refresh/checkpoint spans as JSONL "
                         "to this path (docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-out", default="",
                    help="dump the final metrics-registry snapshot as "
                         "JSON to this path")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="print a train.* metrics snapshot every N steps "
                         "(0 = final snapshot only)")
    ap.add_argument("--audit-manifest", default="",
                    help="check observed jit compilations against this "
                         "expected-compilations manifest and exit "
                         "nonzero on any violation (the compilations == "
                         "expected CI gate)")
    args = ap.parse_args()

    from repro import obs as obs_lib
    obs_ctx = obs_lib.default()
    if args.trace_out:
        obs_ctx.tracer.enabled = True

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_arch
    from repro.core import sparse_adam as sa
    from repro.core.lift import LiftConfig
    from repro.core.peft import PeftConfig
    from repro.data.loader import LoaderState, ShardedLoader
    from repro.data.synthetic import VOCAB_SIZE, generate
    from repro.ft import PreemptionSimulator, StragglerMonitor
    from repro.ft.resilience import StepTimer
    from repro.models import build_model
    from repro.training import trainer as T

    from repro.launch.mesh import parse_mesh_spec, selection_shards
    from repro.parallel.sharding import set_sharding_ctx, tree_shardings

    bundle = get_arch(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.full
    if cfg.vocab_size < VOCAB_SIZE:
        cfg = cfg.replace(vocab_size=128)
    model = build_model(cfg)

    mesh = parse_mesh_spec(args.mesh) if args.mesh else None
    if mesh is not None:
        # the ctx must be live BEFORE the engine is built: the engine
        # snapshots it to decide which groups run as shard_map collectives
        set_sharding_ctx(mesh)
        print(f"[mesh] {dict(mesh.shape)} — selection shards over "
              f"{selection_shards(mesh)} device(s)")

    method = T.MethodConfig(
        kind=args.method,
        lift=LiftConfig(rank=args.lift_rank, density=args.lift_density,
                        method="exact", update_interval=args.update_interval,
                        min_dim=16, use_kernel=args.use_kernel,
                        quota=args.quota, block_size=args.block_size,
                        overflow_retry=not args.no_overflow_retry),
        peft=PeftConfig(rank=args.lift_rank))
    adam = sa.AdamConfig(lr=args.lr, grad_clip=1.0)

    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    if mesh is not None:
        sh = tree_shardings(model.axes(), mesh)
        params = jax.tree.map(jax.device_put, params, sh)
    # one SelectionEngine instance serves init, every refresh, and the
    # checkpoint plan fingerprint (single jitted selection program)
    engine = T.selection_engine(model, method, mesh=mesh)
    if engine is not None and mesh is not None:
        sharded = sorted(m for m in engine.group_exec.values()
                         if m.startswith("sharded"))
        print(f"[mesh] selection groups: "
              f"{len(sharded)}/{len(engine.group_exec)} sharded")
    params, state = T.init_train_state(model, params, method,
                                       jax.random.PRNGKey(args.seed + 1),
                                       engine=engine)
    train_step = obs_lib.instrument_jit(
        T.make_train_step(model, method, adam, T.constant_lr(args.lr)),
        name="train.step", obs=obs_ctx)
    refresh = None
    if args.method in ("lift", "sparse"):
        # already jitted by the engine — selection + state migration fused
        refresh = T.make_refresh_step(model, method, engine=engine)

    data = generate(args.task, args.data_size, args.seq, seed=args.seed)
    if cfg.input_mode == "embeddings":  # frontend stub: embed via random proj
        proj = np.asarray(
            jax.random.normal(jax.random.PRNGKey(7),
                              (128, cfg.d_model))) * 0.05
        data = {"embeds": proj[data["tokens"]].astype(np.float32),
                "labels": data["labels"], "loss_mask": data["loss_mask"]}
    loader = ShardedLoader(data, batch_size=args.batch, seed=args.seed)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            like = {"params": params, "state": state}
            restored = ckpt.restore(latest, like)
            meta = ckpt.restore_meta(latest)
            if engine is not None:
                # fail BEFORE overwriting live state if the on-disk (ns, k)
                # optimizer state was selected under a different plan
                engine.validate_meta(ckpt.restore_selection(latest))
            params, state = restored["params"], restored["state"]
            loader.state = LoaderState.from_dict(meta["loader"])
            start_step = latest
            print(f"[resume] restored step {latest}")

    preempt = PreemptionSimulator(args.crash_at or None)
    monitor = StragglerMonitor()
    timer = StepTimer()

    ckpt_meta = {"loader": None}
    if engine is not None:
        ckpt_meta["selection"] = engine.plan_meta()

    # The loop never calls jax.block_until_ready: train_step and refresh
    # are dispatched asynchronously, the next batch is prepared on the
    # host while the device works, and metric printing is deferred one
    # step.  The only refresh-time sync is overflow_retry's single
    # scalar D2H (disable with --no-overflow-retry to keep refresh fully
    # async) — mask refresh otherwise overlaps the host loop.
    pending = None                # (step, metrics, refreshed_flag)
    n_retried = 0                 # overflow auto-retries logged so far
    reg = obs_ctx.registry
    tr = obs_ctx.tracer
    batch = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
    for step in range(start_step, args.steps):
        t_step = tr.now()
        params, state, metrics = train_step(params, state, batch)
        refreshed = refresh is not None \
            and (step + 1) % args.update_interval == 0
        if refreshed:
            t_rf = tr.now()
            state = refresh(params, state, jax.random.PRNGKey(1000 + step))
            # host-side dispatch window (the refresh program itself is
            # async; only overflow_retry's existing D2H lands here)
            reg.histogram("train.refresh_s").observe(tr.now() - t_rf)
            reg.counter("train.refreshes").inc()
        # snapshot BEFORE prefetching: it must record batches 0..step
        # consumed so a resumed run re-fetches exactly batch step+1
        loader_snap = loader.state.to_dict()
        if step + 1 < args.steps:
            batch = {k: jnp.asarray(v)
                     for k, v in loader.next_batch().items()}
        if pending is not None:
            pstep, pmetrics, pdt = pending
            print(f"step {pstep:5d} loss {float(pmetrics['loss']):.4f} "
                  f"gnorm {float(pmetrics['grad_norm']):.3f} {pdt*1e3:.0f}ms")
        pending = None
        dt = timer.lap()
        monitor.observe(0, dt)
        # the lap time is already a host scalar the loop computes — no
        # sync is added by recording it (obs hard rule, DESIGN.md §11)
        reg.counter("train.steps").inc()
        reg.histogram("train.step_s").observe(dt)
        tr.add("train.step", "train", t_step, tr.now(), step=step)
        if refreshed:
            print(f"[lift] mask refresh dispatched at step {step + 1}")
            if len(refresh.retried_history) > n_retried:
                names, unresolved = refresh.retried_history[-1]
                n_retried = len(refresh.retried_history)
                print(f"[lift] compaction overflow at step {step + 1}: "
                      f"auto-retried {len(names)} tensor(s) with doubled "
                      f"compact_factor: {', '.join(names)}"
                      + (f" (STILL overflowing: {list(unresolved)})"
                         if unresolved else ""))
        if step % 10 == 0 or step == args.steps - 1:
            pending = (step, metrics, dt)
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt_meta["loader"] = loader_snap
            t_ck = tr.now()
            ckpt.save_async(step + 1, {"params": params, "state": state},
                            meta=dict(ckpt_meta))
            # save_async returns after snapshot+enqueue; the write runs
            # in the manager's thread — this span is the loop's cost
            reg.histogram("train.ckpt_enqueue_s").observe(tr.now() - t_ck)
            tr.add("ckpt.save_async", "ckpt", t_ck, tr.now(),
                   step=step + 1)
        if args.metrics_every and (step + 1) % args.metrics_every == 0:
            print(f"[metrics] step {step + 1}")
            print(obs_lib.render_snapshot(reg.snapshot(),
                                          prefix="train."))
        preempt.check(step + 1)

    if pending is not None:
        pstep, pmetrics, pdt = pending
        print(f"step {pstep:5d} loss {float(pmetrics['loss']):.4f} "
              f"gnorm {float(pmetrics['grad_norm']):.3f} {pdt*1e3:.0f}ms")
    if refresh is not None and refresh.overflow_history:
        ovf = sum(int(x) for x in refresh.overflow_history)
        unresolved = [u for _, us in refresh.retried_history for u in us]
        if ovf and not method.lift.overflow_retry:
            print(f"[lift] WARNING: compaction overflow dropped {ovf} "
                  f"candidates across {len(refresh.overflow_history)} "
                  f"refreshes — raise LiftConfig.compact_factor or "
                  f"re-enable overflow_retry")
        elif unresolved:
            print(f"[lift] WARNING: overflow retry exhausted max factor "
                  f"for {sorted(set(unresolved))} — masks degraded; "
                  f"raise LiftConfig.compact_factor")

    if ckpt is not None:
        t_ck = tr.now()
        ckpt.wait()
        tr.add("ckpt.wait", "ckpt", t_ck, tr.now())
    if args.eval:
        from repro.data.synthetic import eval_accuracy
        eff = T.effective_params(model, params, state, method)
        acc = eval_accuracy(model, eff, args.task, n=32, seq_len=args.seq)
        print(f"[eval] {args.task} accuracy {acc:.3f}")

    snap = reg.snapshot()
    print("[metrics]")
    print(obs_lib.render_snapshot(snap))
    if args.metrics_out:
        import json
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[metrics] snapshot -> {args.metrics_out}")
    if args.trace_out:
        n = obs_ctx.tracer.write_jsonl(args.trace_out)
        print(f"[trace] {n} span(s) -> {args.trace_out}")
    if args.audit_manifest:
        manifest = obs_lib.load_manifest(args.audit_manifest)
        for name, r in obs_ctx.auditor.report().items():
            if r["calls"]:
                print(f"[audit] {name}: {r['compilations']} "
                      f"compilation(s) over {r['calls']} call(s)")
        errs = obs_ctx.auditor.check(manifest)
        if errs:
            for e in errs:
                print(f"[audit] FAIL {e}")
            raise SystemExit(1)
        print(f"[audit] ok: compilations == expected "
              f"({args.audit_manifest})")
    print("done")


if __name__ == "__main__":
    main()
