"""Generate the EXPERIMENTS.md §Dry-run table from results/dryrun/."""
from __future__ import annotations


from repro.launch.roofline import load_results


def dryrun_markdown() -> str:
    out = ["| arch | shape | mesh | compile s | in-bytes/dev GiB | "
           "temp bytes/dev | HLO flops/dev (extrap) | coll link-bytes/dev | "
           "collective mix |",
           "|---|---|---|---|---|---|---|---|---|"]
    skips = []
    for mesh_tag in ("single", "multi"):
        for (arch, shape), r in sorted(load_results(mesh_tag).items()):
            if r.get("skipped"):
                if mesh_tag == "single":
                    skips.append((arch, shape, r["reason"]))
                continue
            ce = r.get("cost_extrapolated", {})
            mix = ce.get("coll_by_kind", {})
            mix_s = " ".join(f"{k.split('-')[-1][:4]}:{v:.1e}"
                             for k, v in sorted(mix.items(),
                                                key=lambda x: -x[1])[:3])
            out.append(
                f"| {arch} | {shape} | {mesh_tag} | {r['compile_s']} "
                f"| {r['per_device_input_gib']} "
                f"| {r['memory_analysis']['temp_bytes']:.2e} "
                f"| {ce.get('flops', float('nan')):.3e} "
                f"| {ce.get('coll_link_bytes', float('nan')):.3e} "
                f"| {mix_s} |")
    out.append("")
    out.append("Skipped cells (documented in DESIGN.md §8):")
    out.append("")
    for arch, shape, reason in skips:
        out.append(f"* `{arch} x {shape}` — {reason}")
    return "\n".join(out)


if __name__ == "__main__":
    print(dryrun_markdown())
