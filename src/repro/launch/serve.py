"""Serving launcher: the unified paged engine over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --max-new 16

Every family serves through ONE engine (`repro.serving.make_engine`,
DESIGN.md §5): KV lives in `--pages` shared pages of `--page-size`
tokens with page-aware continuous batching (admission waits or preempts
instead of OOMing), sliding-window families keep a ring of pages per
slot, and recurrent families (rwkv6 / zamba hybrids) draw fixed-size
state slabs from the same pool — checkpointed on preemption so a
restart resumes decode instead of re-running prefill.
`--chunked-prefill` interleaves fixed-size prompt chunks with decode
steps (dense family).  The legacy `--engine` / `--kv-*` spellings are
deprecated aliases.

DeltaHub (DESIGN.md §4): `--base <ckpt-dir>` restores the base weights
from a checkpoint; `--delta <artifact-dir>` loads a sparse delta artifact
into the engine's AdapterStore (refusing a wrong base hash) and serves
every request through the merged adapter — token-identical to serving the
dense fine-tuned checkpoint, at O(k) artifact bytes.  `--merge-mode`
picks the scatter-merge backend (Pallas kernel vs dense reference).

Merge-free multi-adapter serving (DESIGN.md §5): `--adapter-pool N`
keeps ONE base weight set resident and serves every `--delta` (the flag
repeats) as pool-resident sparse pages composed into the forward matmuls
per batch slot — a decode batch mixes adapters freely, requests are
assigned round-robin across the loaded deltas, and token streams are
bitwise-identical to merge-on-load serving.
`--adapter-pool-entries` sets the page granularity.

Quantized base (DESIGN.md §12): `--quantize-base` converts the restored
dense weights into an int8 resident base plus a full-precision overlay
of the top `--overlay-density` principal weights and super-weight
outliers (`src/repro/quant/`) before engine construction — halving
weight HBM per replica while the matmuls dequantize in the epilogue.
Composes with the merge-free adapter pool (base int8 + principal
overlay + per-slot delta in one epilogue); merge-on-load `--delta` is
refused (it scatters into dense leaves).

Speculative decode (DESIGN.md §5): `--speculate` verifies `--draft-len`
drafted tokens per decode dispatch (dense family).  `--draft-source
ngram` drafts by prompt lookup (no extra model); `--draft-source base`
drafts with the unmerged base weights (the LIFT-native drafter under
`--delta`); `--draft-arch` drafts with a smaller arch's smoke config.
Token streams stay bitwise-identical to one-token decode at any
temperature for any drafter — acceptance only moves throughput — and
the verify path compiles exactly one program.
"""
from __future__ import annotations

import argparse
import time
import warnings

import jax
import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pages", type=int, default=64,
                    help="shared KV/state pages in the pool (every "
                         "family serves through the paged engine)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page")
    ap.add_argument("--exhaustion", default="preempt",
                    choices=["preempt", "stall"],
                    help="page-exhaustion policy: preempt the youngest "
                         "sequence or stall the growing one")
    ap.add_argument("--base", default="",
                    help="checkpoint dir to restore base weights from "
                         "(latest step); default: fresh init")
    ap.add_argument("--delta", action="append", default=[],
                    help="sparse delta artifact dir (DeltaHub) to serve — "
                         "refuses a wrong base; repeat the flag to serve "
                         "several adapters (requests are assigned "
                         "round-robin)")
    ap.add_argument("--merge-mode", default="kernel",
                    choices=["kernel", "ref"],
                    help="delta scatter-merge backend: Pallas kernel or "
                         "dense jnp reference (merge-on-load path; "
                         "ignored under --adapter-pool)")
    ap.add_argument("--adapter-pool", type=int, default=0,
                    help="serve --delta adapters MERGE-FREE from a paged "
                         "adapter pool with this many pages: one base "
                         "weight set stays resident and each slot's "
                         "sparse delta composes into the forward matmuls "
                         "(dense family; 0 = merge-on-load AdapterStore)")
    ap.add_argument("--adapter-pool-entries", type=int, default=2048,
                    help="(idx, val) entries per adapter-pool page")
    ap.add_argument("--overlay-backend", default="lax",
                    choices=["lax", "kernel", "auto"],
                    help="delta-overlay matmul backend (--adapter-pool): "
                         "exact O(k) lax scatter or the Pallas fused "
                         "gather-epilogue kernel")
    ap.add_argument("--quantize-base", action="store_true",
                    help="serve an int8 resident base + full-precision "
                         "principal-weight overlay instead of the dense "
                         "weights (src/repro/quant/, DESIGN.md §12); "
                         "composes with --adapter-pool, refuses "
                         "merge-on-load --delta")
    ap.add_argument("--overlay-density", type=float, default=0.05,
                    help="fraction of entries kept at full precision in "
                         "the principal overlay (--quantize-base)")
    ap.add_argument("--quant-scale", default="per-channel",
                    choices=["per-channel", "per-tensor"],
                    help="int8 scale granularity (--quantize-base)")
    ap.add_argument("--quant-rank", type=int, default=32,
                    help="rank-reduction rank for principal-weight "
                         "scoring (--quantize-base)")
    ap.add_argument("--no-buckets", action="store_true",
                    help="disable power-of-two prefill length buckets "
                         "(compile per exact prompt length)")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="prefill long prompts in fixed-size chunks that "
                         "interleave with decode steps (dense family)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="tokens per prefill chunk (--chunked-prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share reference-counted prompt-prefix pages "
                         "across requests (dense family)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative multi-token decode: verify "
                         "--draft-len drafted tokens per decode dispatch "
                         "(dense family; token streams stay "
                         "bitwise-identical to one-token decode)")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="drafted tokens per decode dispatch "
                         "(--speculate)")
    ap.add_argument("--draft-source", default="ngram",
                    choices=["ngram", "base"],
                    help="draft proposals: 'ngram' prompt-lookup (no "
                         "extra model) or 'base' greedy decode with the "
                         "unmerged base weights (the LIFT drafter under "
                         "--delta; self-drafting without it)")
    ap.add_argument("--draft-arch", default="",
                    help="draft with this (smaller) arch's smoke config "
                         "instead of the serving model — fresh-init, so "
                         "acceptance is a smoke signal only; vocab sizes "
                         "must match (--speculate)")
    ap.add_argument("--trace-out", default="",
                    help="write per-request spans (queue/prefill/decode/"
                         "draft/verify/accept/pool tiles + request "
                         "envelopes) as JSONL to this path "
                         "(docs/OBSERVABILITY.md)")
    ap.add_argument("--metrics-out", default="",
                    help="dump the final metrics-registry snapshot as "
                         "JSON to this path")
    ap.add_argument("--audit-manifest", default="",
                    help="check observed jit compilations against this "
                         "expected-compilations manifest "
                         "(benchmarks/compilations_manifest.json) and "
                         "exit nonzero on any violation — the "
                         "compilations == expected CI gate")
    # ------------------------------------------- deprecated aliases
    # (default None so "flag was passed" is detectable; resolved by
    # `resolve_deprecated` into the canonical spellings above)
    ap.add_argument("--engine", default=None, choices=["dense", "paged"],
                    help=argparse.SUPPRESS)
    ap.add_argument("--kv-pages", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--kv-policy", default=None,
                    choices=["preempt", "stall"],
                    help=argparse.SUPPRESS)
    return ap


def resolve_deprecated(args: argparse.Namespace) -> argparse.Namespace:
    """Map legacy flag spellings onto the canonical ones, warning once
    per flag.  `--engine` is accepted and ignored: every family serves
    through the one paged engine now."""
    def warn(old: str, new: str):
        warnings.warn(f"{old} is deprecated; use {new}",
                      DeprecationWarning, stacklevel=3)

    if args.engine is not None:
        warn("--engine", "the unified engine (the flag is ignored; "
             "dense serving survives only as the test oracle)")
    if args.kv_pages is not None:
        warn("--kv-pages", "--pages")
        if args.kv_pages > 0:
            args.pages = args.kv_pages
    if args.kv_page_size is not None:
        warn("--kv-page-size", "--page-size")
        args.page_size = args.kv_page_size
    if args.kv_policy is not None:
        warn("--kv-policy", "--exhaustion")
        args.exhaustion = args.kv_policy
    return args


def build_engine_from_args(args: argparse.Namespace, obs_ctx=None):
    """Model + weights + adapters/quant/draft + unified engine from a
    parsed `build_parser()` namespace.  Returns `(engine, adapter_ids,
    model_cfg)` so callers (the CLI below, the scenario benchmark
    harness) share one construction path."""
    from repro.configs import get_arch
    from repro.data.synthetic import EOS
    from repro.models import build_model
    from repro.serving import AdapterStore, ServingConfig, make_engine

    bundle = get_arch(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.full
    if cfg.is_encoder:
        raise SystemExit("encoder-only architectures have no decode serving")
    if cfg.input_mode == "embeddings":
        cfg = cfg.replace(input_mode="tokens")  # serve the text backbone
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    if args.base:
        from repro.checkpoint.manager import CheckpointManager
        ckpt = CheckpointManager(args.base)
        step = ckpt.latest_step()
        if step is None:
            raise SystemExit(f"--base {args.base}: no checkpoint steps")
        params = ckpt.restore(step, {"params": params})["params"]
        print(f"[base] restored step {step} from {args.base}")

    if args.adapter_pool > 0 and not args.delta:
        raise SystemExit("--adapter-pool without --delta has nothing "
                         "to pool; pass one or more --delta dirs")

    adapters = None
    apool = None
    adapter_ids: list = []
    if args.delta:
        from repro.deltas import DeltaArtifact
        if args.adapter_pool > 0:
            from repro.serving.kvpool import AdapterPool
            apool = AdapterPool(params, num_pages=args.adapter_pool,
                                entries_per_page=args.adapter_pool_entries)
        else:
            adapters = AdapterStore(params, backend=args.merge_mode)
        for i, path in enumerate(args.delta):
            delta = DeltaArtifact.load(path)
            aid = f"delta{i}"
            if apool is not None:
                apool.register(aid, delta)
                verb = "pooled"
            else:
                adapters.load(aid, delta)
                verb = "merged"
            adapter_ids.append(aid)
            print(f"[delta] {verb} {path} as {aid!r} ({delta.nbytes()} "
                  f"payload bytes, "
                  f"{100 * delta.nbytes() / delta.dense_nbytes():.1f}% "
                  f"of dense, mode={delta.manifest['mode']})")
        if apool is not None:
            st = apool.stats()
            print(f"[adapter-pool] {st['num_pages']} pages x "
                  f"{st['entries_per_page']} entries, "
                  f"{st['pages_per_adapter']} pages/adapter "
                  f"({st['adapter_nbytes']} B resident/adapter, "
                  f"{100 * st['adapter_bytes_ratio']:.1f}% of one dense "
                  f"merged copy)")

    if args.quantize_base:
        if args.delta and args.adapter_pool <= 0:
            raise SystemExit(
                "--quantize-base composes with --delta only through the "
                "merge-free pool (--adapter-pool N): merge-on-load "
                "scatters into dense weight leaves, which no longer exist "
                "under a quantized base")
        from repro.quant import QuantConfig, quantize
        qcfg = QuantConfig(scale_mode=args.quant_scale,
                           density=args.overlay_density,
                           rank=args.quant_rank)
        art = quantize(model, params, qcfg, jax.random.PRNGKey(args.seed))
        ratio = art.resident_nbytes() / art.dense_nbytes()
        entries = sum(int(np.prod(t["idx"].shape))
                      for t in art.tensors.values())
        params = art.to_params(params)
        if obs_ctx is not None:
            reg = obs_ctx.registry
            reg.gauge("quant.hbm_bytes_ratio").set(ratio)
            reg.gauge("quant.tensors").set(len(art.tensors))
            reg.gauge("quant.overlay_entries").set(entries)
        print(f"[quant] int8 base + {100 * qcfg.density:.1f}% principal "
              f"overlay ({qcfg.scale_mode} scales): {len(art.tensors)} "
              f"tensors, {entries} overlay entries, "
              f"{art.resident_nbytes()} B resident "
              f"({100 * ratio:.1f}% of dense)")

    draft_model = draft_params = None
    if args.speculate and args.draft_arch:
        dcfg = get_arch(args.draft_arch).smoke
        if dcfg.vocab_size != cfg.vocab_size:
            raise SystemExit(
                f"--draft-arch {args.draft_arch}: drafter vocab "
                f"{dcfg.vocab_size} != target vocab {cfg.vocab_size} — "
                f"drafted token ids must share the target's vocabulary")
        draft_model = build_model(dcfg)
        draft_params = draft_model.init(jax.random.PRNGKey(args.seed + 1))

    eng = make_engine(model, params, ServingConfig(
        batch_slots=args.slots, max_len=args.max_len, eos_id=EOS,
        seed=args.seed, page_size=args.page_size,
        num_pages=args.pages,
        chunked_prefill=args.chunked_prefill,
        prefill_chunk=args.prefill_chunk,
        prefill_buckets=not args.no_buckets,
        prefix_cache=args.prefix_cache,
        exhaustion=args.exhaustion,
        speculate=args.draft_len if args.speculate else 0,
        draft_source=("model" if (args.draft_source == "base"
                                  or args.draft_arch) else "ngram"),
        overlay_backend=args.overlay_backend),
        adapters=adapters, draft_model=draft_model,
        draft_params=draft_params, adapter_pool=apool, obs=obs_ctx)
    return eng, adapter_ids, cfg


def main(argv=None):
    args = resolve_deprecated(build_parser().parse_args(argv))

    from repro import obs as obs_lib
    obs_ctx = obs_lib.default()
    if args.trace_out:
        obs_ctx.tracer.enabled = True

    from repro.data.synthetic import BOS, SEP, encode, decode, \
        make_arith_example
    from repro.serving import Request

    eng, adapter_ids, _ = build_engine_from_args(args, obs_ctx)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        q, _ = make_arith_example(rng)
        prompt = np.asarray([BOS] + encode(q) + [SEP], np.int32)
        aid = adapter_ids[i % len(adapter_ids)] if adapter_ids else None
        eng.submit(Request(uid=i, prompt=prompt,
                           max_new_tokens=args.max_new,
                           temperature=args.temperature,
                           adapter_id=aid))
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: {decode(r.out_tokens)!r}")
    print(f"{len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s, "
          f"{args.slots} slots continuous batching)")
    # ONE renderer over the metrics registry replaces the old per-
    # subsystem stat prints: engine counters, kvpool./apool./spec.
    # gauges and the latency histograms all come out of the snapshot
    snap = eng.metrics_snapshot()
    print("[metrics]")
    print(obs_lib.render_snapshot(snap))

    if args.metrics_out:
        import json
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        print(f"[metrics] snapshot -> {args.metrics_out}")
    if args.trace_out:
        n = obs_ctx.tracer.write_jsonl(args.trace_out)
        print(f"[trace] {n} span(s) -> {args.trace_out}"
              + (f" ({obs_ctx.tracer.dropped} dropped)"
                 if obs_ctx.tracer.dropped else ""))
    if args.audit_manifest:
        manifest = obs_lib.load_manifest(args.audit_manifest)
        rep = obs_ctx.auditor.report()
        for name, r in rep.items():
            if r["calls"]:
                print(f"[audit] {name}: {r['compilations']} "
                      f"compilation(s) over {r['calls']} call(s)")
        errs = obs_ctx.auditor.check(manifest)
        if errs:
            for e in errs:
                print(f"[audit] FAIL {e}")
            raise SystemExit(1)
        print(f"[audit] ok: compilations == expected "
              f"({args.audit_manifest})")


if __name__ == "__main__":
    main()
