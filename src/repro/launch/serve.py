"""Serving launcher: continuous-batching engine over a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.data.synthetic import BOS, EOS, SEP, encode, decode, \
        make_arith_example
    from repro.models import build_model
    from repro.serving.engine import Engine, EngineConfig, Request

    bundle = get_arch(args.arch)
    cfg = bundle.smoke if args.smoke else bundle.full
    if cfg.is_encoder:
        raise SystemExit("encoder-only architectures have no decode serving")
    if cfg.input_mode == "embeddings":
        cfg = cfg.replace(input_mode="tokens")  # serve the text backbone
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    eng = Engine(model, params, EngineConfig(
        batch_slots=args.slots, max_len=args.max_len, eos_id=EOS,
        seed=args.seed))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        q, _ = make_arith_example(rng)
        prompt = np.asarray([BOS] + encode(q) + [SEP], np.int32)
        eng.submit(Request(uid=i, prompt=prompt,
                           max_new_tokens=args.max_new,
                           temperature=args.temperature))
    done = eng.run()
    dt = time.time() - t0
    total_new = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"req {r.uid}: {decode(r.out_tokens)!r}")
    print(f"{len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s, "
          f"{args.slots} slots continuous batching)")


if __name__ == "__main__":
    main()
