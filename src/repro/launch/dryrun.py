import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and the production meshes need 512 host-platform placeholder
devices.  Tests and benchmarks do NOT import this module (they see 1 device).

Per cell this runner produces:
  * full-depth compile  -> proof of shardability + memory_analysis()
  * depth-P and depth-2P UNROLLED compiles (single-pod only) -> exact HLO
    flops / bytes / collective-bytes per layer by linear extrapolation
    (scan bodies are counted once by cost_analysis; DESIGN.md §7)

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--jobs ...]
  python -m repro.launch.dryrun --summary
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# §Perf variants: beyond-baseline sharding schemes.  Each entry is
# (rules_extra, cfg_transform).  "zero3" = pure 256-way data parallelism
# with ZeRO-3 parameter sharding (per-layer weight all-gather) — right for
# small-d_model models where Megatron TP's activation psums dominate.
# "seqp" = sequence-parallel activations for long-context prefill.
VARIANTS = {
    "zero3": (
        {"batch": ("pod", "data", "model"),
         "embed": ("data", "model"),
         "mlp": (), "heads_flat": (), "heads": (), "kv_heads": (),
         "experts": (), "expert_mlp": (), "vocab": (),
         "capacity": ("data", "model"),
         "cache_seq": ()},
        lambda cfg: cfg.replace(moe_groups=256) if cfg.num_experts else cfg,
    ),
    "seqp": (
        {"seq": ("model",)},
        lambda cfg: cfg,
    ),
    # zero3 without remat: drops the 3rd ZeRO weight re-gather (bwd only
    # re-gathers once) at the price of storing activations
    "zero3nr": (
        {"batch": ("pod", "data", "model"),
         "embed": ("data", "model"),
         "mlp": (), "heads_flat": (), "heads": (), "kv_heads": (),
         "experts": (), "expert_mlp": (), "vocab": (),
         "capacity": ("data", "model"),
         "cache_seq": ()},
        lambda cfg: (cfg.replace(moe_groups=256) if cfg.num_experts else cfg
                     ).replace(remat=False),
    ),
    # zero3 + expert weights kept sharded over "model" (no per-layer expert
    # all-gather; XLA reshards the dispatch buffer instead)
    "zero3ep": (
        {"batch": ("pod", "data", "model"),
         "embed": ("data",),
         "mlp": (), "heads_flat": (), "heads": (), "kv_heads": (),
         "experts": ("model",), "expert_mlp": (), "vocab": (),
         "capacity": ("data", "model"),
         "cache_seq": ()},
        lambda cfg: cfg.replace(moe_groups=256) if cfg.num_experts else cfg,
    ),
}


def _result_path(arch, shape, mesh_tag, method, variant=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if method == "lift" else f"_{method}"
    if variant:
        suffix += f"_{variant}"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_tag}{suffix}.json")


def _shard_bytes(sds_tree, sharding_tree, mesh):
    """Exact per-device bytes of an input tree under its shardings."""
    import jax
    import numpy as np
    total = 0
    leaves_s = jax.tree.leaves(sds_tree)
    if sharding_tree is None:
        shardings = [None] * len(leaves_s)
    else:
        shardings = jax.tree.leaves(
            sharding_tree, is_leaf=lambda x: hasattr(x, "shard_shape"))
    for sds, sh in zip(leaves_s, shardings):
        if sh is not None and hasattr(sh, "shard_shape"):
            shp = sh.shard_shape(sds.shape)
        else:
            shp = sds.shape
        total += int(np.prod(shp)) * sds.dtype.itemsize
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool, method: str,
             skip_cost: bool = False, variant: str = "") -> dict:
    import jax
    from repro.configs import LM_SHAPES, get_arch
    from repro.launch import hlo as hlomod
    from repro.launch.lowering import build_cell, cost_analysis_dict
    from repro.launch.mesh import make_production_mesh

    bundle = get_arch(arch)
    shape = LM_SHAPES[shape_name]
    if shape_name in bundle.skips:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": bundle.skips[shape_name]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    cfg = bundle.full
    rules_extra = None
    if variant:
        rules_extra, cfg_tf = VARIANTS[variant]
        cfg = cfg_tf(cfg)
    out = {"arch": arch, "shape": shape_name, "method": method,
           "mesh": list(mesh.devices.shape), "n_devices": n_dev,
           "kind": shape.kind, "variant": variant}

    # ---------------- full-depth compile: shardability + memory ----------
    t0 = time.time()
    low = build_cell(bundle, cfg, mesh, shape, method=method,
                     rules_extra=rules_extra)
    jfn = jax.jit(low.fn, in_shardings=low.in_shardings,
                  out_shardings=low.out_shardings,
                  donate_argnums=low.donate)
    lowered = jfn.lower(*low.args)
    out["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    out["compile_s"] = round(time.time() - t0, 2)
    ma = compiled.memory_analysis()
    out["memory_analysis"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    # exact per-device resident input bytes from the shardings
    names = ["params", "state_or_batch", "batch_or_cache", "positions"]
    per_arg = {}
    for i, (sds, sh) in enumerate(zip(low.args, low.in_shardings)):
        per_arg[names[i] if i < len(names) else f"arg{i}"] = \
            _shard_bytes(sds, sh, mesh)
    out["per_device_input_bytes"] = per_arg
    out["per_device_input_gib"] = round(sum(per_arg.values()) / 2**30, 3)

    ca_full = cost_analysis_dict(compiled)
    out["cost_full_scanned"] = {
        "flops": float(ca_full.get("flops", -1)),
        "bytes": float(ca_full.get("bytes accessed", -1)),
    }

    # ---------------- cost extrapolation (single-pod only) ---------------
    if not multi_pod and not skip_cost:
        period = cfg.shared_attn_period if cfg.family == "hybrid" else 1
        costs = {}
        for depth in (period, 2 * period):
            ccfg = cfg.replace(
                num_layers=depth, scan_layers=False, unroll_layers=True,
                attn_chunk=(max(1024, shape.seq_len // 4)
                            if cfg.attn_chunk else 0))
            low2 = build_cell(bundle, ccfg, mesh, shape, method=method,
                              rules_extra=rules_extra)
            jfn2 = jax.jit(low2.fn, in_shardings=low2.in_shardings,
                           out_shardings=low2.out_shardings,
                           donate_argnums=low2.donate)
            comp2 = jfn2.lower(*low2.args).compile()
            ca = cost_analysis_dict(comp2)
            colls = hlomod.analyze_collectives(comp2.as_text(), n_dev)
            costs[depth] = {
                "flops": float(ca.get("flops", 0)),
                "bytes": float(ca.get("bytes accessed", 0)),
                "coll_link_bytes": colls.link_bytes,
                "coll_by_kind": dict(colls.by_kind),
                "coll_count": colls.count,
                "coll_in_while": colls.in_while,
            }
        L = cfg.num_layers
        P = period
        c1, c2 = costs[P], costs[2 * P]

        def extrap(a, b):
            return a + (L - P) / P * (b - a)

        out["cost_depths"] = costs
        by_kind = {k: extrap(c1["coll_by_kind"].get(k, 0.0),
                             c2["coll_by_kind"].get(k, 0.0))
                   for k in set(c1["coll_by_kind"]) | set(c2["coll_by_kind"])}
        out["cost_extrapolated"] = {
            "flops": extrap(c1["flops"], c2["flops"]),
            "bytes": extrap(c1["bytes"], c2["bytes"]),
            "coll_link_bytes": extrap(c1["coll_link_bytes"],
                                      c2["coll_link_bytes"]),
            "coll_by_kind": by_kind,
            "coll_in_while": c1["coll_in_while"] + c2["coll_in_while"],
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--method", default="lift", choices=["lift", "full"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--variant", default="", choices=[""] + list(VARIANTS))
    args = ap.parse_args()

    if args.summary:
        print_summary()
        return

    if args.all:
        orchestrate(args)
        return

    mesh_tag = "multi" if args.multi_pod else "single"
    path = _result_path(args.arch, args.shape, mesh_tag, args.method,
                        args.variant)
    try:
        res = run_cell(args.arch, args.shape, args.multi_pod, args.method,
                       args.skip_cost, args.variant)
    except Exception as e:  # recorded, orchestrator continues
        res = {"arch": args.arch, "shape": args.shape, "error": str(e),
               "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(f"FAIL {args.arch} {args.shape} {mesh_tag}: {e}",
              file=sys.stderr)
        sys.exit(1)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    if res.get("skipped"):
        print(f"SKIP {args.arch} {args.shape}: {res['reason']}")
    else:
        ce = res.get("cost_extrapolated", {})
        print(f"OK {args.arch} {args.shape} {mesh_tag} "
              f"compile={res['compile_s']}s "
              f"in_bytes/dev={res['per_device_input_gib']}GiB "
              f"flops/dev={ce.get('flops', 0):.3e} "
              f"coll/dev={ce.get('coll_link_bytes', 0):.3e}B")


def orchestrate(args):
    """Run every cell in a subprocess (isolates XLA state + memory)."""
    from repro.configs import ASSIGNED, LM_SHAPES
    meshes = ["single", "multi"] if args.both_meshes else \
        (["multi"] if args.multi_pod else ["single"])
    cells = []
    for arch in ASSIGNED:
        for shape in LM_SHAPES:
            for mesh_tag in meshes:
                cells.append((arch, shape, mesh_tag))
    failures = 0
    for arch, shape, mesh_tag in cells:
        path = _result_path(arch, shape, mesh_tag, args.method)
        if os.path.exists(path) and not args.force:
            print(f"cached {arch} {shape} {mesh_tag}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--method", args.method]
        if mesh_tag == "multi":
            cmd.append("--multi-pod")
        if args.skip_cost or mesh_tag == "multi":
            cmd.append("--skip-cost")
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True)
        dt = time.time() - t0
        tail = (r.stdout + r.stderr).strip().splitlines()
        msg = tail[-1] if tail else ""
        print(f"[{dt:6.1f}s] {msg}")
        if r.returncode != 0:
            failures += 1
    print(f"done; {failures} failures")


def print_summary():
    rows = []
    for fn in sorted(os.listdir(RESULTS_DIR)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(RESULTS_DIR, fn)) as f:
            rows.append(json.load(f))
    ok = [r for r in rows if "error" not in r and not r.get("skipped")]
    sk = [r for r in rows if r.get("skipped")]
    er = [r for r in rows if "error" in r]
    print(f"{len(ok)} compiled, {len(sk)} skipped, {len(er)} failed")
    for r in er:
        print("FAILED:", r["arch"], r["shape"], r["error"][:120])


if __name__ == "__main__":
    main()
