"""Builders that turn (arch x shape x mesh x method) into a lowerable jit.

`input_specs` returns ShapeDtypeStruct stand-ins for every input — weak-type
correct, shardable, never allocated.  Each builder returns
(fn, args, in_shardings, out_shardings, donate) ready for

    jax.jit(fn, in_shardings=..., out_shardings=..., donate_argnums=...) \
        .lower(*args).compile()
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchBundle, ShapeSpec
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig, make_plan
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.parallel.sharding import sharding_ctx, tree_shardings
from repro.training import trainer as T

I32 = jnp.int32
F32 = jnp.float32


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a dict on recent jax but a
    one-dict-per-device list on older releases — normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def safe_shardings(sds_tree, sharding_tree, mesh):
    """jit in_shardings require every sharded dim to divide evenly; null out
    the axes that don't (e.g. hubert's 504-way vocab head, batch=1 decode).
    Interior with_sharding_constraints still shard those values (GSPMD pads
    intermediates)."""
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    if sharding_tree is None:
        return None
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(sds, sh):
        if sh is None or not hasattr(sh, "spec"):
            return sh
        spec = list(sh.spec) + [None] * (len(sds.shape) - len(sh.spec))
        out = []
        for dim, ax in zip(sds.shape, spec):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([axis_size[a] for a in axes]))
            out.append(ax if dim % n == 0 else None)
        return NamedSharding(mesh, P(*out))

    sh_leaves = jax.tree.leaves(
        sharding_tree, is_leaf=lambda x: hasattr(x, "spec"))
    sds_leaves = jax.tree.leaves(sds_tree)
    fixed = [fix(s, h) for s, h in zip(sds_leaves, sh_leaves)]
    treedef = jax.tree.structure(
        sharding_tree, is_leaf=lambda x: hasattr(x, "spec"))
    return jax.tree.unflatten(treedef, fixed)



def _ctx_fn(fn, mesh, rules):
    """Re-enter the sharding context at TRACE time: jit(...).lower() runs
    outside the builder's `with sharding_ctx(...)` block, and shard_logical
    constraints are no-ops without an active mesh."""
    import functools

    @functools.wraps(fn)
    def wrapped(*args):
        with sharding_ctx(mesh, rules):
            return fn(*args)

    return wrapped

def _dt(cfg: ModelConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.compute_dtype]


DEFAULT_LIFT = LiftConfig(rank=128, density=0.05, method="randomized",
                          update_interval=200, k_multiple=1024)
DEFAULT_ADAM = sa.AdamConfig(lr=1e-4, weight_decay=0.0, grad_clip=1.0)


# ------------------------------------------------------------- input specs
def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), _dt(cfg))
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), I32)
    batch["labels"] = jax.ShapeDtypeStruct((B, S), I32)
    batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), F32)
    return batch


def train_batch_axes(cfg: ModelConfig):
    axes = {"labels": ("batch", "seq"), "loss_mask": ("batch", "seq")}
    if cfg.input_mode == "embeddings":
        axes["embeds"] = ("batch", "seq", "embed")
    else:
        axes["tokens"] = ("batch", "seq")
    return axes


def lift_state_specs(model, lcfg: LiftConfig, use_master: bool):
    plan = make_plan(model.spec(), lcfg)
    tensors, axes = {}, {}
    for path, p in sorted(plan.items()):
        ns = int(np.prod(p.stack)) if p.stack else 1
        sd = jax.ShapeDtypeStruct((ns, p.k), I32)
        fd = jax.ShapeDtypeStruct((ns, p.k), F32)
        tensors[path] = {"idx": sd, "m": fd, "v": fd}
        axes[path] = {"idx": ("layers", "topk"), "m": ("layers", "topk"),
                      "v": ("layers", "topk")}
        if use_master:
            tensors[path]["master"] = fd
            axes[path]["master"] = ("layers", "topk")
    return ({"step": jax.ShapeDtypeStruct((), I32), "tensors": tensors},
            {"step": (), "tensors": axes})


def full_state_specs(model):
    p = model.param_shapes()
    f32 = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, F32), p)
    ax = model.axes()
    return ({"step": jax.ShapeDtypeStruct((), I32),
             "opt": {"step": jax.ShapeDtypeStruct((), I32),
                     "m": f32, "v": jax.tree.map(lambda x: x, f32)}},
            {"step": (),
             "opt": {"step": (), "m": ax, "v": jax.tree.map(lambda x: x, ax)}})


# ----------------------------------------------------------------- builders
@dataclasses.dataclass
class Lowering:
    fn: Any
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple
    meta: dict


def build_train(bundle: ArchBundle, cfg: ModelConfig, mesh, shape: ShapeSpec,
                method: str = "lift",
                lcfg: LiftConfig = DEFAULT_LIFT,
                adam: sa.AdamConfig = DEFAULT_ADAM,
                rules_extra: Optional[dict] = None) -> Lowering:
    model = build_model(cfg)
    rules = {**bundle.rules, **(rules_extra or {})}
    with sharding_ctx(mesh, rules):
        mcfg = T.MethodConfig(kind=method, lift=lcfg)
        step = T.make_train_step(model, mcfg, adam,
                                 T.constant_lr(adam.lr))
        params_sds = model.param_shapes()
        params_sh = safe_shardings(params_sds,
                                   tree_shardings(model.axes(), mesh), mesh)
        batch_sds = train_batch_specs(cfg, shape)
        batch_sh = safe_shardings(
            batch_sds, tree_shardings(train_batch_axes(cfg), mesh), mesh)
        if method == "lift":
            use_master = cfg.param_dtype != "float32"
            state_sds_inner, state_axes = lift_state_specs(model, lcfg,
                                                           use_master)
            state_sds = {"step": jax.ShapeDtypeStruct((), I32),
                         "opt": state_sds_inner}
            state_sh = safe_shardings(
                state_sds,
                tree_shardings({"step": (), "opt": state_axes}, mesh), mesh)
        elif method == "full":
            s_sds, s_axes = full_state_specs(model)
            state_sds = {"step": s_sds["step"], "opt": s_sds["opt"]}
            state_sh = safe_shardings(
                state_sds,
                tree_shardings({"step": (), "opt": s_axes["opt"]}, mesh),
                mesh)
        else:
            raise ValueError(method)

        def fn(params, state, batch):
            return step(params, state, batch)

        args = (params_sds, state_sds, batch_sds)
        in_sh = (params_sh, state_sh, batch_sh)
        out_sh = (params_sh, state_sh, None)
    return Lowering(_ctx_fn(fn, mesh, rules), args, in_sh, out_sh, (0, 1),
                    {"kind": "train", "method": method})


def build_refresh(bundle: ArchBundle, cfg: ModelConfig, mesh,
                  lcfg: LiftConfig = DEFAULT_LIFT,
                  rules_extra: Optional[dict] = None) -> Lowering:
    """LIFT mask-refresh program (SVD + top-k + state migration)."""
    model = build_model(cfg)
    rules = {**bundle.rules, **(rules_extra or {})}
    with sharding_ctx(mesh, rules):
        mcfg = T.MethodConfig(kind="lift", lift=lcfg)
        refresh = T.make_refresh_step(model, mcfg)
        params_sds = model.param_shapes()
        params_sh = safe_shardings(params_sds,
                                   tree_shardings(model.axes(), mesh), mesh)
        use_master = cfg.param_dtype != "float32"
        state_sds_inner, state_axes = lift_state_specs(model, lcfg, use_master)
        state_sds = {"step": jax.ShapeDtypeStruct((), I32),
                     "opt": state_sds_inner}
        state_sh = safe_shardings(
            state_sds, tree_shardings({"step": (), "opt": state_axes}, mesh),
            mesh)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)

        def fn(params, state, k):
            return refresh(params, state, k)

        args = (params_sds, state_sds, key)
        in_sh = (params_sh, state_sh, None)
        out_sh = state_sh
    return Lowering(_ctx_fn(fn, mesh, rules), args, in_sh, out_sh, (1,),
                    {"kind": "refresh"})


def build_prefill(bundle: ArchBundle, cfg: ModelConfig, mesh,
                  shape: ShapeSpec,
                  rules_extra: Optional[dict] = None) -> Lowering:
    model = build_model(cfg)
    rules = {**bundle.rules, **(rules_extra or {})}
    B, S = shape.global_batch, shape.seq_len
    with sharding_ctx(mesh, rules):
        params_sds = model.param_shapes()
        params_sh = safe_shardings(params_sds,
                                   tree_shardings(model.axes(), mesh), mesh)
        if cfg.input_mode == "embeddings":
            batch_sds = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                        _dt(cfg))}
            batch_sh = tree_shardings({"embeds": ("batch", "seq", "embed")},
                                      mesh)
        else:
            batch_sds = {"tokens": jax.ShapeDtypeStruct((B, S), I32)}
            batch_sh = tree_shardings({"tokens": ("batch", "seq")}, mesh)
        batch_sh = safe_shardings(batch_sds, batch_sh, mesh)

        if cfg.is_encoder:
            def fn(params, batch):
                return model.logits(params, batch)
            args = (params_sds, batch_sds)
            in_sh = (params_sh, batch_sh)
            return Lowering(_ctx_fn(fn, mesh, rules), args, in_sh, None,
                            (), {"kind": "prefill", "encoder": True})

        cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
        cache_sh = safe_shardings(
            cache_sds, tree_shardings(model.cache_axes(), mesh), mesh)

        def fn(params, batch, cache):
            return model.prefill(params, batch, cache)

        args = (params_sds, batch_sds, cache_sds)
        in_sh = (params_sh, batch_sh, cache_sh)
        out_sh = (None, cache_sh)
    return Lowering(_ctx_fn(fn, mesh, rules), args, in_sh, out_sh, (2,),
                    {"kind": "prefill"})


def build_decode(bundle: ArchBundle, cfg: ModelConfig, mesh,
                 shape: ShapeSpec,
                 rules_extra: Optional[dict] = None) -> Lowering:
    """One-token serve_step with a KV/state cache of shape.seq_len."""
    model = build_model(cfg)
    rules = {**bundle.rules, **(rules_extra or {})}
    B, S = shape.global_batch, shape.seq_len
    with sharding_ctx(mesh, rules):
        params_sds = model.param_shapes()
        params_sh = safe_shardings(params_sds,
                                   tree_shardings(model.axes(), mesh), mesh)
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
        cache_sh = safe_shardings(
            cache_sds, tree_shardings(model.cache_axes(), mesh), mesh)
        tok_sds = jax.ShapeDtypeStruct((B, 1), I32)
        tok_sh = safe_shardings(
            tok_sds, tree_shardings({"t": ("batch", "seq")}, mesh)["t"], mesh)
        pos_sds = jax.ShapeDtypeStruct((B,), I32)
        pos_sh = safe_shardings(
            pos_sds, tree_shardings({"p": ("batch",)}, mesh)["p"], mesh)

        def fn(params, tokens, cache, positions):
            return model.decode(params, tokens, cache, positions)

        args = (params_sds, tok_sds, cache_sds, pos_sds)
        in_sh = (params_sh, tok_sh, cache_sh, pos_sh)
        out_sh = (None, cache_sh)
    return Lowering(_ctx_fn(fn, mesh, rules), args, in_sh, out_sh, (2,),
                    {"kind": "decode"})


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


def build_cell(bundle: ArchBundle, cfg: ModelConfig, mesh, shape: ShapeSpec,
               method: str = "lift", **kw) -> Lowering:
    if shape.kind == "train":
        return build_train(bundle, cfg, mesh, shape, method=method, **kw)
    if shape.kind == "prefill":
        return build_prefill(bundle, cfg, mesh, shape, **kw)
    return build_decode(bundle, cfg, mesh, shape, **kw)
