"""Production mesh definitions.

`make_production_mesh` is a FUNCTION so importing this module never touches
jax device state; the dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.

Topology (TPU v5e-like):
  single pod:  (data=16, model=16)              = 256 chips
  multi pod :  (pod=2, data=16, model=16)       = 512 chips
The "pod" axis is outer data parallelism — batch shards over
("pod", "data"); only the gradient all-reduce crosses the pod boundary.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 names explicit/auto axis types; older releases have none
    from jax.sharding import AxisType

    def _axis_types(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pre-AxisType jax: every mesh axis is already "auto"
    def _axis_types(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests on the local host's devices."""
    return jax.make_mesh((data, model), ("data", "model"), **_axis_types(2))


def parse_mesh_spec(spec: str):
    """"DATAxMODEL" (e.g. "1x8", "4x2") -> host mesh, for the launcher's
    `--mesh` flag.  Validates against the visible device count so a typo
    fails with the topology instead of a deep jax error."""
    parts = spec.lower().replace("x", ",").split(",")
    if len(parts) != 2:
        raise ValueError(
            f"--mesh expects DATAxMODEL (e.g. 1x8), got {spec!r}")
    try:
        data, model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"--mesh expects two integers DATAxMODEL, got {spec!r}") from None
    if data < 1 or model < 1:
        raise ValueError(f"--mesh axes must be >= 1, got {spec!r}")
    avail = jax.device_count()
    if data * model > avail:
        raise ValueError(
            f"--mesh {spec!r} needs {data * model} devices but only "
            f"{avail} are visible (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N for host "
            f"meshes)")
    return make_host_mesh(data, model)


def selection_shards(mesh) -> int:
    """Shard count the SelectionEngine will see on `mesh` (the size of the
    mesh axes behind the "shards" logical axis)."""
    from repro.parallel.sharding import logical_axis_size
    return logical_axis_size("shards", mesh)


# hardware constants for the roofline (per chip) — TPU v5e-like
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s  (per the assignment: ~50 GB/s/link)
