"""Sharded, atomic, elastic checkpointing (no orbax dependency).

Layout per step:
    <dir>/step_000123.tmp/...      (write)
    <dir>/step_000123/             (atomic rename-commit)
        manifest.json              tree structure, shapes, dtypes, metadata
        arrays.npz                 leaf data, keyed by escaped path

Guarantees:
  * atomic: a checkpoint directory either exists fully or not at all
    (write to .tmp, fsync, os.replace) — a crash mid-write is harmless;
  * elastic: leaves are stored as LOGICAL (unsharded) arrays; `restore`
    re-device_puts them under whatever mesh/shardings the restarted job
    uses, so pod counts can change between runs;
  * self-pruning: keep the newest `keep` checkpoints;
  * async: `save_async` hands the (host-materialized) tree to a writer
    thread so the train loop is not blocked by serialization.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(like, flat, prefix=""):
    """Rebuild a tree shaped `like` from flat {path: np.ndarray}."""
    if isinstance(like, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in like.items()}
    if hasattr(like, "_fields"):
        return type(like)(*[
            _unflatten_into(getattr(like, k), flat, f"{prefix}{k}/")
            for k in like._fields])
    if isinstance(like, (list, tuple)):
        return type(like)(_unflatten_into(v, flat, f"{prefix}{i}/")
                          for i, v in enumerate(like))
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, meta: Optional[dict] = None):
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self._write(step, host, meta or {})

    def save_async(self, step: int, tree, meta: Optional[dict] = None):
        if self._error is not None:
            raise RuntimeError("async checkpoint writer failed") \
                from self._error
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # D2H now
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        self._q.put((step, host, meta or {}))

    def wait(self):
        if self._worker is not None:
            self._q.join()
        if self._error is not None:
            raise RuntimeError("async checkpoint writer failed") \
                from self._error

    def _drain(self):
        while True:
            step, host, meta = self._q.get()
            try:
                self._write(step, host, meta)
            except BaseException as e:  # surfaced on next save/wait
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host: dict, meta: dict):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "\x1f"): v for k, v in host.items()})
        manifest = {
            "step": step,
            "meta": meta,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            mm = _STEP_RE.match(d)
            if mm and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(mm.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Rebuild a tree shaped `like`.  If `shardings` (same structure or
        None) is given, leaves are device_put with those shardings —
        this is the elastic-reshard path."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k.replace("\x1f", "/"): z[k] for k in z.files}
        tree = _unflatten_into(like, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jax.device_put(x), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def restore_leaves(self, step: int, paths) -> dict:
        """Partial restore: only the named leaf paths, as host arrays.

        npz members decompress lazily per key, so unrelated leaves are
        never read into memory — delta extraction pulls the planned
        params plus the (ns, k) selection index leaves out of a multi-GB
        checkpoint at O(touched bytes) cost instead of `restore`'s full
        `arrays.npz` load.  Unknown paths raise KeyError (naming the
        step), so a caller can't silently extract against a checkpoint
        written by a different plan."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        out = {}
        with np.load(os.path.join(path, "arrays.npz")) as z:
            for p in paths:
                key = p.replace("/", "\x1f")
                if key not in z:
                    raise KeyError(
                        f"checkpoint step {step} has no leaf {p!r}")
                out[p] = z[key]
        return out

    def restore_meta(self, step: int) -> dict:
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)["meta"]

    def restore_selection(self, step: int) -> Optional[dict]:
        """The SelectionEngine plan fingerprint stored with this step
        (`meta["selection"]`, see SelectionEngine.plan_meta), or None for
        checkpoints written before the engine existed / by non-LIFT runs.
        Callers pass it to `SelectionEngine.validate_meta` so a resumed run
        proves the restored (ns, k) optimizer state matches its current
        selection geometry before training on it."""
        return self.restore_meta(step).get("selection")
