"""moonshot-v1-16b-a3b [moe] 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight.
[hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FULL_ATTN_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="moonshot-16b-a3b", family="moe", num_layers=48, d_model=2048,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=1408,
    vocab_size=163_840, num_experts=64, num_experts_per_tok=6,
    capacity_factor=1.25, moe_groups=16, **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=32, vocab_size=128,
    num_experts=8, num_experts_per_tok=2, capacity_factor=2.0,
    **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="moonshot-16b-a3b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules={},
    notes="64 experts top-6, expert-parallel over model axis (4 experts "
          "per device at TP=16); LIFT vmaps per-expert LRA")
