"""qwen3-1.7b [dense] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FULL_ATTN_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
    num_heads=16, num_kv_heads=8, head_dim=128, d_ff=6144,
    vocab_size=151_936, qk_norm=True, rope_theta=1_000_000.0,
    **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    qk_norm=True, **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="qwen3-1.7b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules={},
    notes="qk-norm per head before RoPE (Qwen3)")
