"""llama2-7b — the paper's own primary model (Tables 1, 2; Figs. 2, 5, 13).
32L d_model=4096 32H MHA d_ff=11008 vocab=32000.  [arXiv:2307.09288]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FULL_ATTN_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llama2-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=11_008,
    vocab_size=32_000, **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="llama2-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
    **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="llama2-7b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules={},
    notes="paper's primary experimental model")
