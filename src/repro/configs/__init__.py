"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (gemma_7b, hubert_xlarge, llama2_7b,
                           llava_next_mistral_7b, mixtral_8x22b,
                           moonshot_16b, qwen2_72b, qwen2_7b, qwen3_1_7b,
                           rwkv6_1_6b, zamba2_1_2b)
from repro.configs.base import LM_SHAPES, ArchBundle, ShapeSpec  # noqa: F401

ARCHS = {
    "qwen3-1.7b": qwen3_1_7b.BUNDLE,
    "qwen2-7b": qwen2_7b.BUNDLE,
    "qwen2-72b": qwen2_72b.BUNDLE,
    "gemma-7b": gemma_7b.BUNDLE,
    "moonshot-16b-a3b": moonshot_16b.BUNDLE,
    "mixtral-8x22b": mixtral_8x22b.BUNDLE,
    "rwkv6-1.6b": rwkv6_1_6b.BUNDLE,
    "hubert-xlarge": hubert_xlarge.BUNDLE,
    "llava-next-mistral-7b": llava_next_mistral_7b.BUNDLE,
    "zamba2-1.2b": zamba2_1_2b.BUNDLE,
    # the paper's own model (not part of the assigned 10)
    "llama2-7b": llama2_7b.BUNDLE,
}

ASSIGNED = [k for k in ARCHS if k != "llama2-7b"]


def get_arch(name: str) -> ArchBundle:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
