"""hubert-xlarge [audio] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504
— encoder-only; conv frontend is a STUB (input_specs provides frame
embeddings).  [arXiv:2106.07447]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, ENCODER_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="hubert-xlarge", family="encoder", num_layers=48, d_model=1280,
    num_heads=16, num_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    causal=False, mlp_glu=False, mlp_act="gelu", input_mode="embeddings",
    **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="hubert-smoke", family="encoder", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=504,
    causal=False, mlp_glu=False, mlp_act="gelu", input_mode="embeddings",
    **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="hubert-xlarge", full=FULL, smoke=SMOKE,
    skips={"decode_32k": ENCODER_SKIP, "long_500k": ENCODER_SKIP},
    rules={},
    notes="masked-prediction loss over 504 codebook classes; "
          "train/prefill shapes take (B, S, 1280) frame embeddings")
