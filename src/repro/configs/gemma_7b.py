"""gemma-7b [dense] 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000
— GeGLU, head_dim=256.  [arXiv:2403.08295; hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FULL_ATTN_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-7b", family="dense", num_layers=28, d_model=3072,
    num_heads=16, num_kv_heads=16, head_dim=256, d_ff=24_576,
    vocab_size=256_000, mlp_act="gelu", tie_embeddings=True,
    scale_embeddings=True, **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense", num_layers=2, d_model=48,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
    mlp_act="gelu", tie_embeddings=True, scale_embeddings=True,
    **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="gemma-7b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules={},
    notes="GeGLU MLP, head_dim=256 (q_dim 4096 > d_model), tied+scaled "
          "embeddings, 256k vocab -> chunked CE is essential")
