"""Config system: architecture bundles (full + smoke + shapes + sharding)."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchBundle:
    name: str
    full: ModelConfig
    smoke: ModelConfig
    skips: dict            # shape_name -> reason (documented in DESIGN.md)
    rules: dict            # sharding-rule overrides (e.g. FSDP)
    notes: str = ""

    def shapes(self):
        return {k: v for k, v in LM_SHAPES.items() if k not in self.skips}


# dry-run numerics: bf16 params/compute, remat, streaming attention + loss
DRYRUN_OPTS = dict(
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat=True,
    scan_layers=True,
    attn_chunk=1024,
    loss_chunk=512,
)

# reduced smoke numerics: tiny fp32, naive attention
SMOKE_OPTS = dict(
    param_dtype="float32",
    compute_dtype="float32",
    remat=False,
    scan_layers=True,
)

FSDP_RULES = {"embed": ("data",)}   # ZeRO-3-style weight sharding over data

FULL_ATTN_SKIP = ("pure full-attention architecture: 500k decode KV cache "
                  "is quadratic-history; assignment says skip")
ENCODER_SKIP = "encoder-only architecture: no autoregressive decode step"
