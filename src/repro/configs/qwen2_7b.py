"""qwen2-7b [dense] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias.  [arXiv:2407.10671; hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FULL_ATTN_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-7b", family="dense", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, head_dim=128, d_ff=18_944,
    vocab_size=152_064, qkv_bias=True, rope_theta=1_000_000.0,
    **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="qwen2-smoke", family="dense", num_layers=2, d_model=56,
    num_heads=7, num_kv_heads=1, head_dim=8, d_ff=128, vocab_size=128,
    qkv_bias=True, **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="qwen2-7b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules={},
    notes="28 q-heads / 4 kv-heads do not divide TP=16: XLA pads the "
          "q-head dim (28->32) and KV projections are replicated "
          "(DESIGN.md §3)")
