"""rwkv6-1.6b [ssm] 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536
— Finch, data-dependent decay.  [arXiv:2404.05892]"""
from repro.configs.base import ArchBundle, DRYRUN_OPTS, SMOKE_OPTS
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="rwkv6-1.6b", family="rwkv6", num_layers=24, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64, d_ff=7168,
    vocab_size=65_536, rwkv_head_dim=64, rwkv_decay_lora=64,
    rwkv_mix_lora=32, ssm_chunk=64, **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="rwkv6", num_layers=2, d_model=64,
    num_heads=8, num_kv_heads=8, head_dim=8, d_ff=128, vocab_size=128,
    rwkv_head_dim=8, rwkv_decay_lora=8, rwkv_mix_lora=4, ssm_chunk=8,
    **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="rwkv6-1.6b", full=FULL, smoke=SMOKE,
    skips={}, rules={},
    notes="attention-free: O(1) decode state -> long_500k runs; LIFT "
          "applies to all time/channel-mix projections (decay-LoRA "
          "vectors excluded, DESIGN.md §8)")
