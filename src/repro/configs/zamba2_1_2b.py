"""zamba2-1.2b [hybrid] 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchBundle, DRYRUN_OPTS, SMOKE_OPTS
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=128, d_ff=8192,
    vocab_size=32_000, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    ssm_conv=4, ssm_chunk=64, shared_attn_period=6,
    **{**DRYRUN_OPTS, "scan_layers": False})

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=128,
    ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=8,
    shared_attn_period=2, **{**SMOKE_OPTS, "scan_layers": False})

BUNDLE = ArchBundle(
    name="zamba2-1.2b", full=FULL, smoke=SMOKE,
    skips={}, rules={},
    notes="shared attention block every 6 mamba layers on "
          "concat(hidden, embeddings) width 2*d_model (32H x 128 = 4096); "
          "O(1) mamba state -> long_500k runs (shared-block KV caches are "
          "sequence-sharded)")
