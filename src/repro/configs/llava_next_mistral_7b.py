"""llava-next-mistral-7b [vlm] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000 — anyres tiling; vision frontend is a STUB (input_specs
provides patch embeddings).  [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FULL_ATTN_SKIP,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b", family="dense", num_layers=32,
    d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14_336,
    vocab_size=32_000, input_mode="embeddings", **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="llava-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    input_mode="embeddings", **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="llava-next-mistral-7b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules={},
    notes="Mistral-7B backbone; train/prefill consume pre-projected "
          "patch+text embeddings (anyres tiling happens in the stub), "
          "decode is standard token decode")
