"""qwen2-72b [dense] 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA, QKV bias.  [arXiv:2407.10671; hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FSDP_RULES,
                                FULL_ATTN_SKIP, SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, head_dim=128, d_ff=29_568,
    vocab_size=152_064, qkv_bias=True, rope_theta=1_000_000.0,
    **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke", family="dense", num_layers=3, d_model=64,
    num_heads=8, num_kv_heads=1, head_dim=8, d_ff=160, vocab_size=128,
    qkv_bias=True, **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="qwen2-72b", full=FULL, smoke=SMOKE,
    skips={"long_500k": FULL_ATTN_SKIP}, rules=FSDP_RULES,
    notes="145 GB of bf16 params: FSDP(embed->data) x TP(model) sharding")
