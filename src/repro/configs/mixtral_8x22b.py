"""mixtral-8x22b [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA.  [arXiv:2401.04088; hf]"""
from repro.configs.base import (ArchBundle, DRYRUN_OPTS, FSDP_RULES,
                                SMOKE_OPTS)
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16_384,
    vocab_size=32_768, num_experts=8, num_experts_per_tok=2,
    sliding_window=4096, capacity_factor=1.25, moe_groups=16,
    rope_theta=1_000_000.0, **DRYRUN_OPTS)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=128,
    num_experts=4, num_experts_per_tok=2, sliding_window=16,
    capacity_factor=2.0, **SMOKE_OPTS)

BUNDLE = ArchBundle(
    name="mixtral-8x22b", full=FULL, smoke=SMOKE,
    skips={},
    # 8 experts < TP=16: expert-parallelism cannot use the whole model axis,
    # so experts replicate over the axis name and instead shard d (over
    # data, FSDP) x d_ff (over model) — tensor-parallel experts.
    rules={**FSDP_RULES, "experts": (), "expert_mlp": ("model",)},
    notes="SWA window 4096 -> long_500k decode runs with a rolling-buffer "
          "cache (4096 slots, key_pos disambiguation) — sub-quadratic "
          "history, so the 500k cell is IN scope. 281 GB bf16 params: "
          "FSDP x TP expert sharding (E=8 < TP=16 rules out pure EP)")
