"""Quantized-base serving: int8 resident weights + principal overlay.

DESIGN.md §12.  `quantize.quantize` converts a dense checkpoint into a
`pack.QuantArtifact` (int8 base + O(k) high-precision overlay of the
top-density principal weights and super-weight outliers);
`QuantArtifact.to_params` swaps planned dense leaves for the
quantized-operand dicts `kernels.ops.overlay_matmul` consumes.
"""
from repro.quant.pack import (QUANT_FORMAT_VERSION,  # noqa: F401
                              SUPPORTED_QUANT_VERSIONS, QuantArtifact)
from repro.quant.quantize import (QuantConfig, hbm_bytes_ratio,  # noqa: F401
                                  lift_config, quantize)
