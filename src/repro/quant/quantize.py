"""Dense checkpoint -> int8 base + high-precision principal overlay.

LIFT's serving-side corollary (PAPER.md, DESIGN.md §12): if the top ~5 %
principal weights after rank reduction carry the reasoning signal, the
other 95 % can sit in HBM at int8 while the principal entries — plus the
super-weight outliers that must never be degraded ("Super Weights in
LLMs", PAPERS.md) — ride in a full-precision O(k) (idx, val) overlay.

Per planned tensor (geometry from `core.lift.make_plan`, the same plan
that drives training-time selection and delta extraction):

  1. score each (rows, cols) matrix with `core.lift.scores_for` —
     default rank-`rank` LIFT scores |A Bᵀ|;
  2. force super-weights in: any entry with |w| > superw_sigma * std(w)
     gets score +inf, so outlier columns can never be quantized away
     regardless of what the low-rank scores say (benchmarks/
     fig_super_weights.py asserts they survive scoring alone too);
  3. `topk_indices` -> sorted flat idx; overlay values are the ORIGINAL
     entries, bitwise (mode-"replace" DeltaHub semantics);
  4. the whole matrix quantizes to int8 with per-tensor or per-channel
     (per output column) absmax/127 scales.  Principal positions are
     quantized too — harmless, since the overlay scatter replaces them
     at apply time — which keeps q a plain dense int8 image.

Everything is host-side numpy except scoring, which runs through the
same jax pipeline training uses (so the selected sets line up with
figures 17/…).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import (LiftConfig, get_by_path, make_plan, scores_for,
                             topk_indices)
from repro.deltas.format import tree_hash
from repro.quant.pack import QuantArtifact, make_manifest


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    scale_mode: str = "per-channel"   # per-tensor | per-channel
    density: float = 0.05             # overlay density (paper's top-5 %)
    rank: int = 32                    # rank-reduction rank for scoring
    selection: str = "lift"           # lift | magnitude (scores_for)
    superw_sigma: float = 6.0         # |w| > sigma*std forced into overlay
    min_dim: int = 16                 # plan floor (smoke configs are small)
    method: str = "exact"             # lowrank method for scoring

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def lift_config(cfg: QuantConfig) -> LiftConfig:
    """The LiftConfig equivalent — one geometry pipeline, not two."""
    return LiftConfig(rank=cfg.rank, density=cfg.density, method=cfg.method,
                      selection=cfg.selection, min_dim=cfg.min_dim)


def _scale(w2d: np.ndarray, mode: str) -> np.ndarray:
    """absmax/127 scale, (1, 1) per-tensor or (1, cols) per-channel.
    All-zero slices get scale 1.0 so dequant stays finite."""
    if mode == "per-tensor":
        absmax = np.max(np.abs(w2d), keepdims=True).reshape(1, 1)
    else:
        absmax = np.max(np.abs(w2d), axis=0, keepdims=True)
    scale = absmax.astype(np.float32) / 127.0
    return np.where(scale > 0.0, scale, np.float32(1.0))


def quantize_matrix(w2d: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return np.clip(np.rint(w2d.astype(np.float32) / scale),
                   -127, 127).astype(np.int8)


def principal_indices(w2d: jax.Array, lcfg: LiftConfig, k: int,
                      superw_sigma: float,
                      key: Optional[jax.Array] = None) -> np.ndarray:
    """Sorted flat top-k indices with the super-weight guard applied."""
    wf = w2d.astype(jnp.float32)
    scores = scores_for(wf, lcfg, lcfg.selection, key)
    if superw_sigma > 0:
        guard = jnp.abs(wf) > superw_sigma * jnp.std(wf)
        scores = jnp.where(guard, jnp.inf, scores)
    return np.asarray(topk_indices(scores, k), np.int32)


def quantize(model, params, cfg: QuantConfig,
             key: Optional[jax.Array] = None) -> QuantArtifact:
    """Convert `params` (the dense checkpoint of `model`) into a
    `QuantArtifact`: int8 base + principal overlay per planned tensor."""
    if key is None:
        key = jax.random.PRNGKey(0)
    lcfg = lift_config(cfg)
    plan = make_plan(model.spec(), lcfg)
    if not plan:
        raise ValueError(
            "quantization plan is empty — every tensor fell below "
            f"min_dim={cfg.min_dim}; nothing to quantize")
    base_hash = tree_hash(params)

    tensors = {}
    tensors_meta = {}
    for path in sorted(plan):
        tp = plan[path]
        leaf = np.asarray(get_by_path(params, path))
        ns = int(np.prod(tp.stack)) if tp.stack else 1
        w3 = leaf.reshape(ns, tp.rows, tp.cols)
        scol = 1 if cfg.scale_mode == "per-tensor" else tp.cols
        q = np.empty((ns, tp.rows, tp.cols), np.int8)
        scale = np.empty((ns, 1, scol), np.float32)
        idx = np.empty((ns, tp.k), np.int32)
        val = np.empty((ns, tp.k), leaf.dtype)
        for s in range(ns):
            key, sub = jax.random.split(key)
            w2d = w3[s]
            fi = principal_indices(jnp.asarray(w2d), lcfg, tp.k,
                                   cfg.superw_sigma, sub)
            sc = _scale(w2d, cfg.scale_mode)
            q[s] = quantize_matrix(w2d, sc)
            scale[s] = sc
            idx[s] = fi
            val[s] = w2d.reshape(-1)[fi]
        tensors[path] = {"q": q, "scale": scale, "idx": idx, "val": val}
        tensors_meta[path] = {
            "shape": list(tp.shape), "stack": list(tp.stack),
            "rows": tp.rows, "cols": tp.cols, "k": tp.k,
            "dtype": str(leaf.dtype), "value_dtype": str(val.dtype),
        }

    manifest = make_manifest(
        base_hash=base_hash, scale_mode=cfg.scale_mode, density=cfg.density,
        rank=cfg.rank, selection=cfg.selection, superw_sigma=cfg.superw_sigma,
        tensors_meta=tensors_meta)
    return QuantArtifact(manifest=manifest, tensors=tensors)


def hbm_bytes_ratio(artifact: QuantArtifact) -> float:
    """Resident bytes of the quantized planned tensors vs dense."""
    return artifact.resident_nbytes() / artifact.dense_nbytes()
