"""Quantized-base artifact layout (DESIGN.md §12).

The unit `quant/quantize.py` produces is a **quantized-base artifact**:
per planned tensor, the int8 base plus the high-precision principal
overlay —

    quant.json          manifest (see below)
    arrays.npz          "<path>\\x1fq"     int8  (ns, rows, cols)
                        "<path>\\x1fscale" f32   (ns, 1, cols) | (ns, 1, 1)
                        "<path>\\x1fidx"   int32 (ns, k) sorted flat
                        "<path>\\x1fval"   value_dtype (ns, k)

The (idx, val) half IS the DeltaHub index machinery (`deltas/format.py`):
row-major flat replace indices into the (rows, cols) matrix, sorted
ascending, exactly the geometry `DeltaMerger`/`PoolLayout` consume — the
overlay is an O(k) sparse artifact holding the top-density principal
weights (and super-weight outliers) at full precision, while everything
else rides as int8 `q * scale`.

Manifest fields mirror the delta manifest's refusal machinery:
  * format_version — QUANT_FORMAT_VERSION; a reader refuses anything it
    does not support, exactly like `DeltaArtifact`;
  * base_hash — `deltas.format.tree_hash` of the dense base the artifact
    was quantized from: `to_params` REFUSES any other base
    (`DeltaMismatchError`), because the overlay values are entries of
    that specific checkpoint;
  * scale_mode / density / rank / selection / superw_sigma — the
    producing `QuantConfig`, pinned for reproducibility;
  * tensors — {path: {shape, stack, rows, cols, k, dtype, value_dtype}}.

`to_params` swaps each planned dense leaf for the quantized-operand
dict {"q", "scale", "idx", "val"} with a leading layer axis — the form
`kernels.ops.overlay_matmul` dispatches on and `LM._scan_serve` slices
per layer (every leaf leads with the stack dim, so `jax.lax.scan` works
unchanged).
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core.lift import get_by_path, set_by_path
from repro.deltas.format import DeltaMismatchError, tree_hash

QUANT_FORMAT_VERSION = 1
SUPPORTED_QUANT_VERSIONS = (1,)
MANIFEST_NAME = "quant.json"
ARRAYS_NAME = "arrays.npz"
SCALE_MODES = ("per-tensor", "per-channel")

_PARTS = ("q", "scale", "idx", "val")


def num_stack(meta: dict) -> int:
    return int(np.prod(meta["stack"])) if meta["stack"] else 1


def make_manifest(*, base_hash: str, scale_mode: str, density: float,
                  rank: int, selection: str, superw_sigma: float,
                  tensors_meta: dict) -> dict:
    if scale_mode not in SCALE_MODES:
        raise ValueError(f"unknown scale_mode {scale_mode!r} "
                         f"(want one of {SCALE_MODES})")
    return {
        "format_version": QUANT_FORMAT_VERSION,
        "kind": "quant-base",
        "base_hash": base_hash,
        "scale_mode": scale_mode,
        "density": float(density),
        "rank": int(rank),
        "selection": selection,
        "superw_sigma": float(superw_sigma),
        "tensors": {p: dict(m) for p, m in sorted(tensors_meta.items())},
    }


@dataclasses.dataclass
class QuantArtifact:
    """manifest + {path: {"q", "scale", "idx", "val"}} numpy arrays."""
    manifest: dict
    tensors: dict

    # ------------------------------------------------------------- sizes
    def resident_nbytes(self) -> int:
        """Device bytes the quantized planned tensors cost resident."""
        return int(sum(arr.nbytes for t in self.tensors.values()
                       for arr in t.values()))

    def dense_nbytes(self) -> int:
        """Bytes the same tensors cost dense at their original dtype."""
        total = 0
        for m in self.manifest["tensors"].values():
            total += int(np.prod(m["shape"])) * np.dtype(m["dtype"]).itemsize
        return total

    def nbytes(self) -> int:
        return self.resident_nbytes()

    # ------------------------------------------------------------- disk
    def save(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)
        with open(os.path.join(path, MANIFEST_NAME), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        arrays = {f"{p}\x1f{part}": np.asarray(t[part])
                  for p, t in self.tensors.items() for part in _PARTS}
        np.savez(os.path.join(path, ARRAYS_NAME), **arrays)

    @classmethod
    def load(cls, path: str) -> "QuantArtifact":
        with open(os.path.join(path, MANIFEST_NAME)) as f:
            manifest = json.load(f)
        ver = manifest.get("format_version")
        if ver not in SUPPORTED_QUANT_VERSIONS:
            raise DeltaMismatchError(
                f"quant artifact at {path} has format_version {ver!r}; "
                f"this reader supports {SUPPORTED_QUANT_VERSIONS}")
        tensors: dict = {}
        with np.load(os.path.join(path, ARRAYS_NAME)) as z:
            for key in z.files:
                p, part = key.rsplit("\x1f", 1)
                tensors.setdefault(p, {})[part] = z[key]
        want = set(manifest["tensors"])
        got = set(tensors)
        if want != got:
            raise DeltaMismatchError(
                f"quant artifact tensor set mismatch: manifest has "
                f"{sorted(want)}, arrays have {sorted(got)}")
        for p, t in tensors.items():
            missing = [part for part in _PARTS if part not in t]
            if missing:
                raise DeltaMismatchError(
                    f"quant artifact tensor {p!r} is missing array "
                    f"part(s) {missing}")
        return cls(manifest=manifest, tensors=tensors)

    # ---------------------------------------------------------- refusals
    def validate_base(self, base_params) -> None:
        """Refuse application to any base but the quantized one."""
        got = tree_hash(base_params)
        want = self.manifest["base_hash"]
        if got != want:
            raise DeltaMismatchError(
                f"quant artifact was produced from base {want[:12]}… but "
                f"application was attempted on base {got[:12]}… — the "
                f"overlay values belong to the original checkpoint")

    # ------------------------------------------------------ params tree
    def to_params(self, base_params, *, validate: bool = True):
        """Swap each planned dense leaf for its quantized-operand dict.

        Leaves keep a leading stack (layer) axis — q (L, rows, cols)
        int8, scale (L, 1, cols)/(L, 1, 1) f32, idx (L, k) int32,
        val (L, k) — so `jax.lax.scan` over `params["blocks"]` slices
        them per layer untouched.  Unplanned leaves (embeddings, norms,
        biases) pass through dense."""
        if validate:
            self.validate_base(base_params)
        out = base_params
        for p in sorted(self.tensors):
            m = self.manifest["tensors"][p]
            t = self.tensors[p]
            stack = tuple(m["stack"])
            rows, cols = int(m["rows"]), int(m["cols"])
            k = int(m["k"])
            scol = 1 if self.manifest["scale_mode"] == "per-tensor" else cols
            leaf = {
                "q": jnp.asarray(t["q"]).reshape(stack + (rows, cols)),
                "scale": jnp.asarray(t["scale"], jnp.float32).reshape(
                    stack + (1, scol)),
                "idx": jnp.asarray(t["idx"], jnp.int32).reshape(
                    stack + (k,)),
                "val": jnp.asarray(t["val"]).reshape(stack + (k,)),
            }
            out = set_by_path(out, p, leaf)
        return out

    def check_against(self, base_params) -> None:
        """Sanity check: every overlay value equals the base entry it
        covers (mode-"replace" semantics of the principal overlay)."""
        for p in sorted(self.tensors):
            m = self.manifest["tensors"][p]
            ns = num_stack(m)
            base = np.asarray(get_by_path(base_params, p)).reshape(
                ns, m["rows"] * m["cols"])
            idx = np.asarray(self.tensors[p]["idx"]).reshape(ns, m["k"])
            val = np.asarray(self.tensors[p]["val"]).reshape(ns, m["k"])
            want = np.take_along_axis(base, idx, axis=1)
            if not np.array_equal(
                    want.astype(val.dtype).astype(np.float32),
                    val.astype(np.float32)):
                raise DeltaMismatchError(
                    f"quant overlay values for {p!r} do not match the "
                    f"base entries they cover")
