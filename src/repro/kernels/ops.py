"""jit'd wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (the kernels execute via the Pallas
interpreter on CPU for correctness); on TPU backends the compiled kernels
run natively.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import lowrank_mask as lrm
from repro.kernels import sparse_adam as sak


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ lowrank ops
@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lowrank_abs(a, b, bm: int = 256, bn: int = 256,
                interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return lrm.lowrank_stat(a, b, "abs", bm=bm, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bs", "interpret"))
def lowrank_count(a, b, tau, bm: int = 256, bn: int = 256, bs: int = 1,
                  interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    parts = lrm.lowrank_stat(a, b, "count", tau=tau, bm=bm, bn=bn, bs=bs,
                             interpret=interpret)
    return jnp.sum(parts)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bs", "interpret"))
def lowrank_absmax(a, b, bm: int = 256, bn: int = 256, bs: int = 1,
                   interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    parts = lrm.lowrank_stat(a, b, "absmax", bm=bm, bn=bn, bs=bs,
                             interpret=interpret)
    return jnp.max(parts)


@functools.partial(jax.jit,
                   static_argnames=("nbins", "bm", "bn", "bs", "interpret"))
def lowrank_hist(a, b, lo, hi, nbins: int = 512, bm: int = 256, bn: int = 256,
                 bs: int = 1, interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    parts = lrm.lowrank_stat(a, b, "hist", lo=lo, hi=hi, nbins=nbins,
                             bm=bm, bn=bn, bs=bs, interpret=interpret)
    return jnp.sum(parts, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("k", "passes", "nbins", "bm", "bn",
                                    "block_size", "interpret"))
def lift_threshold(a, b, k: int, passes: int = 2, nbins: int = 512,
                   bm: int = 256, bn: int = 256, block_size: int = 1,
                   interpret: Optional[bool] = None):
    """Threshold tau s.t. count(score > tau) ~= k (within the final bin),
    where score is |A B^T| for block_size == 1 and the (bs x bs)
    block-summed |A B^T| for structured LIFT — `k` then counts BLOCKS.

    Multi-pass histogram refinement: W' never materializes in HBM.
    """
    return _lift_threshold_lohi(a, b, k, passes, nbins, bm, bn, interpret,
                                block_size)[0]


def hist_refine(hist, k: int, lo, hi, nbins: int):
    """One histogram-refinement step of the threshold binary search:
    narrow (lo, hi) to the single bin whose lower edge keeps >= k entries
    above it.  `hist` may be a single-device histogram or the psum of
    per-shard histograms — the search only sees the (nbins,) counts, which
    is what makes the sharded threshold search bitwise-identical to the
    single-device one (integer counts are exact under any reduction
    order)."""
    # count of entries strictly above each bin's lower edge
    above = jnp.cumsum(hist[::-1])[::-1]          # above[i] = sum(hist[i:])
    # smallest bin whose lower edge keeps >= k entries above it
    ok = above >= k
    j = jnp.maximum(jnp.sum(ok) - 1, 0)           # last True index
    width = (hi - lo) / nbins
    new_lo = lo + j * width
    return new_lo, new_lo + width


def tau_from_lohi(lo, hi):
    """Back off one final-bin width: the histogram counts bin membership
    (>= lo) while the compact kernel compares strictly (> tau), and the
    bin-id rounding can disagree with the direct comparison by a few ulps
    — a full bin below lo re-covers every counted entry, adding only
    final-bin ties that the sort+truncate drops again.  The bin width can
    underflow to 0 in f32 once the passes exhaust the mantissa, so floor
    the backoff at ~8 ulp of lo."""
    width = jnp.maximum(hi - lo, jnp.abs(lo) * 1e-6)
    return jnp.maximum(lo - width, 0.0)


def _lift_threshold_lohi(a, b, k: int, passes: int = 2, nbins: int = 512,
                         bm: int = 256, bn: int = 256,
                         interpret: Optional[bool] = None,
                         block_size: int = 1):
    """(lo, hi) of the final histogram bin: count(>= lo) >= k > count(>= hi)
    up to histogram-binning float rounding (one bin width).  With
    `block_size` > 1 the counted population is block-summed scores and
    `k` counts blocks."""
    interpret = _default_interpret() if interpret is None else interpret
    lo = jnp.float32(0.0)
    hi = lowrank_absmax(a, b, bm, bn, block_size, interpret) * (1 + 1e-6)
    for _ in range(passes):
        hist = lowrank_hist(a, b, lo, hi, nbins, bm, bn, block_size,
                            interpret)
        lo, hi = hist_refine(hist, k, lo, hi, nbins)
    return lo, hi


@functools.partial(jax.jit,
                   static_argnames=("k", "passes", "nbins", "bm", "bn",
                                    "interpret"))
def lift_mask(a, b, k: int, passes: int = 2, nbins: int = 512,
              bm: int = 256, bn: int = 256,
              interpret: Optional[bool] = None):
    """(mask (m, n) bool, tau) with count(mask) in [k, k + final-bin-ties)."""
    interpret = _default_interpret() if interpret is None else interpret
    tau = lift_threshold(a, b, k, passes, nbins, bm, bn,
                         interpret=interpret)
    mask = lrm.lowrank_stat(a, b, "mask", tau=tau, bm=bm, bn=bn,
                            interpret=interpret)
    return mask, tau


def pick_block(dim: int, target: int = 256, multiple: int = 1) -> int:
    """Largest divisor of `dim` in [16, target] (the Pallas grid needs
    exact tiling).  Model matrix dims are overwhelmingly
    power-of-two-ish, so this lands on `target` or close; a dim with no
    usable divisor (prime / awkward odd) gets one full-dim tile rather
    than a degenerate per-element grid.  `multiple` additionally
    constrains the tile to a multiple of the structured block size, so
    block-summed tiles never straddle a (bs x bs) block boundary (the
    caller guarantees dim % multiple == 0)."""
    if dim <= target:
        return dim
    lo = max(16, multiple)
    for c in range(target, lo - 1, -1):
        if dim % c == 0 and c % multiple == 0:
            return c
    return dim


def select_tiling(m: int, n: int, k: int, block_size: int = 1,
                  bm: int = 256, bn: int = 256,
                  factor: int = 8) -> tuple:
    """(bm, bn, capacity) the streaming selection pipeline will use for a
    (m, n) matrix selecting k entries: element-space tiles aligned to
    `block_size`, compaction capacity in score-unit slots (elements for
    block_size == 1, blocks otherwise).  The ONE place this arithmetic
    lives — `_lift_indices_body` defaults and the SelectionEngine's
    explicit capacities both call it, so single-device, per-slab local
    and collective paths stay bitwise-comparable."""
    bs = block_size
    bm0, bn0 = min(bm, m), min(bn, n)
    if m % bm0 or n % bn0 or bm0 % bs or bn0 % bs:
        bm, bn = pick_block(m, bm, bs), pick_block(n, bn, bs)
        bm0, bn0 = min(bm, m), min(bn, n)
    cap = compact_capacity(m // bs, n // bs, k // (bs * bs),
                           bm0 // bs, bn0 // bs, factor)
    if bs > 1:
        # the kernel clamps its buffer to the unit tile size; mirror it so
        # the caller's stored/overflow arithmetic sees the same slot count
        cap = min(cap, (bm0 // bs) * (bn0 // bs))
    return bm, bn, cap


def compact_capacity(m: int, n: int, k: int, bm: int, bn: int,
                     factor: int = 8) -> int:
    """Per-tile slot budget for the compaction kernel.

    `factor` x the uniform per-tile share of k, rounded up to a lane
    multiple (128) and clamped to the tile size — so tiles*capacity >= k
    always holds and the candidate buffer stays O(k), never O(m*n)."""
    bm, bn = min(bm, m), min(bn, n)
    tiles = (m // bm) * (n // bn)
    per_tile = -(-k // max(tiles, 1))
    cap = -(-(factor * per_tile) // 128) * 128
    return int(max(128, min(cap, bm * bn)))


@functools.partial(jax.jit,
                   static_argnames=("capacity", "bm", "bn", "bs",
                                    "interpret"))
def lowrank_compact(a, b, tau, capacity: int = 1024,
                    bm: int = 256, bn: int = 256, bs: int = 1,
                    interpret: Optional[bool] = None):
    """Per-tile compacted flat indices of |A B^T| > tau (+ per-tile
    counts).  `bs` > 1 compacts flat BLOCK indices of the block-summed
    scores instead (row-major into the (m/bs, n/bs) block matrix,
    `capacity` in block slots) — the one compaction dispatch every
    streaming path goes through."""
    interpret = _default_interpret() if interpret is None else interpret
    return lrm.lowrank_stat(a, b, "compact", tau=tau, capacity=capacity,
                            bm=bm, bn=bn, bs=bs, interpret=interpret)


def expand_block_indices(bidx, n_block_cols: int, n_cols: int, bs: int):
    """Sorted flat ELEMENT indices of the (bs x bs) blocks named by the
    flat block indices `bidx` (..., kb) — the one expansion both the
    streaming paths and the dense `lift.topk_indices` block path share,
    so their output ordering is identical.  O(kb * bs^2), never O(m*n).
    Pad/duplicate block entries (degraded masks) expand like real ones —
    still in-range."""
    br, bc = bidx // n_block_cols, bidx % n_block_cols
    rr = br[..., None, None] * bs + jnp.arange(bs)[None, :, None]
    cc = bc[..., None, None] * bs + jnp.arange(bs)[None, None, :]
    flat = (rr * n_cols + cc).reshape(bidx.shape[:-1] + (-1,))
    return jnp.sort(flat, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("k", "passes", "nbins", "capacity",
                                    "bm", "bn", "block_size", "interpret"))
def lift_indices(a, b, k: int, passes: int = 3, nbins: int = 512,
                 capacity: int = 0, bm: int = 256, bn: int = 256,
                 block_size: int = 1,
                 interpret: Optional[bool] = None):
    """Streaming Principal-Weight selection: exactly-k sorted flat indices
    of the top-|A B^T| entries, without ever materializing the (m, n)
    score matrix (the SelectionEngine fast path).

    Three fused stages, all O(k)-sized outputs:
      1. `lift_threshold` — multi-pass histogram search for tau with
         count(|W'| > tau) in [k, k + final-bin ties);
      2. "compact" kernel — per-tile above-tau indices, left-packed into
         `capacity` slots (0 -> heuristic via `select_tiling`);
      3. one sort over the tiles*capacity candidate buffer; sentinel
         padding sinks to the end, truncate to k.

    `block_size` > 1 runs structured LIFT (paper App. G.7) through the
    SAME three stages at block granularity: the kernels block-sum each
    tile's scores in VMEM, the threshold search and compaction operate on
    the (m/bs, n/bs) block-score space for k/bs^2 blocks, and the
    selected block indices expand to their bs^2 member elements at the
    end (`expand_block_indices`) — neither W', the score matrix, nor the
    block-score matrix ever reaches HBM, exactly as for block_size == 1.

    Ties inside the final histogram bin are broken by LOWEST flat index
    (dense `top_k` breaks by highest score then lowest index), so parity
    with the dense path is exact except among final-bin ties — tighten
    with more `passes`/`nbins`.

    Returns (idx (k,) int32 sorted ascending, tau f32, overflow i32) where
    overflow counts entries dropped by tiles whose above-tau population
    exceeded `capacity` (0 in healthy runs; raise `capacity` if not).
    Whenever fewer than k real candidates exist — capacity overflow, or
    the degenerate case count(>tau) < k (k larger than the number of
    nonzero scores) — the tail pads with slot positions [0, k), which are
    in-range but may duplicate selected indices; treat a nonzero overflow
    as a degraded mask, not a cosmetic stat.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return _lift_indices_body(a, b, k, passes, nbins, capacity, bm, bn,
                              interpret, block_size)


def _check_block_geometry(m: int, n: int, k: int, bs: int, what: str):
    if m % bs or n % bs:
        raise ValueError(
            f"structured {what} block_size={bs} does not tile the "
            f"(rows={m}, cols={n}) matrix — both dims must divide")
    if k % (bs * bs):
        raise ValueError(
            f"structured {what} needs k divisible by block_size^2: "
            f"k={k}, block_size={bs}")


def _lift_indices_body(a, b, k: int, passes: int, nbins: int, capacity: int,
                       bm: int, bn: int, interpret: bool,
                       block_size: int = 1):
    """Un-jitted `lift_indices` body, shared verbatim by the single-device,
    per-slab local-quota and shard_map'd collective entry points so their
    per-slab arithmetic is bit-identical.  All selection arithmetic runs
    in score UNITS (elements, or blocks for structured LIFT); only the
    final expansion returns to element space."""
    bs = block_size
    m, n = a.shape[0], b.shape[0]
    if bs > 1:
        _check_block_geometry(m, n, k, bs, "selection")
    ku = k // (bs * bs)                    # selection units (blocks)
    bm, bn, cap_default = select_tiling(m, n, k, bs, bm, bn)
    if capacity <= 0:
        capacity = cap_default
    elif bs > 1:
        capacity = min(capacity, (min(bm, m) // bs) * (min(bn, n) // bs))
    tiles_total = (m // min(bm, m)) * (n // min(bn, n))
    if tiles_total * capacity < ku:
        raise ValueError(
            f"compaction candidate buffer {tiles_total}x{capacity} < "
            f"k={ku} selection units")
    lo, hi = _lift_threshold_lohi(a, b, ku, passes, nbins, bm, bn,
                                  interpret, bs)
    tau = tau_from_lohi(lo, hi)
    tiles, counts = lowrank_compact(a, b, tau, capacity, bm, bn, bs,
                                    interpret)
    cand = jnp.sort(tiles.reshape(-1))
    # `stored`, not sum(counts): a tile whose above-tau population exceeds
    # capacity DROPS the excess, so the sorted buffer holds only
    # min(count, capacity) real entries per tile — guarding with the raw
    # total would hand sentinel padding out as selected indices.
    stored = jnp.sum(jnp.minimum(counts, capacity))
    slot = jnp.arange(ku, dtype=jnp.int32)
    idx = jnp.where(slot < stored, cand[:ku], slot)
    # re-sort: pad slots sort below real candidates, and downstream
    # consumers (moment remap, near-sequential scatter) require ascending
    # order; duplicates remain possible in the degraded case only.
    idx = jnp.sort(idx)
    overflow = jnp.sum(jnp.maximum(counts - capacity, 0))
    if bs > 1:
        idx = expand_block_indices(idx, n // bs, n, bs)
    return idx.astype(jnp.int32), tau, overflow


# ------------------------------------------------- sharded / local quota
def _slab_to_global(idx_local, cols_local: int, cols_global: int, col0):
    """Map local flat indices of a (rows, cols_local) column slab into the
    (rows, cols_global) matrix whose columns [col0, col0 + cols_local) the
    slab holds.  Sentinel entries stay sentinel.  Pad slots (positions
    [0, k) emitted by the degraded path) map like real indices — still
    in-range, preserving `lift_indices`' pad contract."""
    r = idx_local // cols_local
    c = idx_local % cols_local
    g = r * cols_global + col0 + c
    return jnp.where(idx_local == lrm.INT32_SENTINEL, lrm.INT32_SENTINEL,
                     g).astype(jnp.int32)


def shard_buffer_model(m: int, n: int, k: int, n_shards: int,
                       factor: int = 8) -> dict:
    """Modeled per-device candidate-buffer footprint of sharded streaming
    selection (benchmarks + DESIGN.md).  The compaction buffer is the only
    per-device intermediate that scales with k; everything else is O(tiles)
    counts or O(nbins) histograms.  Returns slot counts, bytes and the
    O(compact_factor * k / n_shards) bound it must respect."""
    nl = n // n_shards
    bm, bn = pick_block(m), pick_block(nl)
    kq = -(-k // n_shards)
    cap = compact_capacity(m, nl, kq, bm, bn, factor)
    tiles = (m // bm) * (nl // bn)
    buffer_slots = tiles * cap
    # compact_capacity rounds the per-tile budget up to a 128-lane multiple
    # and floors it at 128 slots, so the worst case is the exact
    # factor * kq share plus one lane-rounding per tile plus the floor.
    bound_slots = factor * kq + tiles * (128 + factor)
    return {
        "n_shards": n_shards, "tiles_per_device": tiles,
        "capacity_per_tile": cap,
        "buffer_slots_per_device": buffer_slots,
        "buffer_bytes_per_device": 4 * buffer_slots,
        "bound_slots_per_device": bound_slots,
        "within_bound": bool(buffer_slots <= bound_slots),
    }


@functools.partial(jax.jit,
                   static_argnames=("k", "n_shards", "passes", "nbins",
                                    "capacity", "bm", "bn", "block_size",
                                    "interpret"))
def lift_indices_local(a, b, k: int, n_shards: int, passes: int = 3,
                       nbins: int = 512, capacity: int = 0,
                       bm: int = 256, bn: int = 256, block_size: int = 1,
                       interpret: Optional[bool] = None):
    """Local-quota streaming selection on a single device (DESIGN.md §3
    "local" mode): the columns are split into `n_shards` slabs and each
    slab runs the full threshold+compaction pipeline for its exact
    k/n_shards quota — the streaming analogue of
    `core.local_quota.local_topk_indices`, and the single-device reference
    the shard_map'd collective path must match bitwise.  `block_size` > 1
    runs each slab's pipeline at block granularity (slab width and the
    per-slab quota must tile into bs / bs^2).

    Returns (idx (k,) int32 sorted ascending GLOBAL flat indices,
    tau (n_shards,) per-slab thresholds, overflow i32 total)."""
    interpret = _default_interpret() if interpret is None else interpret
    bs = block_size
    m, n = a.shape[0], b.shape[0]
    if n % n_shards or k % n_shards:
        raise ValueError(
            f"local-quota selection needs cols and k divisible by n_shards: "
            f"cols={n}, k={k}, n_shards={n_shards}")
    w = n // n_shards
    kq = k // n_shards
    if bs > 1:
        _check_block_geometry(m, w, kq, bs, "local-quota slab")
    slabs = b.reshape(n_shards, w, b.shape[1])
    col0 = jnp.arange(n_shards, dtype=jnp.int32) * w

    def one(args):
        b_slab, c0 = args
        idx_l, tau, ovf = _lift_indices_body(a, b_slab, kq, passes, nbins,
                                             capacity, bm, bn, interpret,
                                             bs)
        return _slab_to_global(idx_l, w, n, c0), tau, ovf

    g, taus, ovfs = jax.lax.map(one, (slabs, col0))
    return jnp.sort(g.reshape(-1)), taus, jnp.sum(ovfs)


def lift_indices_sharded(a, b_local, k: int, *, axis_name: str,
                         n_shards: int, cols_global: int,
                         quota: str = "global", passes: int = 3,
                         nbins: int = 512, capacity: int = 0,
                         compact_factor: int = 8,
                         bm: int = 256, bn: int = 256, block_size: int = 1,
                         interpret: Optional[bool] = None):
    """Collective streaming selection over column-slab-sharded factors.

    MUST run inside `shard_map` with `axis_name` bound: `a` is the
    replicated (rows, r) factor, `b_local` the shard's (cols/n_shards, r)
    slab of B — the shard's slice of where the weights live.  Neither W',
    the score matrix, nor a gathered B ever materializes; the only
    cross-shard traffic is O(nbins) histogram psums, one scalar pmax and
    one O(k)-entry all-gather of candidate indices.

    quota="global": per-shard histograms psum into the threshold search
    (bitwise-identical counts to the single-device search), each shard
    compacts its own above-tau candidates with an O(k / n_shards) buffer,
    and the merge is one all-gather + sort of the O(k) survivors —
    bitwise-identical indices to single-device `lift_indices` whenever no
    tile overflows its capacity.

    quota="local": no cross-shard reduction at all — each shard runs the
    exact-k/n_shards pipeline on its slab (bitwise-identical per slab to
    `lift_indices_local`); the single all-gather only assembles the (k,)
    output vector.

    `block_size` > 1 runs the whole collective at block granularity: the
    psum'd histograms count block-summed scores, each shard compacts its
    above-tau BLOCK indices (O(compact_factor * k / (bs^2 * n_shards))
    per-device buffer), the all-gather merges O(k/bs^2) block candidates,
    and the k-element expansion happens once on the replicated output.
    The shard's column slab must tile into blocks (cols/n_shards % bs
    == 0) — the engine falls back to the unsharded program otherwise.

    Returns (idx (k,) int32 sorted ascending GLOBAL flat indices,
    replicated; tau f32 — this shard's threshold under "local", the global
    threshold under "global"; overflow i32 summed over shards)."""
    interpret = _default_interpret() if interpret is None else interpret
    bs = block_size
    m, nl = a.shape[0], b_local.shape[0]
    shard = jax.lax.axis_index(axis_name)
    col0 = (shard * nl).astype(jnp.int32)

    if quota == "local":
        if k % n_shards:
            raise ValueError(
                f"local-quota selection needs k divisible by n_shards: "
                f"k={k}, n_shards={n_shards}")
        kq = k // n_shards
        if bs > 1:
            _check_block_geometry(m, nl, kq, bs, "local-quota slab")
        idx_l, tau, ovf = _lift_indices_body(a, b_local, kq, passes, nbins,
                                             capacity, bm, bn, interpret,
                                             bs)
        g = _slab_to_global(idx_l, nl, cols_global, col0)
        gall = jax.lax.all_gather(g, axis_name).reshape(-1)
        return (jnp.sort(gall), tau, jax.lax.psum(ovf, axis_name))
    if quota != "global":
        raise ValueError(f"unknown quota mode {quota!r}")

    if bs > 1:
        _check_block_geometry(m, nl, k, bs, "sharded-selection slab")
    ku = k // (bs * bs)                      # selection units (blocks)
    bm, bn, cap_default = select_tiling(m, nl, -(-ku // n_shards) * bs * bs,
                                        bs, bm, bn, compact_factor)
    if capacity <= 0:
        # per-shard slot budget sized by this shard's uniform share of k:
        # the whole candidate buffer stays O(compact_factor * k / n_shards)
        # units per device (shard_buffer_model documents the exact bound)
        capacity = cap_default
    elif bs > 1:
        capacity = min(capacity, (min(bm, m) // bs) * (min(bn, nl) // bs))
    tiles_local = (m // min(bm, m)) * (nl // min(bn, nl))
    if tiles_local * n_shards * capacity < ku:
        raise ValueError(
            f"sharded compaction candidate buffer "
            f"{n_shards}x{tiles_local}x{capacity} < k={ku} selection units")

    # global threshold search over psum'd per-shard histograms: the bin
    # counts (integers) are exact under any reduction order, so lo/hi/tau
    # match the single-device search bit for bit
    hi = jax.lax.pmax(lowrank_absmax(a, b_local, bm, bn, bs, interpret),
                      axis_name) * (1 + 1e-6)
    lo = jnp.float32(0.0)
    for _ in range(passes):
        hist = lowrank_hist(a, b_local, lo, hi, nbins, bm, bn, bs,
                            interpret)
        hist = jax.lax.psum(hist, axis_name)
        lo, hi = hist_refine(hist, ku, lo, hi, nbins)
    tau = tau_from_lohi(lo, hi)

    # shard-local compaction -> O(k) all-gather merge (never the scores);
    # for bs > 1 everything below runs in BLOCK index space until the
    # final expansion
    tiles, counts = lowrank_compact(a, b_local, tau, capacity, bm, bn, bs,
                                    interpret)
    g = _slab_to_global(tiles.reshape(-1), nl // bs, cols_global // bs,
                        (shard * (nl // bs)).astype(jnp.int32))
    cand = jnp.sort(jax.lax.all_gather(g, axis_name).reshape(-1))
    stored = jax.lax.psum(jnp.sum(jnp.minimum(counts, capacity)), axis_name)
    slot = jnp.arange(ku, dtype=jnp.int32)
    idx = jnp.sort(jnp.where(slot < stored, cand[:ku], slot))
    overflow = jax.lax.psum(jnp.sum(jnp.maximum(counts - capacity, 0)),
                            axis_name)
    if bs > 1:
        idx = expand_block_indices(idx, cols_global // bs, cols_global, bs)
    return idx.astype(jnp.int32), tau, overflow


# -------------------------------------------------------- paged attention
@functools.partial(jax.jit, static_argnames=("backend", "interpret",
                                             "window", "ring"))
def paged_attention_decode(q, k_pages, v_pages, block_tables, positions, *,
                           backend: str = "auto",
                           interpret: Optional[bool] = None,
                           window: Optional[int] = None,
                           ring: Optional[int] = None):
    """One-token decode attention over a block-paged KV pool.

    q: (B, H_kv, g, D) grouped queries (GQA groups folded, the cache is
    read at its native kv-head width); k_pages / v_pages: (P, ps, H_kv, D)
    shared page pool; block_tables: (B, nmax) int32 physical page of each
    logical page; positions: (B,) int32 — keys at logical token index
    <= positions[b] are attended, everything else masked.

    `window`/`ring` (STATIC, both or neither) select the sliding-window
    ring read: block tables are then indexed by RING index (logical page
    l lives at table column l % ring, slot-in-page unchanged) and keys at
    kpos <= positions[b] - window are masked off.  The lax path gathers
    into EXACTLY the dense rolling-buffer layout (`attention_decode`'s
    slot s holds position pos - ((pos - s) % window)) and runs the same
    grouped einsum, so ring decode stays bitwise-comparable to the dense
    rolling cache; stale ring cells fall outside the window mask by
    construction ((ring - 1) * ps >= window).

    backend:
      * "kernel" — the Pallas kernel (`paged_attention.paged_decode_fwd`):
        streams one physical page at a time, never materializes the
        gathered (B, nmax*ps) K/V;
      * "lax"    — pure-XLA fallback for non-Pallas backends: gathers the
        pages and runs EXACTLY the grouped-einsum read the dense engine's
        `attention_decode` uses (same equations, same shapes when
        nmax*ps == the dense cache length), so paged decode is
        bitwise-comparable to dense-cache decode;
      * "auto"   — kernel on TPU, lax elsewhere.

    Returns o: (B, H_kv, g, D).
    """
    interpret = _default_interpret() if interpret is None else interpret
    if (window is None) != (ring is None):
        raise ValueError("window and ring must be given together")
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "lax"
    if backend == "kernel":
        from repro.kernels import paged_attention as pak
        return pak.paged_decode_fwd(q, k_pages, v_pages, block_tables,
                                    positions, interpret=interpret,
                                    window=window, ring=ring)
    if backend != "lax":
        raise ValueError(f"unknown paged-attention backend {backend!r}")
    B, hkv, g, D = q.shape
    P, ps, _, _ = k_pages.shape
    nmax = block_tables.shape[1]
    if window is None:
        kc = k_pages[block_tables].reshape(B, nmax * ps, hkv, D)
        vc = v_pages[block_tables].reshape(B, nmax * ps, hkv, D)
        t = jnp.arange(nmax * ps)
        ok = t[None, :] <= positions[:, None]
    else:
        # dense rolling-buffer layout: slot s of a window-long buffer
        # holds the latest position congruent to s (mod window); gather
        # that position's ring cell per slot so the einsum below sees
        # the exact array the dense engine's attention_decode reads
        # (masked slots may gather garbage — the -1e30 bias zeroes them
        # exactly, scores being ~1e20 below the mask's absorption point)
        s_idx = jnp.arange(window)
        kp = positions[:, None] - ((positions[:, None] - s_idx[None, :])
                                   % window)                  # (B, W)
        kpc = jnp.maximum(kp, 0)
        col = (kpc // ps) % ring
        phys = jnp.take_along_axis(block_tables, col, axis=1)  # (B, W)
        kc = k_pages[phys, kpc % ps]                   # (B, W, hkv, D)
        vc = v_pages[phys, kpc % ps]
        ok = (kp >= 0) & (kp <= positions[:, None]) \
            & (kp > positions[:, None] - window)
    kc = kc.astype(q.dtype)
    vc = vc.astype(q.dtype)
    bias = jnp.where(ok, 0.0, -1e30)[:, None, None, None, :]  # (B,1,1,1,T)
    qg = q.reshape(B, 1, hkv, g, D)
    scale = D ** -0.5
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o[:, 0]                                  # (B, hkv, g, D)


@functools.partial(jax.jit, static_argnames=("backend", "interpret"))
def paged_attention_verify(q, k_pages, v_pages, block_tables, positions, *,
                           backend: str = "auto",
                           interpret: Optional[bool] = None):
    """Multi-token (speculative verify) decode attention over the pool.

    q: (B, n_q, H_kv, g, D) grouped queries for n_q CONSECUTIVE decode
    positions per sequence — the current token plus the drafted tokens,
    query i at logical position positions[b] + i attending keys at
    kpos <= positions[b] + i (each draft is blind to the drafts after
    it).  k_pages / v_pages / block_tables / positions are exactly
    `paged_attention_decode`'s.

    Both backends compute each query row with the SAME per-row equations
    as the one-token read — the lax path is the identical grouped einsum
    with the query axis widened from 1 to n_q, the kernel path the same
    online-softmax page walk with a per-row mask — so row i of a verify
    dispatch is bitwise-equal to the one-token dispatch that would run
    at position positions[b] + i over the same pages (the speculative
    engine's stream-identity guarantee rests on this; proven in
    tests/test_paged_kv.py).

    Returns o: (B, n_q, H_kv, g, D).
    """
    interpret = _default_interpret() if interpret is None else interpret
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "lax"
    if backend == "kernel":
        from repro.kernels import paged_attention as pak
        o = pak.paged_verify_fwd(
            jnp.moveaxis(q, 1, 2), k_pages, v_pages, block_tables,
            positions, interpret=interpret)
        return jnp.moveaxis(o, 2, 1)
    if backend != "lax":
        raise ValueError(f"unknown paged-attention backend {backend!r}")
    B, nq, hkv, g, D = q.shape
    P, ps, _, _ = k_pages.shape
    nmax = block_tables.shape[1]
    kc = k_pages[block_tables].reshape(B, nmax * ps, hkv, D).astype(q.dtype)
    vc = v_pages[block_tables].reshape(B, nmax * ps, hkv, D).astype(q.dtype)
    t = jnp.arange(nmax * ps)
    qpos = positions[:, None] + jnp.arange(nq)[None, :]       # (B, nq)
    ok = t[None, None, :] <= qpos[:, :, None]                 # (B, nq, T)
    bias = jnp.where(ok, 0.0, -1e30)[:, None, None, :, :]     # (B,1,1,q,T)
    scale = D ** -0.5
    s = jnp.einsum("bqhgd,bthd->bhgqt", q, kc,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32).astype(q.dtype)
    return o                                        # (B, nq, hkv, g, D)


# ---------------------------------------------------------- scatter merge
def _sorted_windows(idx, vals: tuple, nb: int, bn: int, capacity: int):
    """Per-(stack, block) dense windows of sorted (ns, k) index sets.

    The one implementation of the contiguous-window trick both sparse
    kernels rely on: entries of a sorted flat index vector that land in
    block b of a BN-blocked tensor occupy [starts[b], starts[b+1]), so a
    searchsorted + clamped gather turns O(k) ragged windows into dense
    (ns, nb, K) views.  `vals` is a tuple of (ns, k) arrays gathered into
    the same windows (f32, 0.0-padded); idxw pads with -1.  Sentinel
    entries (idx // bn >= nb) fall in no window.  Returns
    (idxw, tuple(valws), starts (ns, nb))."""
    ns, k = idx.shape
    block_of = idx // bn                                  # (ns, k)
    arangeb = jnp.arange(nb)
    starts = jax.vmap(
        lambda bo: jnp.searchsorted(bo, arangeb, side="left"))(block_of)
    ends = jax.vmap(
        lambda bo: jnp.searchsorted(bo, arangeb, side="right"))(block_of)
    gpos = starts[:, :, None] + jnp.arange(capacity)[None, None, :]
    in_win = gpos < ends[:, :, None]
    gposc = jnp.minimum(gpos, k - 1)

    def take(arr):  # (ns, k) gathered at (ns, nb, K) positions
        return jnp.take_along_axis(arr[:, None, :], gposc, axis=-1)

    idxw = jnp.where(in_win, take(idx), -1).astype(jnp.int32)
    valws = tuple(jnp.where(in_win, take(v), 0.0).astype(jnp.float32)
                  for v in vals)
    return idxw, valws, starts


@functools.partial(jax.jit, static_argnames=("mode", "bn", "capacity",
                                             "exact", "interpret"))
def sparse_scatter_merge(base, idx, val, *, mode: str = "replace",
                         bn: int = 2048, capacity: int = 0,
                         exact: bool = True,
                         interpret: Optional[bool] = None):
    """Fold batched sparse deltas into stacked flat base weights.

    base: (ns, N); idx: (ns, k) int32 sorted ascending per stack entry —
    entries >= N are sentinel pads and write nothing (the shard-local path
    marks foreign entries this way); val: (ns, k) in any float dtype.

    mode "replace" writes val at idx bitwise (the DeltaHub contract:
    base + replace-delta == fine-tuned checkpoint, bit for bit); mode
    "add" accumulates in fp32 and casts back.  `capacity` is the per-block
    window size (0 -> heuristic 4x mean occupancy); with exact=True an
    O(k) XLA fallback corrects any windows that overflowed, so results
    are exact regardless.  Returns (ns, N) in base dtype.
    """
    if mode not in ("replace", "add"):
        raise ValueError(f"unknown merge mode {mode!r}")
    interpret = _default_interpret() if interpret is None else interpret
    from repro.kernels import scatter_merge as smk
    ns, N = base.shape
    k = idx.shape[1]
    bn = min(bn, N)
    nb = max(1, -(-N // bn))
    padN = nb * bn
    base_pad = jnp.pad(base, ((0, 0), (0, padN - N)))

    if capacity <= 0:
        capacity = int(min(k, max(128, 4 * -(-k // nb))))
    idxw, (valw,), starts = _sorted_windows(idx, (val,), nb, bn, capacity)

    out = smk.scatter_merge_blocks(
        base_pad.reshape(ns, nb, bn), idxw, valw, bn=bn, mode=mode,
        interpret=interpret).reshape(ns, padN)

    if exact:
        # entries beyond their window's capacity (or sentinels, dropped by
        # the "drop" scatter mode) fall back to an O(k) XLA update
        j = jnp.arange(k)[None, :]
        block_of = jnp.clip(idx // bn, 0, nb - 1)
        slot = j - jnp.take_along_axis(starts, block_of, axis=-1)
        covered = (slot >= 0) & (slot < capacity) & (idx // bn < nb)

        def fix(o, i, c, v):
            if mode == "add":
                add = jnp.where(c, 0.0, v.astype(jnp.float32))
                return (o.astype(jnp.float32).at[i].add(add, mode="drop")
                        ).astype(o.dtype)
            cur = o.at[i].get(mode="fill", fill_value=0)
            return o.at[i].set(
                jnp.where(c, cur, v.astype(o.dtype)), mode="drop")

        out = jax.vmap(fix)(out, idx, covered, val)
    return out[:, :N]


def sparse_scatter_merge_sharded(base_local, idx, val, *, axis_name: str,
                                 n_shards: int, cols_global: int,
                                 mode: str = "replace", bn: int = 2048,
                                 interpret: Optional[bool] = None):
    """Shard-local scatter merge over column-slab-sharded base weights.

    MUST run inside `shard_map` with `axis_name` bound: `base_local` is
    this shard's (ns, rows, cols_global/n_shards) slab, `idx`/`val` the
    replicated (ns, k) GLOBAL flat delta.  Each shard keeps only the
    entries whose column lands in its slab, remaps them to local flat
    indices (the in-shard subsequence of a sorted global index set is
    itself sorted — global and local flat orders agree lexicographically
    on (row, col)) and scatters locally.  NO collectives: the merge needs
    zero cross-shard traffic, which is the whole point of shipping deltas
    as index+value pairs (DESIGN.md §4).
    """
    interpret = _default_interpret() if interpret is None else interpret
    from repro.kernels import lowrank_mask as lrm
    ns, rows, nl = base_local.shape
    shard = jax.lax.axis_index(axis_name)
    col0 = (shard * nl).astype(jnp.int32)

    r = idx // cols_global
    c = idx % cols_global - col0
    mine = (c >= 0) & (c < nl) & (idx < rows * cols_global)
    key = jnp.where(mine, r * nl + c, lrm.INT32_SENTINEL)
    order = jnp.argsort(key, axis=-1)                 # stable: stays sorted
    idx_l = jnp.take_along_axis(key, order, axis=-1).astype(jnp.int32)
    val_l = jnp.take_along_axis(val, order, axis=-1)
    return sparse_scatter_merge(
        base_local.reshape(ns, rows * nl), idx_l, val_l, mode=mode, bn=bn,
        interpret=interpret).reshape(ns, rows, nl)


# ----------------------------------------------------------- sparse adam
@functools.partial(jax.jit,
                   static_argnames=("bn", "capacity", "exact", "interpret"))
def sparse_adam(p, g, idx, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                wd=0.0, bn: int = 2048, capacity: int = 0,
                exact: bool = True, interpret: Optional[bool] = None):
    """Fused sparse AdamW on a flat tensor.

    p, g: (N,);  idx: (k,) sorted int32;  m, v: (k,) fp32;  step: int (1-based).
    Returns (p', m', v').  `capacity` is the per-block window size (0 ->
    heuristic 4x mean occupancy); with exact=True an O(k) XLA fallback
    corrects any windows that overflowed, so results are exact regardless.
    """
    interpret = _default_interpret() if interpret is None else interpret
    N = p.shape[0]
    k = idx.shape[0]
    nb = max(1, -(-N // bn))
    padN = nb * bn
    p_pad = jnp.pad(p, (0, padN - N))
    g_pad = jnp.pad(g, (0, padN - N))

    if capacity <= 0:
        capacity = int(min(k, max(64, 4 * -(-k // nb))))
    K = capacity

    block_of = idx // bn
    idxw, (mw, vw), starts = _sorted_windows(idx[None], (m[None], v[None]),
                                             nb, bn, K)
    idxw, mw, vw, starts = idxw[0], mw[0], vw[0], starts[0]

    t = jnp.asarray(step, jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.float32(b1), jnp.float32(b2), jnp.float32(eps),
                       jnp.float32(wd), c1, c2]).reshape(1, 7)

    p2, mw2, vw2 = sak.sparse_adam_blocks(
        p_pad.reshape(nb, bn), g_pad.reshape(nb, bn), idxw, mw, vw, hyper,
        bn=bn, interpret=interpret)
    p_out = p2.reshape(padN)[:N]

    # windows -> flat (k,)
    j = jnp.arange(k)
    slot = j - starts[block_of]
    covered = slot < K
    slotc = jnp.minimum(slot, K - 1)
    m_out = mw2[block_of, slotc]
    v_out = vw2[block_of, slotc]

    if exact:
        # O(k) reference update; replaces any window-overflow entries
        g_sel = g.astype(jnp.float32)[idx]
        m_ref = b1 * m + (1 - b1) * g_sel
        v_ref = b2 * v + (1 - b2) * g_sel * g_sel
        w = p.astype(jnp.float32)[idx]
        upd = (m_ref / c1) / (jnp.sqrt(v_ref / c2) + eps) + wd * w
        w_ref = w - lr * upd
        cur = p_out[idx]
        p_out = p_out.at[idx].set(
            jnp.where(covered, cur, w_ref.astype(p.dtype)))
        m_out = jnp.where(covered, m_out, m_ref)
        v_out = jnp.where(covered, v_out, v_ref)

    return p_out, m_out, v_out


# ------------------------------------- merge-free delta matmul (serving)
def _colmajor_windows(idx, val, rows: int, cols: int, nb: int, bn: int,
                      capacity: int):
    """Per-(slot, col-block) dense windows of (B, k) row-major deltas.

    The delta-matmul kernel tiles W by column, so entries are re-keyed
    column-major (key = col * rows + row) and sorted per slot — the
    entries landing in col-block j then occupy one contiguous window,
    exactly the `_sorted_windows` trick in a transposed key space.
    Sentinel entries (idx >= rows*cols) key to INT32_SENTINEL and fall in
    no window.  capacity <= 0 sizes windows to the measured worst-case
    occupancy when idx is concrete, else to k (always exact — a missed
    matmul entry has no cheap post-fix, unlike scatter-merge).  Returns
    (keyw (B, nb, K) int32 -1-padded, valw (B, nb, K) f32, K).
    """
    from repro.kernels import lowrank_mask as lrm
    b, k = idx.shape
    r = idx // cols
    c = idx % cols
    key = jnp.where(idx >= rows * cols, lrm.INT32_SENTINEL,
                    c * rows + r).astype(jnp.int32)
    order = jnp.argsort(key, axis=-1)
    key_s = jnp.take_along_axis(key, order, axis=-1)
    val_s = jnp.take_along_axis(val, order, axis=-1)

    block_of = key_s // (rows * bn)                          # (B, k)
    arangeb = jnp.arange(nb)
    starts = jax.vmap(
        lambda bo: jnp.searchsorted(bo, arangeb, side="left"))(block_of)
    ends = jax.vmap(
        lambda bo: jnp.searchsorted(bo, arangeb, side="right"))(block_of)
    if capacity <= 0:
        try:
            capacity = max(1, int(jnp.max(ends - starts)))
        except jax.errors.ConcretizationTypeError:
            capacity = k                                     # traced: exact
    gpos = starts[:, :, None] + jnp.arange(capacity)[None, None, :]
    in_win = gpos < ends[:, :, None]
    gposc = jnp.minimum(gpos, k - 1)

    def take(arr):  # (B, k) gathered at (B, nb, K) positions
        return jnp.take_along_axis(arr[:, None, :], gposc, axis=-1)

    keyw = jnp.where(in_win, take(key_s), -1).astype(jnp.int32)
    valw = jnp.where(in_win, take(val_s), 0.0).astype(jnp.float32)
    return keyw, valw, capacity


def delta_matmul(x, w, idx, val, *, bn: int = 256, capacity: int = 0,
                 backend: str = "auto", interpret: Optional[bool] = None):
    """Per-slot delta matmul: y[b] = x[b] @ merge(w, idx[b], val[b]).

    x: (B, d); w: (d, f) the ONE resident base weight; idx: (B, k) int32
    row-major flat REPLACE indices (sentinel >= d*f writes nothing — the
    base-slot no-op); val: (B, k) replacement values.  Each decode slot
    composes the base with its own adapter's delta inside the dot — no
    merged weight is ever resident (DESIGN.md §5).

    backend:
      * "kernel" — the fused Pallas kernel (`delta_matmul.py`): per
        (slot, col-block) one-hot deposit into the W tile, then the
        engine's own `x @ w` dot at DEFAULT precision;
      * "lax"    — exact fallback: O(k) per-slot scatter into a transient
        W copy inside XLA, then ONE batched dot whose per-row arithmetic
        is the dense engine's `x @ w` row (proven bitwise in tests);
      * "auto"   — kernel on TPU, lax elsewhere.

    Both backends are bitwise-matched by `ref.delta_matmul` (dense
    merge-then-matmul per slot) — the pool-serving identity contract.
    Returns y: (B, f).
    """
    interpret = _default_interpret() if interpret is None else interpret
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "lax"
    rows, cols = w.shape
    b = x.shape[0]
    if backend == "lax":
        wf = w.reshape(-1)
        wm = jax.vmap(
            lambda i, v: wf.at[i].set(v.astype(w.dtype), mode="drop"))(
                idx, val).reshape(b, rows, cols)
        return jnp.einsum("bd,bdf->bf", x, wm)
    if backend != "kernel":
        raise ValueError(f"unknown delta-matmul backend {backend!r}")
    from repro.kernels import delta_matmul as dmk
    bn = max(1, min(bn, cols))
    nb = -(-cols // bn)
    keyw, valw, _ = _colmajor_windows(idx, val, rows, cols, nb, bn, capacity)
    w_pad = jnp.pad(w, ((0, 0), (0, nb * bn - cols)))
    y = dmk.delta_matmul_blocks(x, w_pad, keyw, valw, bn=bn,
                                interpret=interpret)
    return y[:, :cols]


def overlay_matmul(x, w, overlay, *, backend: str = "lax",
                   interpret: Optional[bool] = None):
    """The serving forward's weight matmul, with an optional slot overlay.

    overlay None -> exactly `x @ w` (the engines' existing HLO, untouched
    — non-pool serving compiles the identical program).  Otherwise
    overlay is {"idx": (B, k) int32, "val": (B, k)} of per-slot replace
    entries (row-major flat into w, sentinel >= w.size = no-op) gathered
    from the paged adapter pool, and slot b's output row is computed
    against base-composed-with-slot-b's-delta:

      * x (1, T, d) or any B == 1 (prefill): one transient O(k) scatter
        into a W copy, then the same `x @ w` dot — operand-bitwise equal
        to merge-on-load serving;
      * x (B, d) (decode): `delta_matmul` — the fused kernel or the
        batched-einsum lax fallback, both row-bitwise to the dense dot.
    """
    if is_quantized(w):
        return quant_overlay_matmul(x, w, overlay, backend=backend,
                                    interpret=interpret)
    if overlay is None:
        return x @ w
    idx, val = overlay["idx"], overlay["val"]
    b = idx.shape[0]
    if b == 1:
        wm = (w.reshape(-1).at[idx[0]].set(val[0].astype(w.dtype),
                                           mode="drop").reshape(w.shape))
        return x @ wm
    if x.ndim == 3 and x.shape[1] == 1:       # (B, 1, d) one-token decode
        y = delta_matmul(x[:, 0, :], w, idx, val, backend=backend,
                         interpret=interpret)
        return y[:, None, :]
    if x.ndim == 2:
        return delta_matmul(x, w, idx, val, backend=backend,
                            interpret=interpret)
    # (B, T, d) multi-query per-slot composition (speculative verify)
    wf = w.reshape(-1)
    wm = jax.vmap(
        lambda i, v: wf.at[i].set(v.astype(w.dtype), mode="drop"))(
            idx, val).reshape((b,) + w.shape)
    return jnp.einsum("btd,bdf->btf", x, wm)


# --------------------------------- quantized-base matmul (DESIGN.md §12)
def is_quantized(w) -> bool:
    """True for a quantized-weight operand: the {"q", "scale", "idx",
    "val"} dict `quant.QuantArtifact.to_params` swaps in for a planned
    dense leaf (int8 base + high-precision principal overlay)."""
    return isinstance(w, dict) and "q" in w and "scale" in w


def weight_operand(w, dtype):
    """The forward's weight-cast point: dense leaves cast to the
    activation dtype (the engines' existing `.astype`), quantized
    operand dicts pass through untouched — dequant happens inside
    `quant_matmul` in f32 regardless of activation dtype."""
    if is_quantized(w):
        return w
    return w.astype(dtype)


def _dequant_merged_f32(qw):
    """(rows, cols) f32 merged weight of a quantized operand: dequantize
    the int8 base elementwise, then REPLACE the principal entries with
    their stored full-precision values (`ref.quant_merged` arithmetic)."""
    merged = qw["q"].astype(jnp.float32) * qw["scale"]
    idx, val = qw.get("idx"), qw.get("val")
    if idx is not None:
        merged = merged.reshape(-1).at[idx].set(
            val.astype(jnp.float32), mode="drop").reshape(qw["q"].shape)
    return merged


def quant_matmul(x, qw, idx=None, val=None, *, bn: int = 256,
                 capacity: int = 0, backend: str = "auto",
                 interpret: Optional[bool] = None):
    """y[b] = x[b] @ (dequant(qw) + principal overlay [+ slot b's delta]).

    x: (B, d); qw: quantized operand dict for the (d, f) weight; idx/val:
    optional (B, kd) per-slot adapter replace-deltas (sentinel >= d*f
    writes nothing), composing base + principal + adapter in ONE epilogue.
    A colliding adapter entry overrides the principal value (sequential
    scatter order — principal first, delta second).

    backend:
      * "kernel" — the fused Pallas kernel (`quant_matmul.py`): per
        (slot, col-block) in-VMEM dequant, one-hot overlay deposits, then
        the f32 dot;
      * "lax"    — exact fallback: dequant + principal scatter into ONE
        transient f32 matrix inside XLA, per-slot delta scatters, one dot;
      * "auto"   — kernel on TPU, lax elsewhere.

    All backends are bitwise-matched by `ref.quant_matmul` (the
    BENCH_quant matches_ref contract).  Returns y: (B, f) in x.dtype.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "lax"
    rows, cols = qw["q"].shape
    xf = x.astype(jnp.float32)
    if backend == "lax":
        merged = _dequant_merged_f32(qw)
        if idx is None:
            return (xf @ merged).astype(x.dtype)
        mf = merged.reshape(-1)
        wm = jax.vmap(
            lambda i, v: mf.at[i].set(v.astype(jnp.float32),
                                      mode="drop"))(idx, val).reshape(
                                          x.shape[0], rows, cols)
        return jnp.einsum("bd,bdf->bf", xf, wm).astype(x.dtype)
    if backend != "kernel":
        raise ValueError(f"unknown quant-matmul backend {backend!r}")
    from repro.kernels import quant_matmul as qmk
    bn = max(1, min(bn, cols))
    nb = -(-cols // bn)
    pkeyw, pvalw, _ = _colmajor_windows(
        qw["idx"][None], qw["val"][None].astype(jnp.float32),
        rows, cols, nb, bn, capacity)
    if idx is None:                              # no adapter: empty windows
        dkeyw = jnp.full((1, nb, 1), -1, jnp.int32)
        dvalw = jnp.zeros((1, nb, 1), jnp.float32)
    else:
        dkeyw, dvalw, _ = _colmajor_windows(
            idx, val.astype(jnp.float32), rows, cols, nb, bn, capacity)
    q_pad = jnp.pad(qw["q"], ((0, 0), (0, nb * bn - cols)))
    sc = jnp.broadcast_to(qw["scale"].astype(jnp.float32), (1, cols))
    sc_pad = jnp.pad(sc, ((0, 0), (0, nb * bn - cols)))
    y = qmk.quant_matmul_blocks(xf, q_pad, sc_pad, pkeyw, pvalw,
                                dkeyw, dvalw, bn=bn, interpret=interpret)
    return y[:, :cols].astype(x.dtype)


def quant_overlay_matmul(x, qw, overlay, *, backend: str = "lax",
                         interpret: Optional[bool] = None):
    """`overlay_matmul` for a quantized weight operand — same shape
    contract, same per-slot composition semantics, with the int8 base
    dequantized and the principal overlay merged inside the dot.

      * overlay None: plain quantized matmul (any leading shape);
      * overlay b == 1 (prefill / shared delta): one transient scatter
        into the merged f32 matrix, then the same dot;
      * x (B, d) or (B, 1, d) decode: `quant_matmul` per-slot epilogue
        (fused kernel or lax fallback per `backend`);
      * x (B, T, d) multi-query (speculative verify): per-slot lax
        composition, einsum over per-slot merged copies.
    """
    if backend == "auto":
        backend = "kernel" if jax.default_backend() == "tpu" else "lax"
    if overlay is None:
        if x.ndim == 2 and backend == "kernel":
            return quant_matmul(x, qw, backend=backend, interpret=interpret)
        merged = _dequant_merged_f32(qw)
        return (x.astype(jnp.float32) @ merged).astype(x.dtype)
    idx, val = overlay["idx"], overlay["val"]
    b = idx.shape[0]
    if b == 1:
        merged = _dequant_merged_f32(qw)
        wm = merged.reshape(-1).at[idx[0]].set(
            val[0].astype(jnp.float32), mode="drop").reshape(merged.shape)
        return (x.astype(jnp.float32) @ wm).astype(x.dtype)
    if x.ndim == 3 and x.shape[1] == 1:       # (B, 1, d) one-token decode
        y = quant_matmul(x[:, 0, :], qw, idx, val, backend=backend,
                         interpret=interpret)
        return y[:, None, :]
    if x.ndim == 2:
        return quant_matmul(x, qw, idx, val, backend=backend,
                            interpret=interpret)
    # (B, T, d) multi-query per-slot composition (speculative verify)
    merged = _dequant_merged_f32(qw)
    mf = merged.reshape(-1)
    wm = jax.vmap(
        lambda i, v: mf.at[i].set(v.astype(jnp.float32), mode="drop"))(
            idx, val).reshape((b,) + merged.shape)
    return jnp.einsum("btd,bdf->btf", x.astype(jnp.float32),
                      wm).astype(x.dtype)
