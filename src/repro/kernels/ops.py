"""jit'd wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (the kernels execute via the Pallas
interpreter on CPU for correctness); on TPU backends the compiled kernels
run natively.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import lowrank_mask as lrm
from repro.kernels import sparse_adam as sak


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ lowrank ops
@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lowrank_abs(a, b, bm: int = 256, bn: int = 256,
                interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return lrm.lowrank_stat(a, b, "abs", bm=bm, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lowrank_count(a, b, tau, bm: int = 256, bn: int = 256,
                  interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    parts = lrm.lowrank_stat(a, b, "count", tau=tau, bm=bm, bn=bn,
                             interpret=interpret)
    return jnp.sum(parts)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def lowrank_absmax(a, b, bm: int = 256, bn: int = 256,
                   interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    parts = lrm.lowrank_stat(a, b, "absmax", bm=bm, bn=bn,
                             interpret=interpret)
    return jnp.max(parts)


@functools.partial(jax.jit, static_argnames=("nbins", "bm", "bn", "interpret"))
def lowrank_hist(a, b, lo, hi, nbins: int = 512, bm: int = 256, bn: int = 256,
                 interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    parts = lrm.lowrank_stat(a, b, "hist", lo=lo, hi=hi, nbins=nbins,
                             bm=bm, bn=bn, interpret=interpret)
    return jnp.sum(parts, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("k", "passes", "nbins", "bm", "bn",
                                    "interpret"))
def lift_threshold(a, b, k: int, passes: int = 2, nbins: int = 512,
                   bm: int = 256, bn: int = 256,
                   interpret: Optional[bool] = None):
    """Threshold tau s.t. count(|A B^T| > tau) ~= k (within the final bin).

    Multi-pass histogram refinement: W' never materializes in HBM.
    """
    interpret = _default_interpret() if interpret is None else interpret
    lo = jnp.float32(0.0)
    hi = lowrank_absmax(a, b, bm, bn, interpret) * (1 + 1e-6)
    for _ in range(passes):
        hist = lowrank_hist(a, b, lo, hi, nbins, bm, bn, interpret)
        # count of entries strictly above each bin's lower edge
        above = jnp.cumsum(hist[::-1])[::-1]          # above[i] = sum(hist[i:])
        # smallest bin whose lower edge keeps >= k entries above it
        ok = above >= k
        j = jnp.maximum(jnp.sum(ok) - 1, 0)           # last True index
        width = (hi - lo) / nbins
        new_lo = lo + j * width
        new_hi = new_lo + width
        lo, hi = new_lo, new_hi
    return lo


@functools.partial(jax.jit,
                   static_argnames=("k", "passes", "nbins", "bm", "bn",
                                    "interpret"))
def lift_mask(a, b, k: int, passes: int = 2, nbins: int = 512,
              bm: int = 256, bn: int = 256,
              interpret: Optional[bool] = None):
    """(mask (m, n) bool, tau) with count(mask) in [k, k + final-bin-ties)."""
    interpret = _default_interpret() if interpret is None else interpret
    tau = lift_threshold(a, b, k, passes, nbins, bm, bn, interpret)
    mask = lrm.lowrank_stat(a, b, "mask", tau=tau, bm=bm, bn=bn,
                            interpret=interpret)
    return mask, tau


# ----------------------------------------------------------- sparse adam
@functools.partial(jax.jit,
                   static_argnames=("bn", "capacity", "exact", "interpret"))
def sparse_adam(p, g, idx, m, v, step, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                wd=0.0, bn: int = 2048, capacity: int = 0,
                exact: bool = True, interpret: Optional[bool] = None):
    """Fused sparse AdamW on a flat tensor.

    p, g: (N,);  idx: (k,) sorted int32;  m, v: (k,) fp32;  step: int (1-based).
    Returns (p', m', v').  `capacity` is the per-block window size (0 ->
    heuristic 4x mean occupancy); with exact=True an O(k) XLA fallback
    corrects any windows that overflowed, so results are exact regardless.
    """
    interpret = _default_interpret() if interpret is None else interpret
    N = p.shape[0]
    k = idx.shape[0]
    nb = max(1, -(-N // bn))
    padN = nb * bn
    p_pad = jnp.pad(p, (0, padN - N))
    g_pad = jnp.pad(g, (0, padN - N))

    if capacity <= 0:
        capacity = int(min(k, max(64, 4 * -(-k // nb))))
    K = capacity

    block_of = idx // bn
    arangeb = jnp.arange(nb)
    starts = jnp.searchsorted(block_of, arangeb, side="left")
    ends = jnp.searchsorted(block_of, arangeb, side="right")
    gpos = starts[:, None] + jnp.arange(K)[None, :]
    in_win = gpos < ends[:, None]
    gposc = jnp.minimum(gpos, k - 1)
    idxw = jnp.where(in_win, idx[gposc], -1).astype(jnp.int32)
    mw = jnp.where(in_win, m[gposc], 0.0)
    vw = jnp.where(in_win, v[gposc], 0.0)

    t = jnp.asarray(step, jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    hyper = jnp.stack([jnp.asarray(lr, jnp.float32),
                       jnp.float32(b1), jnp.float32(b2), jnp.float32(eps),
                       jnp.float32(wd), c1, c2]).reshape(1, 7)

    p2, mw2, vw2 = sak.sparse_adam_blocks(
        p_pad.reshape(nb, bn), g_pad.reshape(nb, bn), idxw, mw, vw, hyper,
        bn=bn, interpret=interpret)
    p_out = p2.reshape(padN)[:N]

    # windows -> flat (k,)
    j = jnp.arange(k)
    slot = j - starts[block_of]
    covered = slot < K
    slotc = jnp.minimum(slot, K - 1)
    m_out = mw2[block_of, slotc]
    v_out = vw2[block_of, slotc]

    if exact:
        # O(k) reference update; replaces any window-overflow entries
        g_sel = g.astype(jnp.float32)[idx]
        m_ref = b1 * m + (1 - b1) * g_sel
        v_ref = b2 * v + (1 - b2) * g_sel * g_sel
        w = p.astype(jnp.float32)[idx]
        upd = (m_ref / c1) / (jnp.sqrt(v_ref / c2) + eps) + wd * w
        w_ref = w - lr * upd
        cur = p_out[idx]
        p_out = p_out.at[idx].set(
            jnp.where(covered, cur, w_ref.astype(p.dtype)))
        m_out = jnp.where(covered, m_out, m_ref)
        v_out = jnp.where(covered, v_out, v_ref)

    return p_out, m_out, v_out
