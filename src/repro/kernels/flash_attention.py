"""Pallas TPU flash-attention (forward) kernel.

The §Roofline tables show attention score traffic dominating the memory
term of every train/prefill cell under unfused accounting — this kernel is
the TPU hot-path that keeps the (q_blk x kv_blk) score tile in VMEM
end-to-end (the pure-JAX nn/flash.py remains the autodiff-complete
reference and the CPU default; MaxText-style layering).

Grid: (B*H, S/q_blk).  Each program instance streams the KV blocks of one
query block with the online-softmax recurrence in VMEM registers:

    m' = max(m, rowmax(s));  l' = l*e^{m-m'} + rowsum(e^{s-m'})
    acc' = acc*e^{m-m'} + e^{s-m'} @ v

Causality is handled per-block: fully-masked KV blocks are skipped via the
grid upper bound, the diagonal block applies the triangular mask.
Validated against ref/naive attention in interpret mode
(tests/test_kernels.py); dtypes bf16/f32, head dims {64, 80, 128, 256}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, q_blk: int, kv_blk: int,
               seq_len: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (q_blk, d)
    d = q.shape[-1]

    m0 = jnp.full((q_blk,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_blk,), jnp.float32)
    a0 = jnp.zeros((q_blk, d), jnp.float32)

    n_kv = seq_len // kv_blk
    if causal:
        # number of kv blocks this q block attends into
        hi = (qi * q_blk + q_blk + kv_blk - 1) // kv_blk
    else:
        hi = n_kv

    def body(kj, carry):
        m, l, acc = carry
        # pl.dslice(0, 1) + [0] rather than a bare int index: integer
        # entries in a pl.load index tuple break on some jax releases
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(kj * kv_blk, kv_blk),
                            slice(None)))[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(kj * kv_blk, kv_blk),
                            slice(None)))[0].astype(jnp.float32)
        s = q @ k.T                                   # (q_blk, kv_blk)
        if causal:
            qpos = qi * q_blk + jax.lax.broadcasted_iota(
                jnp.int32, (q_blk, kv_blk), 0)
            kpos = kj * kv_blk + jax.lax.broadcasted_iota(
                jnp.int32, (q_blk, kv_blk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-37)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        scale: float | None = None,
                        q_blk: int = 128, kv_blk: int = 128,
                        interpret: bool = True):
    """q, k, v: (B, S, H, D) with equal head counts (GQA pre-expanded).
    Returns o: (B, S, H, D)."""
    B, S, H, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, S)
    assert S % q_blk == 0 and S % kv_blk == 0, (S, q_blk, kv_blk)

    # (B*H, S, D) layout: one grid row per (batch, head)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    kern = functools.partial(_fa_kernel, q_blk=q_blk, kv_blk=kv_blk,
                             seq_len=S, scale=scale, causal=causal)
    oh = pl.pallas_call(
        kern,
        grid=(B * H, S // q_blk),
        in_specs=[
            pl.BlockSpec((1, q_blk, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_blk, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qh, kh, vh)
    return oh.reshape(B, H, S, D).transpose(0, 2, 1, 3)
