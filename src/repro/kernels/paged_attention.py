"""Pallas paged-attention decode kernel (DESIGN.md §5).

Decode attention over a block-paged KV pool: K/V live in fixed-size
pages shared by every sequence, and a per-sequence *block table* maps
logical page j to a physical page.  The kernel never materializes the
gathered (B, T) key/value tensors that the jax.lax fallback builds —
each program instance walks its sequence's block table and streams one
physical page at a time through the online-softmax recurrence, so HBM
traffic is exactly the live pages of that sequence (plus the query
block), not nmax * page_size slots.

Grid: (B, H_kv).  Each instance handles one (sequence, kv-head) pair and
an (n_q, g, d) query block — n_q decode positions (1 for plain decode,
1 + draft_len for speculative verify) times the `g = H_q / H_kv` query
heads of its GQA group — at once: decode is memory-bound, so the cache
is read once at its native kv-head width and the whole (n_q * g,
page_size) score tile stays in registers/VMEM.

Only the pages holding tokens <= positions[b] + n_q - 1 are visited (the
loop upper bound is `(pos + n_q - 1) // ps + 1`, clamped to the table
width); each query row i applies its own per-token `kpos <= pos + i`
mask, which keeps draft token i blind to drafts i+1.. — exactly the
causal order one-token decode would produce.  Physical page ids are read
from the block-table block and indexed with `pl.dslice` dynamic starts,
the same dynamic-load idiom the flash kernel uses (integer entries in a
pl.load index tuple break on some jax releases).

Two page-streaming schedules share the softmax math:

  * interpret / fallback (`_paged_attn_kernel`): plain `pl.load` per
    page — the schedule interpret mode (and the unit tests, which run
    off-TPU) can execute;
  * real TPU (`_paged_attn_kernel_dma`): K/V pages stay in HBM
    (`memory_space=ANY`) and the kernel double-buffers the page stream
    through two VMEM scratch slots with `pltpu.make_async_copy` — page
    j+1's copy is started before page j's compute waits, in the
    emit_pipeline style (pallas guide "Patterns: Double Buffering"), so
    the page DMA overlaps the (n_q*g, ps) score tile's compute instead
    of blocking on every block-table entry.

Validated against `ref.paged_attention` / `ref.paged_attention_multi`
and the lax fallback in tests/test_paged_kv.py (interpret mode off-TPU);
dtypes bf16/f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attend_page(q, k, v, j, pos, carry, *, page_size: int, g: int,
                 window=None):
    """One page's online-softmax update.  q: (n_q*g, d) pre-scaled fp32;
    k/v: (ps, d); query row r belongs to decode position pos + r // g;
    j is the page's LOGICAL index (kpos = j * ps + slot), which for ring
    walks may differ from the block-table column it was loaded from.
    `window` additionally masks kpos <= qpos - window (sliding-window
    rings; stale ring cells alias kpos - ring * ps and land outside the
    window by construction)."""
    m, l, acc = carry
    rows = q.shape[0]
    s = q @ k.astype(jnp.float32).T                     # (n_q*g, ps)
    kpos = j * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 1)
    qpos = pos + jax.lax.broadcasted_iota(
        jnp.int32, (rows, page_size), 0) // g
    ok = kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
    return m_new, l_new, acc_new


def _paged_attn_kernel(q_ref, k_ref, v_ref, bt_ref, pos_ref, o_ref, *,
                       page_size: int, scale: float, window=None,
                       ring=None):
    """Direct-load schedule: one blocking page load per block-table
    entry.  Runs under interpret mode and is the non-TPU reference.

    With `ring` the block table is indexed by ring column: the walk
    visits the newest page first (logical page pos // ps lives at column
    (pos // ps) % ring) and steps back at most `ring` pages — everything
    older is outside the window."""
    nq, g, d = q_ref.shape[2:]
    q = q_ref[0, 0].astype(jnp.float32).reshape(nq * g, d) * scale
    pos = pos_ref[0, 0]                                 # scalar int32
    nmax = bt_ref.shape[1]
    if ring is None:
        n_live = jnp.minimum((pos + nq - 1) // page_size + 1, nmax)
    else:
        base = pos // page_size
        n_live = jnp.minimum(base + 1, ring)

    m0 = jnp.full((nq * g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq * g,), jnp.float32)
    a0 = jnp.zeros((nq * g, d), jnp.float32)

    def body(i, carry):
        if ring is None:
            logical = i
            col = i
        else:
            logical = base - i          # newest page first: it always
            col = jax.lax.rem(logical, ring)   # holds pos itself, so the
            #                           # softmax max is finite before any
            #                           # fully-masked older page arrives
        page = bt_ref[0, col]
        k = pl.load(k_ref, (pl.dslice(page, 1), slice(None),
                            pl.dslice(0, 1), slice(None)))[0, :, 0, :]
        v = pl.load(v_ref, (pl.dslice(page, 1), slice(None),
                            pl.dslice(0, 1), slice(None)))[0, :, 0, :]
        return _attend_page(q, k, v, logical, pos, carry,
                            page_size=page_size, g=g, window=window)

    m, l, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-37)
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype).reshape(nq, g, d)


def _paged_attn_kernel_dma(q_ref, k_hbm, v_hbm, bt_ref, pos_ref, o_ref, *,
                           page_size: int, scale: float, window=None,
                           ring=None):
    """Double-buffered schedule: K/V pages live in HBM and stream
    through two VMEM scratch slots — page j+1's async copy is in flight
    while page j is attended.  `ring` walks the block table by ring
    column, newest page first (see `_paged_attn_kernel`)."""
    h = pl.program_id(1)
    nq, g, d = q_ref.shape[2:]
    q = q_ref[0, 0].astype(jnp.float32).reshape(nq * g, d) * scale
    pos = pos_ref[0, 0]
    nmax = bt_ref.shape[1]
    if ring is None:
        n_live = jnp.minimum((pos + nq - 1) // page_size + 1, nmax)
        base = None
    else:
        base = pos // page_size
        n_live = jnp.minimum(base + 1, ring)

    def body(k_buf, v_buf, sem):
        def page_dma(slot, j):
            col = j if ring is None else jax.lax.rem(base - j, ring)
            page = bt_ref[0, col]
            return (
                pltpu.make_async_copy(
                    k_hbm.at[pl.dslice(page, 1), :, pl.dslice(h, 1), :],
                    k_buf.at[slot], sem.at[slot, 0]),
                pltpu.make_async_copy(
                    v_hbm.at[pl.dslice(page, 1), :, pl.dslice(h, 1), :],
                    v_buf.at[slot], sem.at[slot, 1]),
            )

        for c in page_dma(0, 0):
            c.start()

        m0 = jnp.full((nq * g,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((nq * g,), jnp.float32)
        a0 = jnp.zeros((nq * g, d), jnp.float32)

        def loop(j, carry):
            slot = jax.lax.rem(j, 2)

            @pl.when(j + 1 < n_live)
            def _():                     # prefetch page j+1 before waiting
                for c in page_dma(jax.lax.rem(j + 1, 2), j + 1):
                    c.start()

            for c in page_dma(slot, j):
                c.wait()
            k = k_buf[slot, 0, :, 0, :]
            v = v_buf[slot, 0, :, 0, :]
            logical = j if ring is None else base - j
            return _attend_page(q, k, v, logical, pos, carry,
                                page_size=page_size, g=g, window=window)

        m, l, acc = jax.lax.fori_loop(0, n_live, loop, (m0, l0, a0))
        l = jnp.maximum(l, 1e-37)
        o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype) \
            .reshape(nq, g, d)

    pl.run_scoped(
        body,
        k_buf=pltpu.VMEM((2, 1, page_size, 1, d), k_hbm.dtype),
        v_buf=pltpu.VMEM((2, 1, page_size, 1, d), v_hbm.dtype),
        sem=pltpu.SemaphoreType.DMA((2, 2)),
    )


def _paged_attn_call(q, k_pages, v_pages, block_tables, positions, *,
                     scale: float, interpret: bool, pipeline: bool,
                     window=None, ring=None):
    """Shared pallas_call plumbing.  q: (B, H_kv, n_q, g, D)."""
    B, hkv, nq, g, D = q.shape
    P, ps, hkv2, D2 = k_pages.shape
    assert (hkv, D) == (hkv2, D2), (q.shape, k_pages.shape)
    nmax = block_tables.shape[1]

    if pipeline:
        kern = functools.partial(_paged_attn_kernel_dma, page_size=ps,
                                 scale=scale, window=window, ring=ring)
        kv_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    else:
        kern = functools.partial(_paged_attn_kernel, page_size=ps,
                                 scale=scale, window=window, ring=ring)
        kv_spec = pl.BlockSpec((P, ps, 1, D), lambda b, h: (0, 0, h, 0))
    return pl.pallas_call(
        kern,
        grid=(B, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, nq, g, D), lambda b, h: (b, h, 0, 0, 0)),
            kv_spec,
            kv_spec,
            pl.BlockSpec((1, nmax), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, nq, g, D),
                               lambda b, h: (b, h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hkv, nq, g, D), q.dtype),
        interpret=interpret,
    )(q, k_pages, v_pages, block_tables.astype(jnp.int32),
      positions.astype(jnp.int32).reshape(B, 1))


def paged_decode_fwd(q, k_pages, v_pages, block_tables, positions, *,
                     scale: float | None = None, interpret: bool = True,
                     pipeline: bool | None = None,
                     window: int | None = None, ring: int | None = None):
    """q: (B, H_kv, g, D) grouped queries for ONE decode token;
    k_pages / v_pages: (P, ps, H_kv, D); block_tables: (B, nmax) int32;
    positions: (B,) int32.  Returns o: (B, H_kv, g, D).

    `window`/`ring` (STATIC, both or neither) select the sliding-window
    ring walk: the block table is indexed by ring column and only keys
    with kpos in (pos - window, pos] contribute.

    `pipeline` selects the double-buffered HBM page stream; it defaults
    to on for compiled TPU runs and off under interpret mode (the DMA
    primitives need real TPU semaphores)."""
    B, hkv, g, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    pipeline = (not interpret) if pipeline is None else pipeline
    o = _paged_attn_call(q[:, :, None], k_pages, v_pages, block_tables,
                         positions, scale=scale, interpret=interpret,
                         pipeline=pipeline, window=window, ring=ring)
    return o[:, :, 0]


def paged_verify_fwd(q, k_pages, v_pages, block_tables, positions, *,
                     scale: float | None = None, interpret: bool = True,
                     pipeline: bool | None = None):
    """Speculative verify: q: (B, H_kv, n_q, g, D) grouped queries for
    n_q consecutive decode positions starting at positions[b] (the
    current token plus the drafted tokens); query i attends
    kpos <= positions[b] + i.  Returns o: (B, H_kv, n_q, g, D)."""
    B, hkv, nq, g, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    pipeline = (not interpret) if pipeline is None else pipeline
    return _paged_attn_call(q, k_pages, v_pages, block_tables, positions,
                            scale=scale, interpret=interpret,
                            pipeline=pipeline)
