"""Pallas paged-attention decode kernel (DESIGN.md §5).

Decode attention over a block-paged KV pool: K/V live in fixed-size
pages shared by every sequence, and a per-sequence *block table* maps
logical page j to a physical page.  The kernel never materializes the
gathered (B, T) key/value tensors that the jax.lax fallback builds —
each program instance walks its sequence's block table and streams one
physical page at a time through the online-softmax recurrence, so HBM
traffic is exactly the live pages of that sequence (plus the one query
token), not nmax * page_size slots.

Grid: (B, H_kv).  Each instance handles one (sequence, kv-head) pair and
the `g = H_q / H_kv` query heads of its GQA group at once — decode is
memory-bound, so the cache is read once at its native kv-head width and
the whole (g, page_size) score tile stays in registers/VMEM.

Only the pages holding tokens <= positions[b] are visited (the loop
upper bound is `pos // ps + 1`); the final page applies the per-token
`kpos <= pos` mask.  Physical page ids are read from the block-table
block and indexed with `pl.dslice` dynamic starts, the same dynamic-load
idiom the flash kernel uses (integer entries in a pl.load index tuple
break on some jax releases).

Validated against `ref.paged_attention` and the lax fallback in
tests/test_paged_kv.py (interpret mode off-TPU); dtypes bf16/f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_decode_kernel(q_ref, k_ref, v_ref, bt_ref, pos_ref, o_ref, *,
                         page_size: int, scale: float):
    q = q_ref[0, 0].astype(jnp.float32) * scale        # (g, d)
    g, d = q.shape
    pos = pos_ref[0, 0]                                # scalar int32
    n_live = pos // page_size + 1                      # pages with tokens

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, d), jnp.float32)

    def body(j, carry):
        m, l, acc = carry
        page = bt_ref[0, j]
        k = pl.load(k_ref, (pl.dslice(page, 1), slice(None),
                            pl.dslice(0, 1), slice(None)))[0, :, 0, :]
        v = pl.load(v_ref, (pl.dslice(page, 1), slice(None),
                            pl.dslice(0, 1), slice(None)))[0, :, 0, :]
        s = q @ k.astype(jnp.float32).T                # (g, ps)
        kpos = j * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (g, page_size), 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, a0))
    l = jnp.maximum(l, 1e-37)
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def paged_decode_fwd(q, k_pages, v_pages, block_tables, positions, *,
                     scale: float | None = None, interpret: bool = True):
    """q: (B, H_kv, g, D) grouped queries for ONE decode token;
    k_pages / v_pages: (P, ps, H_kv, D); block_tables: (B, nmax) int32;
    positions: (B,) int32.  Returns o: (B, H_kv, g, D)."""
    B, hkv, g, D = q.shape
    P, ps, hkv2, D2 = k_pages.shape
    assert (hkv, D) == (hkv2, D2), (q.shape, k_pages.shape)
    nmax = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale

    kern = functools.partial(_paged_decode_kernel, page_size=ps, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((P, ps, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((P, ps, 1, D), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((1, nmax), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hkv, g, D), q.dtype),
        interpret=interpret,
    )(q, k_pages, v_pages, block_tables.astype(jnp.int32),
      positions.astype(jnp.int32).reshape(B, 1))
