"""Fused low-rank reconstruct + magnitude kernel (Pallas TPU).

The LIFT mask-refresh hot spot is `top-k of |A @ B^T|` where A (m, r),
B (n, r) are the rank-r factors.  Materializing W' = A B^T in HBM costs an
m*n fp32 round-trip per refresh (0.97 GB for qwen2-72b's down-proj).  This
kernel computes each (bm x bn) tile of W' in VMEM straight off the MXU and
immediately reduces it to the requested statistic — W' never leaves VMEM:

  * mode "abs"     -> |W'| tile (materializing variant, for tests/fallback)
  * mode "count"   -> per-tile count of |W'| > tau        (threshold search)
  * mode "hist"    -> per-tile histogram of |W'| on [lo,hi) (2-pass search)
  * mode "absmax"  -> per-tile max |W'|                    (range finding)
  * mode "mask"    -> bool tile of |W'| > tau              (final mask)
  * mode "compact" -> per-tile compacted flat indices of |W'| > tau
                      (streaming index extraction; see below)

Structured LIFT (paper App. G.7): the reducing modes (count / hist /
absmax / compact) accept `bs > 1` and operate on BLOCK scores — each
(bm, bn) tile of |W'| is summed over its (bs x bs) sub-blocks in VMEM
right after the MXU matmul, so the statistic (and the compacted indices)
live in the (m/bs, n/bs) block-score space.  Tiles must align to block
boundaries (bm % bs == 0, bn % bs == 0); "compact" then emits global
flat BLOCK indices (row-major into the (m/bs, n/bs) block matrix) and
`capacity` counts block slots.  The block-score matrix, like W', never
leaves VMEM.

"compact" is the selection-engine fast path: each tile emits the GLOBAL
flat indices (row-major into the full (m, n) matrix) of its above-threshold
entries, ascending, left-packed into a fixed `capacity`-slot buffer and
sentinel-padded (INT32_MAX), plus the tile's true count.  The caller
concatenates all tile buffers and sorts once — O(tiles * capacity), sized
by k, never by m*n — so neither W' nor a full score/mask matrix is ever
written to HBM.  Counts above `capacity` mean dropped entries; callers
surface sum(max(count - capacity, 0)) as an overflow diagnostic.
Compaction is scatter-free (TPU has no VPU scatter): per row of the tile,
a cumsum assigns output slots and a (bn x capacity) one-hot reduction
deposits the indices, fori_loop-carried across rows.

Grid is (m/bm, n/bn); A tiles are revisited along j (read m*r*gn values
total — negligible vs m*n).  MXU work per tile is a (bm, r) x (r, bn)
matmul with fp32 accumulate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tile_scores(a_ref, b_ref, bs: int = 1):
    """|A_tile B_tile^T| at score-unit granularity: elements for bs == 1,
    (bs x bs) block sums for structured LIFT — the one place the
    block-summed score definition is spelled out (VPU reshape+reduce on
    the fp32 MXU tile, no extra VMEM traffic)."""
    w = jnp.dot(a_ref[...], b_ref[...].T,
                preferred_element_type=jnp.float32)
    s = jnp.abs(w)
    if bs > 1:
        bm, bn = s.shape
        s = s.reshape(bm // bs, bs, bn // bs, bs).sum(axis=(1, 3))
    return s


def _tile_kernel_abs(a_ref, b_ref, out_ref):
    out_ref[...] = _tile_scores(a_ref, b_ref)


def _tile_kernel_mask(tau_ref, a_ref, b_ref, out_ref):
    out_ref[...] = (_tile_scores(a_ref, b_ref) > tau_ref[0, 0])


def _tile_kernel_count(tau_ref, a_ref, b_ref, out_ref, *, bs: int):
    s = _tile_scores(a_ref, b_ref, bs)
    out_ref[0, 0] = jnp.sum(s > tau_ref[0, 0]).astype(jnp.int32)


def _tile_kernel_absmax(a_ref, b_ref, out_ref, *, bs: int):
    out_ref[0, 0] = jnp.max(_tile_scores(a_ref, b_ref, bs))


def _tile_kernel_hist(lohi_ref, a_ref, b_ref, out_ref, *, nbins: int,
                      bs: int):
    s = _tile_scores(a_ref, b_ref, bs)
    lo, hi = lohi_ref[0, 0], lohi_ref[0, 1]
    width = (hi - lo) / nbins
    ids = jnp.clip(jnp.floor((s - lo) / width), 0, nbins - 1)
    ids = ids.astype(jnp.int32).reshape(-1)
    # one-hot reduction (VPU-friendly; no scatter on TPU)
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, nbins), 1)
    onehot = (ids[:, None] == bins).astype(jnp.int32)
    out_ref[0, :] = jnp.sum(onehot, axis=0)


INT32_SENTINEL = 2 ** 31 - 1


def _tile_kernel_compact(tau_ref, a_ref, b_ref, idx_ref, cnt_ref, *,
                         capacity: int, n_cols: int, bm: int, bn: int,
                         bs: int):
    """`n_cols`, `bm`, `bn` and the emitted indices are in score UNITS:
    elements for bs == 1, (bs x bs) blocks for structured LIFT (the caller
    passes n/bs and bm/bs-sized unit tiles)."""
    i, j = pl.program_id(0), pl.program_id(1)
    hit = _tile_scores(a_ref, b_ref, bs) > tau_ref[0, 0]   # (bm, bn) units
    row0 = i * bm
    col_ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    slots = jax.lax.broadcasted_iota(jnp.int32, (1, capacity), 1)
    idx_ref[0, :] = jnp.zeros((capacity,), jnp.int32)

    def body(r, filled):
        h = hit[r, :]                                      # (bn,) bool
        h32 = h.astype(jnp.int32)
        pos = filled + jnp.cumsum(h32) - h32               # output slot/hit
        gidx = (row0 + r) * n_cols + col_ids[0]            # (bn,) int32
        onehot = (pos[:, None] == slots) & h[:, None]      # (bn, capacity)
        idx_ref[0, :] += jnp.sum(
            jnp.where(onehot, gidx[:, None], 0), axis=0).astype(jnp.int32)
        return filled + jnp.sum(h32)

    cnt = jax.lax.fori_loop(0, bm, body, jnp.int32(0))
    cnt_ref[0, 0] = cnt
    idx_ref[0, :] = jnp.where(slots[0] < jnp.minimum(cnt, capacity),
                              idx_ref[0, :], INT32_SENTINEL)


def _grid(m, n, bm, bn):
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return m // bm, n // bn


def lowrank_stat(a: jax.Array, b: jax.Array, mode: str, *,
                 tau=None, lo=None, hi=None, nbins: int = 256,
                 capacity: int = 1024,
                 bm: int = 256, bn: int = 256, bs: int = 1,
                 interpret: bool = True):
    """Dispatch one fused pass over the implicit W' = A B^T.

    `bs > 1` switches the reducing modes (count / absmax / hist / compact)
    to (bs x bs) block-summed scores — stats and compacted indices live in
    the (m/bs, n/bs) block space; tiles must align (bm % bs == bn % bs
    == 0).  "abs"/"mask" are element-only (dense fallbacks materialize).

    Returns: abs -> (m, n) f32;  mask -> (m, n) bool;
             count -> (gm, gn) i32;  absmax -> (gm, gn) f32;
             hist -> (gm*gn, nbins) i32 (sum over axis 0 for the total);
             compact -> ((gm*gn, capacity) i32 indices, (gm, gn) i32 counts).
    """
    m, r = a.shape
    n, _ = b.shape
    bm, bn = min(bm, m), min(bn, n)
    gm, gn = _grid(m, n, bm, bn)
    if bs > 1:
        if mode in ("abs", "mask"):
            raise ValueError(f"mode {mode!r} has no block-summed variant")
        if bm % bs or bn % bs:
            raise ValueError(
                f"block-summed stats need tiles aligned to block_size: "
                f"bm={bm}, bn={bn}, bs={bs}")
    a_spec = pl.BlockSpec((bm, r), lambda i, j: (i, 0))
    b_spec = pl.BlockSpec((bn, r), lambda i, j: (j, 0))
    common = dict(grid=(gm, gn), interpret=interpret)

    if mode == "abs":
        return pl.pallas_call(
            _tile_kernel_abs,
            in_specs=[a_spec, b_spec],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            **common)(a, b)
    if mode == "mask":
        tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)
        return pl.pallas_call(
            _tile_kernel_mask,
            in_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                      a_spec, b_spec],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.bool_),
            **common)(tau_arr, a, b)
    if mode == "count":
        tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)
        return pl.pallas_call(
            functools.partial(_tile_kernel_count, bs=bs),
            in_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                      a_spec, b_spec],
            out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((gm, gn), jnp.int32),
            **common)(tau_arr, a, b)
    if mode == "absmax":
        return pl.pallas_call(
            functools.partial(_tile_kernel_absmax, bs=bs),
            in_specs=[a_spec, b_spec],
            out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((gm, gn), jnp.float32),
            **common)(a, b)
    if mode == "compact":
        tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)
        capacity = int(min(capacity, (bm // bs) * (bn // bs)))
        return pl.pallas_call(
            functools.partial(_tile_kernel_compact, capacity=capacity,
                              n_cols=n // bs, bm=bm // bs, bn=bn // bs,
                              bs=bs),
            in_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                      a_spec, b_spec],
            out_specs=(pl.BlockSpec((1, capacity),
                                    lambda i, j: (i * gn + j, 0)),
                       pl.BlockSpec((1, 1), lambda i, j: (i, j))),
            out_shape=(jax.ShapeDtypeStruct((gm * gn, capacity), jnp.int32),
                       jax.ShapeDtypeStruct((gm, gn), jnp.int32)),
            **common)(tau_arr, a, b)
    if mode == "hist":
        lohi = jnp.asarray([lo, hi], jnp.float32).reshape(1, 2)
        return pl.pallas_call(
            functools.partial(_tile_kernel_hist, nbins=nbins, bs=bs),
            in_specs=[pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
                      a_spec, b_spec],
            out_specs=pl.BlockSpec((1, nbins),
                                   lambda i, j: (i * gn + j, 0)),
            out_shape=jax.ShapeDtypeStruct((gm * gn, nbins), jnp.int32),
            **common)(lohi, a, b)
    raise ValueError(mode)
