"""Fused per-slot delta matmul kernel (Pallas TPU).

Merge-free multi-tenant serving's hot spot (DESIGN.md §5): compute

    y[b] = x[b] @ (W overlaid with slot b's sparse replace-delta)

without ever materializing a merged weight copy per adapter.  One base W
stays resident; each slot of a decode batch carries its own (idx, val)
delta gathered from the paged adapter pool, so a single dispatch serves a
batch that mixes adapters per slot.

The kernel tiles W column-blocks of BN and relies on the same structural
property as `scatter_merge.py`: entries sorted in COLUMN-MAJOR order
(key = col * rows + row) land in col-block j as one contiguous window of
the entry stream, which the wrapper (`ops.delta_matmul`) pads to a fixed
capacity K.  Per (slot, col-block) grid cell the scatter is a two-sided
one-hot deposit against iota (VPU work, no dynamic addressing):

    row_oh[e, r] = (row[e] == r) & valid[e]          # (K, d)
    col_oh[e, c] = (col[e] - j*BN == c) & valid[e]   # (K, BN)
    dep  = (row_oh * val).T @ col_oh                 # (d, BN) deposited
    hit  = row_oh.T @ col_oh > 0                     # unique entries: 0/1
    W_b  = where(hit, dep, W_blk)                    # replace, bitwise
    y    = x[b] @ W_b                                # the engine's dot

The deposit dots run at HIGHEST precision (the TPU default would truncate
delta-value mantissas to bf16 and break the bitwise-replace contract);
the final x @ W_b dot runs at DEFAULT precision — exactly the precision
of the dense engine's `x @ w`, which is what makes pool-mode decode rows
bitwise-equal to merge-on-load serving.

Unlike scatter-merge there is no cheap exact post-fix for a window that
overflows (a missed entry perturbs a whole output column dot), so the
wrapper sizes K to the worst case when it cannot prove a tighter bound —
correctness never depends on a capacity heuristic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, keyw_ref, valw_ref, w_ref, out_ref, *, rows: int, bn: int):
    j = pl.program_id(1)
    keyw = keyw_ref[0, 0, :]                     # (K,) col-major keys, -1 pad
    valid = keyw >= 0
    keyc = jnp.maximum(keyw, 0)
    col_loc = keyc // rows - j * bn              # local col in [0, bn)
    row = keyc % rows                            # row in [0, rows)
    k = keyw.shape[0]

    iota_r = jax.lax.broadcasted_iota(jnp.int32, (k, rows), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (k, bn), 1)
    row_oh = ((row[:, None] == iota_r) & valid[:, None]).astype(jnp.float32)
    col_oh = ((col_loc[:, None] == iota_c) & valid[:, None]).astype(
        jnp.float32)

    vals = valw_ref[0, 0, :].astype(jnp.float32)             # (K,)
    contract = (((0,), (0,)), ((), ()))                      # sum over K
    # HIGHEST precision: deposits must carry the delta values bit-exact
    dep = jax.lax.dot_general(row_oh * vals[:, None], col_oh, contract,
                              precision=jax.lax.Precision.HIGHEST)
    cnt = jax.lax.dot_general(row_oh, col_oh, contract,
                              precision=jax.lax.Precision.HIGHEST)
    w_blk = w_ref[...].astype(jnp.float32)                   # (rows, bn)
    merged = jnp.where(cnt > 0, dep, w_blk)

    x_row = x_ref[...].astype(jnp.float32)                   # (1, rows)
    # DEFAULT precision: the dense engine's `x @ w` dot, bit for bit
    out_ref[...] = jax.lax.dot(x_row, merged).astype(out_ref.dtype)


def delta_matmul_blocks(x, w, keyw, valw, *, bn: int,
                        interpret: bool = True):
    """x: (B, rows); w: (rows, NB*BN); keyw/valw: (B, NB, K).

    keyw entries are COLUMN-MAJOR flat keys (col * rows + row) into the
    un-padded (rows, cols) matrix, -1 = padded window slot.  Returns
    y (B, NB*BN) in result dtype — columns beyond the real `cols` are the
    base matmul of zero-padded weight columns and are sliced by the caller.
    """
    b, rows = x.shape
    nb = keyw.shape[1]
    k = keyw.shape[2]
    assert w.shape == (rows, nb * bn), (w.shape, rows, nb, bn)
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    kern = functools.partial(_kernel, rows=rows, bn=bn)
    return pl.pallas_call(
        kern,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, rows), lambda s, j: (s, 0)),      # x row
            pl.BlockSpec((1, 1, k), lambda s, j: (s, j, 0)),   # key windows
            pl.BlockSpec((1, 1, k), lambda s, j: (s, j, 0)),   # val windows
            pl.BlockSpec((rows, bn), lambda s, j: (0, j)),     # w col-block
        ],
        out_specs=pl.BlockSpec((1, bn), lambda s, j: (s, j)),
        out_shape=jax.ShapeDtypeStruct((b, nb * bn), out_dtype),
        interpret=interpret,
    )(x, keyw, valw, w)
