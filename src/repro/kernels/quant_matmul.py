"""Fused dequant + overlay matmul kernel (Pallas TPU).

Quantized-base serving's hot spot (DESIGN.md §12): compute

    y[b] = x[b] @ (dequant(Q, scale) overlaid with the principal
                   (idx, val) entries, then slot b's adapter delta)

without ever materializing the dequantized weight in HBM.  The int8
base Q is the ONE resident copy; each grid cell dequantizes its
(rows, BN) tile in VMEM (`Q_blk * scale_blk` in f32), then scatters the
high-precision overlay in the epilogue — first the principal-weight
entries shared by every slot, then the per-slot adapter delta, so a
colliding adapter entry overrides the principal value exactly like the
sequential lax scatters of the fallback.

The scatter mechanics are `delta_matmul.py`'s: entries arrive re-keyed
COLUMN-MAJOR (key = col * rows + row, -1 = pad) so col-block j's entries
form one contiguous window, deposited via two-sided one-hot dots at
HIGHEST precision (bit-exact single-entry deposits).  The final
x @ merged dot runs in f32 at DEFAULT precision — the same arithmetic
as the lax fallback's dot over the fully dequantized matrix, which is
what makes kernel, fallback, and `ref.quant_matmul` bitwise-identical
(the BENCH_quant `matches_ref` contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _deposit(keyw, vals, base, *, j, rows: int, bn: int):
    """Replace-deposit one -1-padded column-major entry window into the
    (rows, bn) f32 tile `base` — delta_matmul.py's one-hot scatter."""
    valid = keyw >= 0
    keyc = jnp.maximum(keyw, 0)
    col_loc = keyc // rows - j * bn              # local col in [0, bn)
    row = keyc % rows
    k = keyw.shape[0]

    iota_r = jax.lax.broadcasted_iota(jnp.int32, (k, rows), 1)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (k, bn), 1)
    row_oh = ((row[:, None] == iota_r) & valid[:, None]).astype(jnp.float32)
    col_oh = ((col_loc[:, None] == iota_c) & valid[:, None]).astype(
        jnp.float32)

    contract = (((0,), (0,)), ((), ()))          # sum over K
    # HIGHEST precision: deposits must carry the overlay values bit-exact
    dep = jax.lax.dot_general(row_oh * vals[:, None], col_oh, contract,
                              precision=jax.lax.Precision.HIGHEST)
    cnt = jax.lax.dot_general(row_oh, col_oh, contract,
                              precision=jax.lax.Precision.HIGHEST)
    return jnp.where(cnt > 0, dep, base)


def _kernel(x_ref, pkeyw_ref, pvalw_ref, dkeyw_ref, dvalw_ref, q_ref, s_ref,
            out_ref, *, rows: int, bn: int):
    j = pl.program_id(1)
    # dequantize the int8 tile in VMEM: elementwise, so bitwise-equal to
    # the same elements of the full dequantized matrix
    w_blk = q_ref[...].astype(jnp.float32) * s_ref[...]      # (rows, bn)
    merged = _deposit(pkeyw_ref[0, 0, :],
                      pvalw_ref[0, 0, :].astype(jnp.float32),
                      w_blk, j=j, rows=rows, bn=bn)          # principal
    merged = _deposit(dkeyw_ref[0, 0, :],
                      dvalw_ref[0, 0, :].astype(jnp.float32),
                      merged, j=j, rows=rows, bn=bn)         # slot delta
    x_row = x_ref[...].astype(jnp.float32)                   # (1, rows)
    # DEFAULT precision: the fallback's f32 `x @ merged` dot, bit for bit
    out_ref[...] = jax.lax.dot(x_row, merged).astype(out_ref.dtype)


def quant_matmul_blocks(x, q, scale, pkeyw, pvalw, dkeyw, dvalw, *, bn: int,
                        interpret: bool = True):
    """x: (B, rows); q: (rows, NB*BN) int8; scale: (1, NB*BN) f32;
    pkeyw/pvalw: (1, NB, Kp) principal windows shared by every slot;
    dkeyw/dvalw: (B, NB, Kd) per-slot delta windows, or (1, NB, Kd)
    shared (the broadcast b == 1 overlay).

    Window entries are COLUMN-MAJOR flat keys (col * rows + row) into the
    un-padded (rows, cols) matrix, -1 = padded slot.  Returns y
    (B, NB*BN) in x.dtype — columns beyond the real `cols` multiply
    zero-padded q columns and are sliced by the caller.
    """
    b, rows = x.shape
    nb = pkeyw.shape[1]
    kp = pkeyw.shape[2]
    kd = dkeyw.shape[2]
    assert q.shape == (rows, nb * bn), (q.shape, rows, nb, bn)
    assert scale.shape == (1, nb * bn), (scale.shape, nb, bn)
    d_shared = dkeyw.shape[0] == 1
    d_map = (lambda s, j: (0, j, 0)) if d_shared else (lambda s, j: (s, j, 0))
    kern = functools.partial(_kernel, rows=rows, bn=bn)
    return pl.pallas_call(
        kern,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, rows), lambda s, j: (s, 0)),      # x row
            pl.BlockSpec((1, 1, kp), lambda s, j: (0, j, 0)),  # principal key
            pl.BlockSpec((1, 1, kp), lambda s, j: (0, j, 0)),  # principal val
            pl.BlockSpec((1, 1, kd), d_map),                   # delta keys
            pl.BlockSpec((1, 1, kd), d_map),                   # delta vals
            pl.BlockSpec((rows, bn), lambda s, j: (0, j)),     # q col-block
            pl.BlockSpec((1, bn), lambda s, j: (0, j)),        # scale block
        ],
        out_specs=pl.BlockSpec((1, bn), lambda s, j: (s, j)),
        out_shape=jax.ShapeDtypeStruct((b, nb * bn), x.dtype),
        interpret=interpret,
    )(x, pkeyw, pvalw, dkeyw, dvalw, q, scale)
