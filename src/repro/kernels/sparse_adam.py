"""Fused sparse-AdamW kernel (Pallas TPU): gather -> Adam -> scatter.

TPUs have no efficient random gather/scatter, so the kernel exploits the one
structural property LIFT guarantees: **indices are sorted ascending**.  The
flat parameter vector is processed in contiguous blocks of BN entries; the
selected indices falling in block b occupy a contiguous *window* of the
(idx, m, v) vectors, [starts[b], starts[b+1]).  The XLA-side wrapper
(ops.py) pads each window to a fixed capacity K and hands the kernel
windowed views, so all kernel memory access is dense:

    grid = (N / BN,)
    p_blk (BN,)   g_blk (BN,)   idxw/mw/vw (K,) per block

In-block gather/scatter become one-hot matmuls against iota (MXU/VPU work,
no dynamic addressing):   sel[e, i] = (idxw[e] - b*BN == i)
    g_sel = sel @ g_blk          (gather)
    p'    = p_blk + sel^T @ dw   (scatter; windows are disjoint)

Entries beyond a window's capacity are handled by an exact XLA fallback in
ops.py (correctness never depends on the capacity heuristic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(hyper_ref, idxw_ref, mw_ref, vw_ref, p_ref, g_ref,
            po_ref, mo_ref, vo_ref, *, bn: int):
    b = pl.program_id(0)
    lr = hyper_ref[0, 0]
    b1 = hyper_ref[0, 1]
    b2 = hyper_ref[0, 2]
    eps = hyper_ref[0, 3]
    wd = hyper_ref[0, 4]
    c1 = hyper_ref[0, 5]          # 1 - b1**t
    c2 = hyper_ref[0, 6]          # 1 - b2**t

    idxw = idxw_ref[0, :]                            # (K,) int32, -1 = pad
    local = idxw - b * bn
    valid = (idxw >= 0)
    k = idxw.shape[0]

    iota = jax.lax.broadcasted_iota(jnp.int32, (k, bn), 1)
    sel = ((local[:, None] == iota) & valid[:, None]).astype(jnp.float32)

    p_blk = p_ref[0, :].astype(jnp.float32)          # (BN,)
    g_blk = g_ref[0, :].astype(jnp.float32)

    g_sel = sel @ g_blk                              # (K,) gather
    w_sel = sel @ p_blk

    m2 = b1 * mw_ref[0, :] + (1.0 - b1) * g_sel
    v2 = b2 * vw_ref[0, :] + (1.0 - b2) * g_sel * g_sel
    upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps) + wd * w_sel
    dw = jnp.where(valid, -lr * upd, 0.0)

    po_ref[0, :] = (p_blk + dw @ sel).astype(po_ref.dtype)   # scatter
    mo_ref[0, :] = jnp.where(valid, m2, mw_ref[0, :])
    vo_ref[0, :] = jnp.where(valid, v2, vw_ref[0, :])


def sparse_adam_blocks(p, g, idxw, mw, vw, hyper, *, bn: int,
                       interpret: bool = True):
    """p, g: (NB, BN); idxw/mw/vw: (NB, K); hyper: (1, 7) f32.

    Returns (p', m'_windows, v'_windows) with the same shapes.
    """
    nb, bn_ = p.shape
    assert bn_ == bn
    k = idxw.shape[1]
    kern = functools.partial(_kernel, bn=bn)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 7), lambda b: (0, 0)),      # hyper
            pl.BlockSpec((1, k), lambda b: (b, 0)),      # idx windows
            pl.BlockSpec((1, k), lambda b: (b, 0)),      # m windows
            pl.BlockSpec((1, k), lambda b: (b, 0)),      # v windows
            pl.BlockSpec((1, bn), lambda b: (b, 0)),     # p blocks
            pl.BlockSpec((1, bn), lambda b: (b, 0)),     # g blocks
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda b: (b, 0)),
            pl.BlockSpec((1, k), lambda b: (b, 0)),
            pl.BlockSpec((1, k), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bn), p.dtype),
            jax.ShapeDtypeStruct((nb, k), jnp.float32),
            jax.ShapeDtypeStruct((nb, k), jnp.float32),
        ],
        interpret=interpret,
    )(hyper, idxw, mw, vw, p, g)
