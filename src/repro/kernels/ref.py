"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ lowrank_mask
def lowrank_abs(a: jax.Array, b: jax.Array) -> jax.Array:
    """|A @ B^T| in fp32.  a: (m, r); b: (n, r)."""
    return jnp.abs(a.astype(jnp.float32) @ b.astype(jnp.float32).T)


def lowrank_count(a, b, tau) -> jax.Array:
    return jnp.sum(lowrank_abs(a, b) > tau, dtype=jnp.int32)


def lowrank_mask(a, b, tau) -> jax.Array:
    return lowrank_abs(a, b) > tau


def lowrank_hist(a, b, lo, hi, nbins: int) -> jax.Array:
    """Histogram of |A B^T| over `nbins` uniform bins on [lo, hi); the last
    bin also catches >= hi, the first also catches < lo."""
    s = lowrank_abs(a, b)
    width = (hi - lo) / nbins
    ids = jnp.clip(jnp.floor((s - lo) / width), 0, nbins - 1).astype(jnp.int32)
    return jnp.zeros((nbins,), jnp.int32).at[ids.reshape(-1)].add(1)


def lowrank_absmax(a, b) -> jax.Array:
    return jnp.max(lowrank_abs(a, b))


def lowrank_block_scores(a, b, bs: int) -> jax.Array:
    """(m/bs, n/bs) block-summed |A B^T| — the structured-LIFT score
    matrix (paper App. G.7, Table 17): each entry sums a (bs x bs) tile
    of element scores.  The dense oracle every block-summed kernel stat
    (count / absmax / hist / compact) is checked against."""
    s = lowrank_abs(a, b)
    m, n = s.shape
    return s.reshape(m // bs, bs, n // bs, bs).sum(axis=(1, 3))


def block_threshold_indices(a, b, tau, kb: int, bs: int) -> jax.Array:
    """Flat BLOCK indices of the kb smallest-index blocks with block score
    > tau, sorted ascending, slot-padded — the oracle for the structured
    compact path (`ops.lift_indices(block_size=bs)` before expansion)."""
    s = lowrank_block_scores(a, b, bs).reshape(-1)
    cand = jnp.sort(jnp.where(s > tau, jnp.arange(s.size, dtype=jnp.int32),
                              jnp.int32(2 ** 31 - 1)))
    slot = jnp.arange(kb, dtype=jnp.int32)
    return jnp.where(slot < jnp.sum(s > tau), cand[:kb], slot)


def threshold_indices(a, b, tau, k: int) -> jax.Array:
    """Flat indices of the k smallest-index entries with |A B^T| > tau,
    sorted ascending, padded with slot positions when fewer than k exist —
    the oracle for the streaming compact path (`ops.lift_indices`)."""
    s = lowrank_abs(a, b).reshape(-1)
    cand = jnp.sort(jnp.where(s > tau, jnp.arange(s.size, dtype=jnp.int32),
                              jnp.int32(2 ** 31 - 1)))
    slot = jnp.arange(k, dtype=jnp.int32)
    return jnp.where(slot < jnp.sum(s > tau), cand[:k], slot)


# ---------------------------------------------------------- scatter merge
def sparse_scatter_merge(base, idx, val, mode: str = "replace"):
    """Dense oracle for `ops.sparse_scatter_merge`.

    base: (ns, N); idx: (ns, k) int32 sorted ascending — entries >= N are
    sentinel pads and write nothing; val: (ns, k).
    mode "replace" writes val at idx bitwise; mode "add" accumulates in
    fp32 and casts back to base dtype (the kernel's canonical semantics).
    """
    def one(b, i, v):
        if mode == "add":
            out = b.astype(jnp.float32).at[i].add(
                v.astype(jnp.float32), mode="drop")
            return out.astype(b.dtype)
        return b.at[i].set(v.astype(b.dtype), mode="drop")

    return jax.vmap(one)(base, idx, val)


# -------------------------------------------------- delta matmul (serving)
def delta_matmul(x, w, idx, val):
    """Dense oracle for `ops.delta_matmul` (merge-free adapter serving).

    x: (B, d); w: (d, f); idx: (B, k) int32 row-major flat replace
    indices (sentinel >= d*f writes nothing); val: (B, k).  Slot b's
    output row is the row the merge-on-load engine would compute: merge
    the slot's delta densely, run the engine's full-batch `x @ w` dot,
    and keep row b — the per-slot composition both backends must match
    bitwise.
    """
    b = x.shape[0]
    wf = w.reshape(-1)
    rows = []
    for s in range(b):
        wm = wf.at[idx[s]].set(val[s].astype(w.dtype),
                               mode="drop").reshape(w.shape)
        rows.append((x @ wm)[s])
    return jnp.stack(rows)


# ---------------------------------------------- quantized-base matmul
def quant_merged(q, scale, idx, val):
    """(rows, cols) f32 merged weight: dequantize the int8 base, then
    REPLACE the principal-overlay entries with their full-precision
    values.  q: (rows, cols) int8; scale: (1, cols) | (1, 1) f32;
    idx: (k,) int32 row-major flat, sorted; val: (k,)."""
    m = q.astype(jnp.float32) * scale
    return m.reshape(-1).at[idx].set(
        val.astype(jnp.float32), mode="drop").reshape(q.shape)


def quant_matmul(x, q, scale, idx, val, didx=None, dval=None):
    """Dense oracle for `ops.quant_matmul`: dequantize, merge the
    principal overlay, optionally merge slot b's adapter delta (which
    overrides principal entries on collision — the sequential-scatter
    order every backend implements), then the f32 matmul.

    x: (B, d); didx/dval: (B, kd) per-slot replace entries (sentinel
    >= d*f writes nothing) or None.  Returns (B, f) in x.dtype.
    """
    merged = quant_merged(q, scale, idx, val)
    xf = x.astype(jnp.float32)
    if didx is None:
        return (xf @ merged).astype(x.dtype)
    mf = merged.reshape(-1)
    rows = []
    for s in range(x.shape[0]):
        wm = mf.at[didx[s]].set(dval[s].astype(jnp.float32),
                                mode="drop").reshape(merged.shape)
        rows.append((xf @ wm)[s])
    return jnp.stack(rows).astype(x.dtype)


# ------------------------------------------------------------- sparse_adam
def sparse_adam(p, g, idx, m, v, *, lr, b1, b2, eps, wd, step):
    """Reference sparse AdamW on flat vectors.

    p, g: (N,); idx: (k,) sorted unique int32; m, v: (k,).
    Returns (p', m', v') — only entries at idx change.
    """
    p32 = p.astype(jnp.float32)
    g_sel = g.astype(jnp.float32)[idx]
    m2 = b1 * m + (1 - b1) * g_sel
    v2 = b2 * v + (1 - b2) * g_sel * g_sel
    c1 = 1.0 - b1 ** step
    c2 = 1.0 - b2 ** step
    w = p32[idx]
    upd = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps) + wd * w
    p_new = p32.at[idx].set(w - lr * upd)
    return p_new.astype(p.dtype), m2, v2


# -------------------------------------------------------- paged attention
def paged_attention(q, k_pages, v_pages, block_tables, positions,
                    scale=None):
    """Dense oracle for `ops.paged_attention_decode` (one decode token).

    q: (B, H, D) — this step's query per sequence;
    k_pages / v_pages: (P, ps, H_kv, D) — the shared page pool;
    block_tables: (B, nmax) int32 — logical page j of sequence b lives in
    physical page block_tables[b, j];
    positions: (B,) int32 — the query's position; keys at logical token
    index <= positions[b] are attended, everything else (unwritten slots,
    stale pages, other sequences' trash) is masked.

    fp32 softmax over the fully gathered logical token stream.
    """
    B, H, D = q.shape
    P, ps, hkv, _ = k_pages.shape
    nmax = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale
    k = k_pages[block_tables].reshape(B, nmax * ps, hkv, D)
    v = v_pages[block_tables].reshape(B, nmax * ps, hkv, D)
    reps = H // hkv
    kf = jnp.repeat(k.astype(jnp.float32), reps, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), reps, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32), kf) * scale
    t = jnp.arange(nmax * ps)
    ok = t[None, :] <= positions[:, None]
    s = jnp.where(ok[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bht,bthd->bhd", p, vf)
    return o.astype(q.dtype)


def paged_attention_multi(q, k_pages, v_pages, block_tables, positions,
                          scale=None):
    """Dense oracle for `ops.paged_attention_verify` (n_q consecutive
    decode tokens per sequence — speculative verify).

    q: (B, n_q, H, D) — queries at logical positions positions[b] + i;
    query i attends keys at token index <= positions[b] + i, so each
    draft position sees the drafts before it and nothing after.  The
    rest of the contract matches `paged_attention`.

    fp32 softmax over the fully gathered logical token stream.
    """
    B, nq, H, D = q.shape
    P, ps, hkv, _ = k_pages.shape
    nmax = block_tables.shape[1]
    scale = D ** -0.5 if scale is None else scale
    k = k_pages[block_tables].reshape(B, nmax * ps, hkv, D)
    v = v_pages[block_tables].reshape(B, nmax * ps, hkv, D)
    reps = H // hkv
    kf = jnp.repeat(k.astype(jnp.float32), reps, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), reps, axis=2)
    s = jnp.einsum("bqhd,bthd->bqht", q.astype(jnp.float32), kf) * scale
    t = jnp.arange(nmax * ps)
    qpos = positions[:, None] + jnp.arange(nq)[None, :]   # (B, nq)
    ok = t[None, None, :] <= qpos[:, :, None]             # (B, nq, T)
    s = jnp.where(ok[:, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqht,bthd->bqhd", p, vf)
    return o.astype(q.dtype)


# -------------------------------------------------------- flash attention
def naive_attention(q, k, v, causal=True, scale=None):
    """q,k,v: (B, S, H, D) -> o (B, S, H, D), fp32 softmax."""
    B, S, H, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
