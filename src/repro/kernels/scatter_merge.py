"""Sparse delta scatter-merge kernel (Pallas TPU).

DeltaHub's serving-side hot spot (DESIGN.md §4): fold a (k,)-entry sparse
delta `(indices, values)` into a flat base weight vector.  TPUs have no
efficient random scatter, so the kernel exploits the one structural
property every LIFT artifact guarantees: **indices are sorted ascending**.
The flat vector is processed in contiguous blocks of BN entries; the delta
entries landing in block b occupy a contiguous *window* of the (idx, val)
vectors, [starts[b], starts[b+1]).  The XLA-side wrapper
(`ops.sparse_scatter_merge`) pads each window to a fixed capacity K and
hands the kernel windowed views, so all kernel memory access is dense —
the same window trick as the sparse-Adam kernel:

    grid = (NS, N / BN)
    base_blk (BN,)   idxw/valw (K,) per (stack, block)

In-block scatter is a one-hot reduction against iota (VPU work, no dynamic
addressing):

    onehot[e, i] = (idxw[e] - b*BN == i) & valid[e]
    dep          = valw @ onehot                        # (BN,) deposited
    out          = where(any_e onehot, dep, base)       # mode "replace"
    out          = base + dep                           # mode "add"

"replace" writes the delta value bitwise (ties never happen: indices are
unique per matrix), which is what makes base + replace-delta reproduce the
fine-tuned checkpoint exactly.  Entries beyond a window's capacity are
corrected by an exact XLA fallback in ops.py — correctness never depends
on the capacity heuristic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idxw_ref, valw_ref, base_ref, out_ref, *, bn: int, mode: str):
    b = pl.program_id(1)
    idxw = idxw_ref[0, 0, :]                         # (K,) int32, -1 = pad
    local = idxw - b * bn
    valid = idxw >= 0
    k = idxw.shape[0]

    iota = jax.lax.broadcasted_iota(jnp.int32, (k, bn), 1)
    onehot_b = (local[:, None] == iota) & valid[:, None]

    base_blk = base_ref[0, 0, :].astype(jnp.float32)  # (BN,)
    vals = valw_ref[0, 0, :].astype(jnp.float32)      # (K,)
    # HIGHEST precision: the TPU default downcasts f32 matmul operands to
    # bf16, which would truncate delta-value mantissas and silently break
    # the bitwise-replace contract on the one backend that compiles this
    dep = jax.lax.dot(vals, onehot_b.astype(jnp.float32),
                      precision=jax.lax.Precision.HIGHEST)  # (BN,) scatter

    if mode == "add":
        out = base_blk + dep
    else:                                             # replace
        hit = jnp.any(onehot_b, axis=0)
        out = jnp.where(hit, dep, base_blk)
    out_ref[0, 0, :] = out.astype(out_ref.dtype)


def scatter_merge_blocks(base, idxw, valw, *, bn: int, mode: str = "replace",
                         interpret: bool = True):
    """base: (NS, NB, BN); idxw/valw: (NS, NB, K).

    Returns merged (NS, NB, BN) in base dtype.  idxw entries are GLOBAL
    flat indices into the (NB*BN,) vector, -1 = padded window slot.
    """
    ns, nb, bn_ = base.shape
    assert bn_ == bn, (bn_, bn)
    k = idxw.shape[2]
    kern = functools.partial(_kernel, bn=bn, mode=mode)
    return pl.pallas_call(
        kern,
        grid=(ns, nb),
        in_specs=[
            pl.BlockSpec((1, 1, k), lambda s, b: (s, b, 0)),    # idx windows
            pl.BlockSpec((1, 1, k), lambda s, b: (s, b, 0)),    # val windows
            pl.BlockSpec((1, 1, bn), lambda s, b: (s, b, 0)),   # base blocks
        ],
        out_specs=pl.BlockSpec((1, 1, bn), lambda s, b: (s, b, 0)),
        out_shape=jax.ShapeDtypeStruct((ns, nb, bn), base.dtype),
        interpret=interpret,
    )(idxw, valw, base)
