from repro.ft.resilience import (  # noqa: F401
    PreemptionSimulator, StragglerMonitor, auto_resume,
)
