"""Fault tolerance at 1000-node scale, exercised on one host.

Three mechanisms (DESIGN.md §6):

* PreemptionSimulator — stands in for the TPU preemption signal
  (SIGTERM / maintenance event).  Tests and examples inject "crash at
  step K"; the launcher's auto_resume path must then restore bit-exact.

* StragglerMonitor — per-step wall-time EWMA + variance.  On real fleets a
  rank whose step time exceeds mean + z*sigma for `patience` consecutive
  steps is flagged; the policy hook decides between (a) ignore, (b) trigger
  checkpoint-and-reconfigure (elastic scale-down).  The detection math is
  hardware-independent and fully unit-tested here.

* auto_resume — pick the newest complete checkpoint (atomicity comes from
  CheckpointManager's rename-commit) and rebuild state on the CURRENT mesh,
  which may have a different shape than the writer's (elastic restart).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


class PreemptionSimulator:
    """Raises SystemExit at a scheduled step — like a maintenance event."""

    def __init__(self, crash_at_step: Optional[int] = None):
        self.crash_at_step = crash_at_step
        self.fired = False

    def check(self, step: int):
        if self.crash_at_step is not None and step >= self.crash_at_step \
                and not self.fired:
            self.fired = True
            raise SystemExit(f"[preemption] simulated at step {step}")


@dataclasses.dataclass
class StragglerVerdict:
    is_straggler: bool
    z_score: float
    mean: float


class StragglerMonitor:
    def __init__(self, z_threshold: float = 3.0, patience: int = 3,
                 ema: float = 0.9):
        self.z = z_threshold
        self.patience = patience
        self.ema = ema
        self.mean: Optional[float] = None
        self.var: float = 0.0
        self.strikes: dict[int, int] = {}

    def observe(self, rank: int, step_time: float) -> StragglerVerdict:
        if self.mean is None:
            self.mean, self.var = step_time, (0.25 * step_time) ** 2
            return StragglerVerdict(False, 0.0, self.mean)
        sd = max(self.var ** 0.5, 1e-9)
        z = (step_time - self.mean) / sd
        flagged = z > self.z
        self.strikes[rank] = self.strikes.get(rank, 0) + 1 if flagged else 0
        # only non-outliers update the baseline (a straggler must not drag
        # the fleet mean up and mask itself)
        if not flagged:
            d = step_time - self.mean
            self.mean += (1 - self.ema) * d
            self.var = self.ema * (self.var + (1 - self.ema) * d * d)
        return StragglerVerdict(self.strikes.get(rank, 0) >= self.patience,
                                z, self.mean)


def auto_resume(ckpt_manager, like_state, shardings=None):
    """-> (state, step) from the newest checkpoint, or (None, 0)."""
    step = ckpt_manager.latest_step()
    if step is None:
        return None, 0
    state = ckpt_manager.restore(step, like_state, shardings)
    return state, step


class StepTimer:
    def __init__(self):
        self.t = time.monotonic()

    def lap(self) -> float:
        now = time.monotonic()
        dt = now - self.t
        self.t = now
        return dt
