"""Unified telemetry: metrics registry, per-request tracing, and the
compile/trace auditor (DESIGN.md §11, docs/OBSERVABILITY.md).

One `ObsContext` bundles the three concerns a subsystem needs:

  * `registry` — counters/gauges/histograms (`obs.registry`), host-side
    only (incrementing never adds a device sync);
  * `tracer` — per-request/per-step spans (`obs.tracing`), disabled by
    default (enable via `launch/serve.py --trace-out` or by passing an
    enabled Tracer);
  * `auditor` — the (jit name, abstract-shape fingerprint) compile
    ledger (`obs.audit`), SHARED process-wide by default so every
    engine/trainer in the process feeds one CI-gated audit.

Engines and trainers take `obs: ObsContext | None`; None gives them a
fresh private registry + the process defaults (`engine_context()`), so
per-engine stats never collide while the compile audit stays global.
`ObsContext.disabled()` is the zero-overhead configuration the
`obs/` benchmark row compares against (benchmarks/paged_decode.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.audit import (CompileAuditor, InstrumentedJit,
                             call_fingerprint, load_manifest)
from repro.obs.registry import (Counter, Gauge, Histogram,
                                MetricsRegistry, log_edges,
                                render_snapshot)
from repro.obs.tracing import (Span, Tracer, read_jsonl,
                               request_breakdown)

__all__ = [
    "CompileAuditor", "Counter", "Gauge", "Histogram", "InstrumentedJit",
    "MetricsRegistry", "ObsContext", "Span", "Tracer", "call_fingerprint",
    "default", "engine_context", "instrument_jit", "load_manifest",
    "log_edges", "read_jsonl", "render_snapshot", "request_breakdown",
    "stat_view",
]


def stat_view(metric: str):
    """Registry-backed attribute view for a class with an `obs`
    attribute: the counter in `self.obs.registry` is the ONE store; the
    legacy attribute read/write sites (engines, benches, tests) keep
    working unchanged (DESIGN.md §11)."""
    def _get(self):
        return int(self.obs.registry.counter(metric).value)

    def _set(self, v):
        self.obs.registry.counter(metric).set(int(v))

    return property(_get, _set)


@dataclasses.dataclass
class ObsContext:
    registry: MetricsRegistry
    tracer: Tracer
    auditor: CompileAuditor
    enabled: bool = True

    @classmethod
    def fresh(cls, *, trace: bool = False) -> "ObsContext":
        """Fully private context (tests, benchmarks): own registry, own
        tracer, own auditor."""
        reg = MetricsRegistry()
        return cls(registry=reg, tracer=Tracer(enabled=trace),
                   auditor=CompileAuditor(registry=reg))

    @classmethod
    def disabled(cls) -> "ObsContext":
        """No tracing, no fingerprinting: `instrument_jit` returns the
        raw jitted callable; registry stays live (attribute-view
        bookkeeping costs a couple of host adds per dispatch)."""
        ctx = cls.fresh()
        ctx.enabled = False
        return ctx


_DEFAULT: Optional[ObsContext] = None


def default() -> ObsContext:
    """The process-wide context (lazy).  `launch/serve.py` and
    `launch/train.py` snapshot/audit/export THIS context."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = ObsContext.fresh()
    return _DEFAULT


def engine_context() -> ObsContext:
    """Default context for an engine built without an explicit one: a
    PRIVATE registry (two engines in one process never mix stats) with
    the process-wide tracer and auditor (one trace file, one compile
    audit per run)."""
    d = default()
    return ObsContext(registry=MetricsRegistry(), tracer=d.tracer,
                      auditor=d.auditor, enabled=d.enabled)


def instrument_jit(fn, *, name: str, obs: Optional[ObsContext] = None,
                   static_argnames=(), static_argnums=(), **jit_kwargs):
    """THE way to create a jit entry point (DESIGN.md §11): wraps
    `jax.jit(fn, ...)` and records (name, abstract-shape fingerprint)
    per call into the context's auditor.  With a disabled context this
    returns the raw jitted callable — zero per-call overhead."""
    ctx = obs or default()
    if not ctx.enabled:
        import jax
        if isinstance(static_argnames, str):
            static_argnames = (static_argnames,)
        return jax.jit(fn, static_argnames=tuple(static_argnames) or None,
                       static_argnums=tuple(static_argnums) or None,
                       **jit_kwargs)
    return InstrumentedJit(fn, name=name, auditor=ctx.auditor,
                           static_argnames=static_argnames,
                           static_argnums=static_argnums, **jit_kwargs)
