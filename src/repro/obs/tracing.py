"""Per-request span tracing (DESIGN.md §11).

A `Span` is one timed interval with a category, an optional subject
request (`uid`), and — because serving dispatches are BATCHED — two uid
lists:

  * `uids`: the requests this span is *about* (the prefilling request,
    the decoding slots in the dispatch);
  * `co_uids`: other requests that were placed in the batch while this
    span ran but were not its subject (a decoding request waiting out
    another request's prefill dispatch).

The engine records spans as TILES of its step loop — admission/prefill,
draft, decode/verify dispatch (including the `np.asarray` readback,
which is where the device sync actually lands), accept bookkeeping — so
for any request, `queue wait + sum(spans containing it)` reconstructs
its end-to-end latency: `request_breakdown` does exactly that, and
tests/test_obs.py holds the decomposition within 5% of the measured
latency.  Training uses the same tracer for step/refresh/checkpoint
spans (launch/train.py).

Clock: `time.perf_counter()` relative to the tracer's epoch, so spans
from one process share a timeline.  The tracer is BOUNDED
(`max_spans`, default 1_000_000): past the bound new spans are counted
in `dropped` instead of retained — tracing never grows without limit.

Hot path: the engines do NOT build `Span` objects per step — in engine
context every Python call runs cold (evicted between ~ms-apart steps)
and costs ~10x its tight-loop time, so `tile()` appends ONE raw tuple
of perf_counter stamps and `drain()` materializes Spans and feeds the
latency histograms later, off the step path (the ring-buffer-and-drain
shape every low-overhead tracer uses).  Reading `tracer.spans` or
calling `write_jsonl` drains implicitly; the buffer self-drains past
`_DEFER_BOUND` records so it stays bounded too.

Export: `write_jsonl` emits one JSON object per span; `read_jsonl`
loads them back (round-trip tested).  A disabled tracer (the default —
`launch/serve.py --trace-out` enables it) records nothing and costs one
attribute check per call.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional


@dataclasses.dataclass(slots=True)
class Span:
    name: str                     # e.g. "prefill", "verify", "ckpt.save"
    cat: str                      # queue|prefill|decode|verify|pool|train|...
    t0: float                     # seconds since tracer epoch
    t1: float = 0.0
    uid: Optional[int] = None     # single-subject convenience
    uids: tuple = ()              # subject requests
    co_uids: tuple = ()           # co-resident (batched) requests
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        d = {"name": self.name, "cat": self.cat,
             "t0": self.t0, "t1": self.t1, "dur": self.dur}
        if self.uid is not None:
            d["uid"] = self.uid
        if self.uids:
            d["uids"] = list(self.uids)
        if self.co_uids:
            d["co_uids"] = list(self.co_uids)
        if self.attrs:
            d["attrs"] = self.attrs
        return d


_DEFER_BOUND = 8192          # raw tile records before a forced drain


class Tracer:
    def __init__(self, *, enabled: bool = True,
                 max_spans: int = 1_000_000):
        self.enabled = enabled
        self.max_spans = int(max_spans)
        self.epoch = time.perf_counter()
        self._spans: list[Span] = []
        self._defer: list[tuple] = []
        self.dropped = 0

    @property
    def spans(self) -> list:
        """Materialized span list (drains the hot-path tile buffer)."""
        if self._defer:
            self.drain()
        return self._spans

    # ------------------------------------------------------------ record
    def now(self) -> float:
        return time.perf_counter() - self.epoch

    def tile(self, name: str, cat: str, t0: float, t1: float,
             uids: tuple, co_uids: tuple, hist=None,
             attrs: Optional[dict] = None) -> None:
        """Hot-path tile record: ONE tuple append, nothing else.

        `t0`/`t1` are RAW `time.perf_counter()` stamps (not epoch-
        relative — the subtraction is deferred too); `hist`, when given,
        is a resolved `obs.registry.Histogram` that receives the tile
        duration at drain time.  Span construction, attr dicts and
        histogram bucketing all happen in `drain()`, off the engine
        step path."""
        self._defer.append((name, cat, t0, t1, uids, co_uids, hist, attrs))
        if len(self._defer) >= _DEFER_BOUND:
            self.drain()

    def drain(self) -> None:
        """Materialize buffered tile records: retain Spans (when
        enabled) and feed the tile histograms.  Idempotent; called
        implicitly by `spans`/`write_jsonl` and by the engines at their
        stats read points."""
        raw, self._defer = self._defer, []
        epoch = self.epoch
        for name, cat, t0, t1, uids, co_uids, hist, attrs in raw:
            if self.enabled:
                self._retain(Span(name=name, cat=cat, t0=t0 - epoch,
                                  t1=t1 - epoch, uids=uids,
                                  co_uids=co_uids,
                                  attrs=dict(attrs) if attrs else {}))
            if hist is not None:
                hist.observe(t1 - t0)

    def begin(self, name: str, cat: str, *, uid: Optional[int] = None,
              uids: tuple = (), co_uids: tuple = (),
              **attrs) -> Optional[Span]:
        """Open a span; `end` closes and retains it.  Returns None when
        disabled — `end(None)` is a no-op, so call sites stay linear."""
        if not self.enabled:
            return None
        return Span(name=name, cat=cat, t0=self.now(), uid=uid,
                    uids=tuple(uids), co_uids=tuple(co_uids), attrs=attrs)

    def end(self, span: Optional[Span], **attrs) -> Optional[Span]:
        if span is None:
            return None
        span.t1 = self.now()
        if attrs:
            span.attrs.update(attrs)
        self._retain(span)
        return span

    def add(self, name: str, cat: str, t0: float, t1: float, *,
            uid: Optional[int] = None, uids: tuple = (),
            co_uids: tuple = (), **attrs) -> Optional[Span]:
        """Record an externally-timed span (queue waits: the submit
        timestamp is taken long before the span is emitted)."""
        if not self.enabled:
            return None
        span = Span(name=name, cat=cat, t0=t0, t1=t1, uid=uid,
                    uids=tuple(uids), co_uids=tuple(co_uids), attrs=attrs)
        self._retain(span)
        return span

    def _retain(self, span: Span) -> None:
        if len(self._spans) >= self.max_spans:
            self.dropped += 1
            return
        self._spans.append(span)

    # ------------------------------------------------------------ export
    def write_jsonl(self, path: str) -> int:
        """One JSON object per line, chronological by `t0` (drained tile
        records interleave with directly-added spans); returns the span
        count written."""
        spans = sorted(self.spans, key=lambda s: s.t0)
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps(s.to_dict(), sort_keys=True) + "\n")
        return len(spans)


def read_jsonl(path: str) -> list:
    """Load spans back as dicts (schema of `Span.to_dict`)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _span_dicts(spans) -> list:
    return [s.to_dict() if isinstance(s, Span) else s for s in spans]


def request_breakdown(spans) -> dict:
    """Per-request wall-time decomposition from a span list (Span objects
    or `to_dict` dicts).

    Returns {uid: {"total": s, "by_cat": {cat: s}, "e2e": s|None}} where
    `by_cat` sums subject spans by category, co-resident time lands
    under "batch" (the request sat in the batch while another request's
    dispatch ran), and `e2e` is the request's `cat == "request"`
    envelope span when one was recorded.  Subject/co tiles are disjoint
    by construction (the engine emits them as a tiling of its step
    loop), so `total` approximates the request's placed lifetime and
    `total + queue` its end-to-end latency.
    """
    out: dict = {}

    def slot(uid):
        return out.setdefault(uid, {"total": 0.0, "by_cat": {}, "e2e": None})

    for s in _span_dicts(spans):
        cat, dur = s["cat"], s["dur"]
        subjects = list(s.get("uids", ()))
        if s.get("uid") is not None and s["uid"] not in subjects:
            subjects.append(s["uid"])
        if cat == "request":
            for uid in subjects:
                slot(uid)["e2e"] = dur
            continue
        for uid in subjects:
            d = slot(uid)
            d["total"] += dur
            d["by_cat"][cat] = d["by_cat"].get(cat, 0.0) + dur
        for uid in s.get("co_uids", ()):
            d = slot(uid)
            d["total"] += dur
            d["by_cat"]["batch"] = d["by_cat"].get("batch", 0.0) + dur
    return out
