"""Compile/trace auditor (DESIGN.md §11).

Every `jax.jit` entry point in the serving engines, the drafter, the
SelectionEngine, the delta merger and the train step goes through ONE
helper — `instrument_jit(fn, name=...)` — which wraps `jax.jit` and
counts new traces per call.  Two detection paths:

  * **fast path** (jax exposes `_cache_size`): after each call the
    wrapper reads jax's own compiled-entry count — ONE cheap C++
    attribute call — and a delta is a new trace.  This is the ground
    truth the hot loops run under; it never touches the argument
    pytree (flattening a params tree per decode step costs ~10% of an
    interpret-mode pass — measured, benchmarks/paged_decode.py `obs/`
    row).
  * **fallback** (`call_fingerprint`): fingerprint the call's ABSTRACT
    shapes the way jax keys its trace cache — array leaves by
    (shape, dtype) (values never retrace), python scalars by type only
    (weak-typed: 3 vs 4 does NOT recompile), `static_argnames`/
    `static_argnums` by VALUE (changing a static arg IS a retrace).
    tests/test_obs.py holds the fingerprint equal to `_cache_size()`
    on all three behaviors.

The process-wide `CompileAuditor` counts compilations per name
(cross-checkable against jax's own `_cache_size()` per wrapper) and
`check()` compares the run against
a committed expected-compilations manifest — the system-wide CI gate
that turns today's hand-rolled `decode_compilations == 1` invariants
into one audit: any future silent re-trace regression (a per-prompt
prefill shape, a bucketing bypass, a scalar promoted to a traced shape)
shows up as a count over its manifest bound and fails the run loudly
(`launch/serve.py` / `launch/train.py --audit-manifest`).

Manifest schema (benchmarks/compilations_manifest.json):

    {"version": 1,
     "require_listed": true,
     "entries": {
        "serve.paged.decode":  {"exact": 1},
        "serve.paged.prefill_whole": {"max": 4},
        "selection.retry": {"any": true}}}

`exact` — observed names must compile exactly N traces; `max` — at most
N; `any` — tracked but unbounded (workload-keyed retraces that are the
design, e.g. overflow-retry capacity bumps).  With `require_listed`,
an instrumented name that is OBSERVED but missing from the manifest
fails too — new entry points must declare their compile budget.
Names never observed in a run are skipped (a train run does not see
serving entry points).
"""
from __future__ import annotations

import inspect
import json
import threading
from typing import Optional


def _leaf_sig(x):
    shape = getattr(x, "shape", None)
    if shape is not None and hasattr(x, "dtype"):
        return (tuple(shape), str(x.dtype))
    # python scalar / other hashable: jax traces these weak-typed by
    # TYPE — the value does not key the cache, so it must not key the
    # fingerprint either
    return ("py", type(x).__name__)


def call_fingerprint(args: tuple, kwargs: dict,
                     static: dict) -> tuple:
    """Hashable trace-cache key approximation for one call."""
    import jax
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    return (treedef,
            tuple(_leaf_sig(x) for x in leaves),
            tuple(sorted((k, repr(v)) for k, v in static.items())))


class InstrumentedJit:
    """`jax.jit(fn, **jit_kwargs)` plus per-call trace-fingerprint
    recording into an auditor.  Transparent to callers: `__call__` only
    forwards; `lower`/`_cache_size` proxy to the jitted callable."""

    def __init__(self, fn, *, name: str, auditor: "CompileAuditor",
                 static_argnames=(), static_argnums=(), **jit_kwargs):
        import jax
        if isinstance(static_argnames, str):
            static_argnames = (static_argnames,)
        self.name = name
        self.auditor = auditor
        self._static_names = tuple(static_argnames)
        self._static_nums = tuple(static_argnums)
        self._jfn = jax.jit(fn, static_argnames=static_argnames or None,
                            static_argnums=static_argnums or None,
                            **jit_kwargs)
        self._sig = None
        if self._static_names or self._static_nums:
            self._sig = inspect.signature(fn)
        self._cs_fn = getattr(self._jfn, "_cache_size", None)
        self._last_cs = 0
        self.calls = 0          # plain int: bumped lock-free per call,
                                # folded into the auditor at report time
        auditor.register(self)

    def _split_static(self, args, kwargs):
        if self._sig is None:
            return args, kwargs, {}
        bound = self._sig.bind_partial(*args, **kwargs)
        static = {}
        names = set(self._static_names)
        params = list(self._sig.parameters)
        for i in self._static_nums:
            names.add(params[i])
        dyn_args, dyn_kwargs = [], {}
        for i, (k, v) in enumerate(bound.arguments.items()):
            if k in names:
                static[k] = v
            elif i < len(args):
                dyn_args.append(v)
            else:
                dyn_kwargs[k] = v
        return tuple(dyn_args), dyn_kwargs, static

    def __call__(self, *args, **kwargs):
        if self._cs_fn is not None:
            # fast path: jax's own compiled-entry count, read AFTER the
            # dispatch — a delta is a new trace, attributed to this
            # call.  Cache hits (every hot-loop call) touch no lock.
            out = self._jfn(*args, **kwargs)
            self.calls += 1
            cs = self._cs_fn()
            if cs != self._last_cs:
                self.auditor.note_traces(self.name, cs - self._last_cs)
                self._last_cs = cs
            return out
        dyn_args, dyn_kwargs, static = self._split_static(args, kwargs)
        self.auditor.note_call(
            self.name, call_fingerprint(dyn_args, dyn_kwargs, static))
        return self._jfn(*args, **kwargs)

    def cache_size(self) -> Optional[int]:
        """jax's own compiled-entry count for THIS wrapper (None if the
        jax version has no `_cache_size`)."""
        f = getattr(self._jfn, "_cache_size", None)
        return f() if callable(f) else None

    def __getattr__(self, item):            # lower(), eval_shape(), ...
        return getattr(self._jfn, item)


class CompileAuditor:
    """Process-wide (name, fingerprint) trace ledger."""

    def __init__(self, registry=None):
        self._lock = threading.Lock()
        self._traces: dict[str, set] = {}       # name -> fingerprints
        self._compiled: dict[str, int] = {}     # name -> compilations
        self._calls: dict[str, int] = {}
        self._wrappers: list = []
        self.registry = registry                # optional MetricsRegistry

    def register(self, wrapper: InstrumentedJit) -> None:
        with self._lock:
            self._wrappers.append(wrapper)
            self._traces.setdefault(wrapper.name, set())
            self._compiled.setdefault(wrapper.name, 0)
            self._calls.setdefault(wrapper.name, 0)

    def note_traces(self, name: str, new: int) -> None:
        """Record `new` fresh traces (the `_cache_size`-delta fast path
        calls this ONLY when the compiled-entry count moved; call counts
        ride on the wrapper's lock-free `calls` int)."""
        with self._lock:
            self._compiled[name] = self._compiled.get(name, 0) + new
        if self.registry is not None:
            self.registry.counter(f"compile.{name}").inc(new)

    def note_call(self, name: str, fp) -> bool:
        """Record one call by fingerprint (fallback path); returns True
        when `fp` is a NEW trace."""
        with self._lock:
            self._calls[name] = self._calls.get(name, 0) + 1
            seen = self._traces.setdefault(name, set())
            if fp in seen:
                return False
            seen.add(fp)
            self._compiled[name] = self._compiled.get(name, 0) + 1
        if self.registry is not None:
            self.registry.counter(f"compile.{name}").inc()
        return True

    def compilations(self, name: str) -> int:
        with self._lock:
            return self._compiled.get(name, 0)

    def names(self) -> list:
        with self._lock:
            return sorted(self._compiled)

    def report(self) -> dict:
        """{name: {"compilations": n, "calls": n, "cache_size": n|None}}
        — `cache_size` sums jax's own per-wrapper compiled-entry counts
        (the ground truth the fingerprints approximate)."""
        with self._lock:
            sizes: dict[str, Optional[int]] = {}
            calls = dict(self._calls)
            for w in self._wrappers:
                calls[w.name] = calls.get(w.name, 0) + w.calls
                cs = w.cache_size()
                if cs is None:
                    sizes.setdefault(w.name, None)
                else:
                    sizes[w.name] = (sizes.get(w.name) or 0) + cs
            return {name: {"compilations": n,
                           "calls": calls.get(name, 0),
                           "cache_size": sizes.get(name)}
                    for name, n in sorted(self._compiled.items())}

    # ------------------------------------------------------------- audit
    def check(self, manifest: dict) -> list:
        """Audit the observed traces against `manifest` (see module
        docstring).  Returns human-readable violations (empty = pass).
        Only names with >= 1 observed call are audited."""
        errs = []
        entries = manifest.get("entries", {})
        require_listed = bool(manifest.get("require_listed", True))
        rep = self.report()
        for name, r in rep.items():
            if r["calls"] == 0:
                continue
            n = r["compilations"]
            ent = entries.get(name)
            if ent is None:
                if require_listed:
                    errs.append(
                        f"{name}: {n} compilation(s) observed but the "
                        f"name is not in the manifest — new jit entry "
                        f"points must declare their compile budget "
                        f"(docs/OBSERVABILITY.md)")
                continue
            if ent.get("any"):
                continue
            if "exact" in ent and n != int(ent["exact"]):
                errs.append(
                    f"{name}: {n} compilation(s), manifest expects "
                    f"exactly {ent['exact']} — "
                    + ("a shape-keyed re-trace crept in"
                       if n > int(ent["exact"])
                       else "expected traces never ran"))
            elif "max" in ent and n > int(ent["max"]):
                errs.append(
                    f"{name}: {n} compilation(s) exceed the manifest "
                    f"bound {ent['max']} — a shape-keyed re-trace crept "
                    f"in (un-bucketed length? scalar promoted to a "
                    f"traced shape?)")
            elif "exact" not in ent and "max" not in ent:
                errs.append(f"{name}: manifest entry has none of "
                            f"exact/max/any")
        return errs


def load_manifest(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != 1:
        raise ValueError(f"{path}: unsupported compilations-manifest "
                         f"version {doc.get('version')!r} (expected 1)")
    if not isinstance(doc.get("entries"), dict):
        raise ValueError(f"{path}: manifest needs an 'entries' object")
    return doc
