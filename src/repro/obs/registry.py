"""Process-wide metrics registry (DESIGN.md §11).

Counters, gauges and bounded-bucket histograms behind ONE lock, built
for the serving/training hot loops under two hard rules:

  * **no host sync, ever**: every `inc`/`set`/`observe` takes a HOST
    scalar.  Passing a `jax.Array` raises `TypeError` instead of
    silently forcing a device fetch — metrics are incremented at points
    that already sync (the engine's `np.asarray(logits)` readback, the
    refresh overflow D2H that `overflow_retry` pays anyway) and device
    scalars are drained only where they are already fetched;
  * **bounded memory**: a histogram keeps a fixed log-spaced bucket
    array for the full stream plus a bounded raw-sample window for
    exact percentiles — observing forever never grows either.

Percentile readout (`Histogram.percentile`) is EXACT (bitwise equal to
`numpy.percentile(..., method="linear")`) while the stream fits the raw
window (`max_samples`, default 4096 — far above any smoke/bench run);
past the window it falls back to a bucket-edge estimate whose error is
bounded by the bucket width (`exact` flips to False in the snapshot so
a reader never mistakes one for the other).  tests/test_obs.py holds
both halves against a numpy oracle.

Thread-safety: all mutation and snapshotting goes through the
registry's single re-entrant lock; the serving engine loop may run in
one thread while another polls `snapshot()` (tested).
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Optional, Union

Number = Union[int, float]


def _host_scalar(v, what: str) -> float:
    """Coerce to a host float; refuse device arrays (the no-sync rule)."""
    if type(v) is int or type(v) is float:
        return v
    # np scalars / 0-d arrays are already host-side; jax.Array is not
    mod = type(v).__module__
    if mod.startswith("jax") or mod.startswith("jaxlib"):
        raise TypeError(
            f"{what} got a device value ({type(v).__name__}): metrics "
            f"must never force a host sync on the hot path — fetch the "
            f"scalar where the code already syncs (e.g. the existing "
            f"np.asarray readback) and pass a plain int/float")
    return float(v)


class Counter:
    """Monotonic-by-convention counter (supports `set` for the thin
    attribute views the engines keep; see serving/kvpool/engine.py)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self._v = 0.0
        self._lock = lock

    def inc(self, n: Number = 1) -> None:
        n = _host_scalar(n, f"counter {self.name!r}")
        with self._lock:
            self._v += n

    def set(self, v: Number) -> None:
        v = _host_scalar(v, f"counter {self.name!r}")
        with self._lock:
            self._v = v

    @property
    def value(self) -> Number:
        v = self._v
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-write-wins scalar with an optional running max
    (`set_max` — peak residency, peak live tokens, ...)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self._v = 0.0
        self._lock = lock

    def set(self, v: Number) -> None:
        v = _host_scalar(v, f"gauge {self.name!r}")
        with self._lock:
            self._v = v

    def set_max(self, v: Number) -> None:
        v = _host_scalar(v, f"gauge {self.name!r}")
        with self._lock:
            if v > self._v:
                self._v = v

    @property
    def value(self) -> Number:
        v = self._v
        return int(v) if float(v).is_integer() else v


def log_edges(lo: float, hi: float, per_decade: int) -> list:
    """Log-spaced bucket edges: `per_decade` edges per power of ten
    spanning [lo, hi].  Shared by latency (seconds) and size (bytes /
    tokens) histograms — the default covers 1us..10000s."""
    n = int(round(math.log10(hi / lo) * per_decade))
    return [lo * 10 ** (i / per_decade) for i in range(n + 1)]


class Histogram:
    """Bounded-bucket histogram with exact-percentile readout.

    Buckets: fixed log-spaced edges; values below the first edge land in
    bucket 0, values past the last edge in the overflow bucket.  The
    bucket counts cover the WHOLE stream; the raw-sample window keeps
    the first `max_samples` observations so percentiles are exact
    (numpy `method="linear"`) until the stream outgrows it, after which
    `percentile` answers from the bucket upper edges (error <= one
    bucket width) and `exact` reads False.
    """

    __slots__ = ("name", "_lock", "_edges", "_buckets", "_samples",
                 "_max_samples", "count", "sum", "min", "max")

    def __init__(self, name: str, lock: threading.RLock, *,
                 edges: Optional[list] = None, max_samples: int = 4096):
        self.name = name
        self._lock = lock
        self._edges = list(edges) if edges is not None \
            else log_edges(1e-6, 1e4, per_decade=4)
        self._buckets = [0] * (len(self._edges) + 1)
        self._samples: list = []
        self._max_samples = int(max_samples)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: Number) -> None:
        v = _host_scalar(v, f"histogram {self.name!r}")
        with self._lock:
            self._buckets[bisect.bisect_left(self._edges, v)] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if len(self._samples) < self._max_samples:
                self._samples.append(v)

    @property
    def exact(self) -> bool:
        return self.count <= self._max_samples

    def percentile(self, q: Number) -> float:
        """q in [0, 100].  Exact (numpy linear interpolation) while the
        stream fits the raw window; bucket-upper-edge estimate after."""
        with self._lock:
            if self.count == 0:
                return math.nan
            if self.exact:
                xs = sorted(self._samples)
                # numpy.percentile(method="linear"): virtual index
                # h = (n - 1) * q / 100, linear between floor/ceil
                h = (len(xs) - 1) * (float(q) / 100.0)
                lo = math.floor(h)
                hi = math.ceil(h)
                return xs[lo] + (xs[hi] - xs[lo]) * (h - lo)
            want = (float(q) / 100.0) * self.count
            seen = 0
            for i, c in enumerate(self._buckets):
                seen += c
                if seen >= want:
                    # upper edge of the bucket (overflow: last edge +
                    # the stream max, whichever is larger)
                    if i < len(self._edges):
                        return min(self._edges[i], self.max)
                    return self.max
            return self.max

    def summary(self) -> dict:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "exact": True}
            return {"count": self.count, "sum": self.sum,
                    "min": self.min, "max": self.max,
                    "mean": self.sum / self.count,
                    "p50": self.percentile(50), "p90": self.percentile(90),
                    "p99": self.percentile(99), "exact": self.exact}


class MetricsRegistry:
    """Name -> instrument map; `get`-or-create is idempotent so call
    sites never coordinate.  One registry per serving engine / training
    run (the process-wide default lives in `repro.obs.default()`)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name, self._lock)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name, self._lock)
            return g

    def histogram(self, name: str, *, edges: Optional[list] = None,
                  max_samples: int = 4096) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(
                    name, self._lock, edges=edges, max_samples=max_samples)
            return h

    def snapshot(self) -> dict:
        """One JSON-serializable dict of everything (sorted names)."""
        with self._lock:
            return {
                "counters": {n: c.value
                             for n, c in sorted(self._counters.items())},
                "gauges": {n: g.value
                           for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.summary()
                               for n, h in sorted(self._histograms.items())},
            }


def render_snapshot(snap: dict, *, prefix: str = "") -> str:
    """The ONE human-readable snapshot renderer (launch/serve.py,
    launch/train.py): counters and gauges one per line, histograms with
    count/mean/p50/p90/p99.  `prefix` filters by name prefix."""
    lines = []
    for name, v in snap.get("counters", {}).items():
        if name.startswith(prefix):
            lines.append(f"  {name} = {v}")
    for name, v in snap.get("gauges", {}).items():
        if name.startswith(prefix):
            vs = f"{v:.4g}" if isinstance(v, float) else str(v)
            lines.append(f"  {name} = {vs}")
    for name, h in snap.get("histograms", {}).items():
        if not name.startswith(prefix) or not h.get("count"):
            continue
        lines.append(
            f"  {name}: n={h['count']} mean={h['mean']:.4g} "
            f"p50={h['p50']:.4g} p90={h['p90']:.4g} p99={h['p99']:.4g}"
            + ("" if h["exact"] else " (bucket-estimated)"))
    return "\n".join(lines)
