"""Delta extraction and diffing (DESIGN.md §4).

Extraction builds a `DeltaArtifact` from a LIFT checkpoint step versus its
base parameters using the **stored selection index sets** — the (ns, k)
`idx` leaves the sparse optimizer carries.  Only the planned parameter
leaves and those index leaves are read (`CheckpointManager.restore_leaves`
partial reads), and values come from an O(k) gather per tensor: no dense
subtraction tree ever materializes on the host.

Exactness contract: LIFT's train step touches ONLY the currently-selected
entries, so with mode="replace" `base + delta == fine-tuned checkpoint`
bitwise **as long as the shipped index sets cover every entry that was
ever trained** — i.e. the run's masks were fixed (no refresh between base
and the extracted step), or deltas are extracted at least once per
refresh interval and shipped via `diff`.  A refreshed-away entry keeps
its trained value in the checkpoint but leaves the stored index set;
persisting the mask *union* in the optimizer state is the documented
follow-up (ROADMAP).

`diff(a, b)` compares two artifacts of the same geometry over their index
sets and returns the O(changed) patch that turns `a` into `b` — the
shipping unit between checkpoint steps (`apply_diff` reconstructs `b`).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.lift import get_by_path
from repro.deltas.format import (DeltaArtifact, DeltaMismatchError,
                                 make_manifest, num_stack, tree_hash)

PARAM_LEAF = "params/{path}"
IDX_LEAF = "state/opt/tensors/{path}/idx"


def extract(ckpt, step: int, base_params, *, mode: str = "replace",
            base_hash: Optional[str] = None,
            value_dtype: Optional[str] = None) -> DeltaArtifact:
    """Build a sparse delta from checkpoint `step` against `base_params`.

    `ckpt` is a `CheckpointManager` whose step was written by
    `launch/train.py` ({"params", "state"} tree with the engine's
    `plan_meta` under meta["selection"]).  `base_hash` short-circuits
    re-hashing when the caller already fingerprinted the base.

    `value_dtype` (e.g. "float16") stores the shipped VALUES narrower
    than the tensor dtype — half the value bytes for fp32 tensors;
    merging upcasts (format v2).  `value_dtype="int8"` (format v3)
    quantizes the values to int8 with a per-tensor absmax/127
    `value_scale` — a quarter of the value bytes; merging dequantizes
    `val * value_scale` in fp32.  Quantization breaks the bitwise
    mode="replace" contract (merged = fp32(fp16(w)) or
    fp32(int8(w) * scale)); leave None when bitwise identity to the
    fine-tuned checkpoint matters."""
    selection = ckpt.restore_selection(step)
    if selection is None:
        raise DeltaMismatchError(
            f"checkpoint step {step} carries no selection plan fingerprint "
            f"— not a LIFT/sparse run; there is no index set to extract")
    plan_tensors = selection["tensors"]
    leaves = ckpt.restore_leaves(
        step,
        [PARAM_LEAF.format(path=p) for p in plan_tensors]
        + [IDX_LEAF.format(path=p) for p in plan_tensors])

    tensors = {}
    tensors_meta = {}
    for path, meta in plan_tensors.items():
        tuned = leaves[PARAM_LEAF.format(path=path)]
        idx = leaves[IDX_LEAF.format(path=path)]
        ns = num_stack(meta)
        flat = tuned.reshape(ns, meta["rows"] * meta["cols"])
        idx2 = idx.reshape(ns, meta["k"]).astype(np.int32)
        val = np.take_along_axis(flat, idx2, axis=-1)
        if mode == "add":
            base_flat = np.asarray(get_by_path(base_params, path)).reshape(
                ns, meta["rows"] * meta["cols"])
            val = val - np.take_along_axis(base_flat, idx2, axis=-1)
        meta_out = dict(meta, dtype=str(tuned.dtype))
        if value_dtype == "int8":
            absmax = float(np.max(np.abs(val.astype(np.float32))))
            scale = (absmax / 127.0) or 1.0
            val = np.clip(np.rint(val.astype(np.float32) / scale),
                          -127, 127).astype(np.int8)
            meta_out["value_dtype"] = "int8"
            meta_out["value_scale"] = scale
        elif value_dtype is not None and value_dtype != str(tuned.dtype):
            val = val.astype(np.dtype(value_dtype))
            meta_out["value_dtype"] = value_dtype
        tensors[path] = {"idx": idx2, "val": val}
        tensors_meta[path] = meta_out

    manifest = make_manifest(
        mode=mode,
        base_hash=base_hash or tree_hash(base_params),
        selection=selection, tensors_meta=tensors_meta, step=step)
    return DeltaArtifact(manifest=manifest, tensors=tensors)


# ------------------------------------------------------------------ diff
def _check_comparable(a: DeltaArtifact, b: DeltaArtifact) -> None:
    if a.manifest["mode"] != b.manifest["mode"]:
        raise DeltaMismatchError(
            f"cannot diff deltas of different modes "
            f"({a.manifest['mode']!r} vs {b.manifest['mode']!r})")
    if a.manifest["base_hash"] != b.manifest["base_hash"]:
        raise DeltaMismatchError(
            "cannot diff deltas extracted against different bases")
    if sorted(a.tensors) != sorted(b.tensors):
        raise DeltaMismatchError("delta tensor sets differ")


def diff(a: DeltaArtifact, b: DeltaArtifact) -> dict:
    """Index-set diff turning artifact `a` into artifact `b`.

    Per tensor, per stack row: `upsert` = entries of b that are new or
    changed vs a (index + value), `drop` = indices of a absent from b.
    Entries are stored flattened with explicit stack-row ids so the patch
    is a plain {path: {"upsert_row", "upsert_idx", "upsert_val",
    "drop_row", "drop_idx"}} dict of 1-D arrays — O(changed) bytes, the
    delta-shipping unit between checkpoint steps.  `stats` accumulates
    patch vs full-artifact bytes and the index-set Jaccard overlap."""
    _check_comparable(a, b)
    out: dict = {"tensors": {}, "stats": {}}
    patch_bytes = 0
    inter_total = union_total = 0
    for path in sorted(a.tensors):
        ta, tb = a.tensors[path], b.tensors[path]
        u_row, u_idx, u_val, d_row, d_idx = [], [], [], [], []
        for s in range(ta["idx"].shape[0]):
            ia, va = ta["idx"][s], ta["val"][s]
            ib, vb = tb["idx"][s], tb["val"][s]
            common, pa, pb = np.intersect1d(ia, ib, assume_unique=False,
                                            return_indices=True)
            inter_total += common.size
            union_total += ia.size + ib.size - common.size
            changed = va[pa] != vb[pb]
            new_mask = ~np.isin(ib, common)
            ups_idx = np.concatenate([common[changed], ib[new_mask]])
            ups_val = np.concatenate([vb[pb][changed], vb[new_mask]])
            order = np.argsort(ups_idx, kind="stable")
            u_row.append(np.full(ups_idx.size, s, np.int32))
            u_idx.append(ups_idx[order].astype(np.int32))
            u_val.append(ups_val[order])
            gone = ia[~np.isin(ia, common)]
            d_row.append(np.full(gone.size, s, np.int32))
            d_idx.append(gone.astype(np.int32))
        entry = {
            "upsert_row": np.concatenate(u_row),
            "upsert_idx": np.concatenate(u_idx),
            "upsert_val": np.concatenate(u_val),
            "drop_row": np.concatenate(d_row),
            "drop_idx": np.concatenate(d_idx),
        }
        patch_bytes += sum(int(v.nbytes) for v in entry.values())
        out["tensors"][path] = entry
    out["step"] = b.manifest["step"]
    out["stats"] = {
        "patch_bytes": patch_bytes,
        "full_bytes": b.nbytes(),
        "index_jaccard": (inter_total / union_total) if union_total else 1.0,
    }
    return out


def apply_diff(a: DeltaArtifact, patch: dict) -> DeltaArtifact:
    """Reconstruct artifact `b` from `a` and `diff(a, b)` — the receiving
    end of delta-shipping.  Round-trip property (tested):
    `apply_diff(a, diff(a, b)).tensors == b.tensors` exactly."""
    tensors = {}
    for path, ta in a.tensors.items():
        p = patch["tensors"][path]
        ns, k = ta["idx"].shape
        new_idx = np.empty_like(ta["idx"])
        new_val = np.empty_like(ta["val"])
        for s in range(ns):
            keep = ~np.isin(ta["idx"][s], p["drop_idx"][p["drop_row"] == s])
            ui = p["upsert_idx"][p["upsert_row"] == s]
            uv = p["upsert_val"][p["upsert_row"] == s]
            # surviving a-entries not overridden by an upsert, plus upserts
            keep &= ~np.isin(ta["idx"][s], ui)
            idx = np.concatenate([ta["idx"][s][keep], ui])
            val = np.concatenate([ta["val"][s][keep], uv])
            order = np.argsort(idx, kind="stable")
            if idx.size != k:
                raise DeltaMismatchError(
                    f"patch for {path!r} row {s} yields {idx.size} entries, "
                    f"expected k={k} — patch does not match this artifact")
            new_idx[s] = idx[order]
            new_val[s] = val[order]
        tensors[path] = {"idx": new_idx, "val": new_val}
    manifest = dict(a.manifest, step=patch.get("step", a.manifest["step"]))
    return DeltaArtifact(manifest=manifest, tensors=tensors)
