"""DeltaHub: sparse-delta artifacts — extract, ship, hot-swap (DESIGN.md §4)."""
from repro.deltas.extract import apply_diff, diff, extract
from repro.deltas.format import (DELTA_FORMAT_VERSION, DeltaArtifact,
                                 DeltaMismatchError, tree_hash)
from repro.deltas.merge import DeltaMerger, merge_delta
from repro.deltas.pool_layout import SENTINEL_IDX, PoolLayout

__all__ = [
    "DELTA_FORMAT_VERSION", "DeltaArtifact", "DeltaMismatchError",
    "DeltaMerger", "PoolLayout", "SENTINEL_IDX", "apply_diff", "diff",
    "extract", "merge_delta", "tree_hash",
]
