"""Batched delta merge into base weights (DESIGN.md §4).

Mirrors the SelectionEngine's batching: tensors are grouped by
(rows, cols, k) geometry, each group's leaves stacked into one
(ns_total, rows*cols) batch, and the whole merge runs as ONE jitted
program per delta — one `sparse_scatter_merge` kernel launch per
geometry group, not a per-tensor Python dispatch loop.

Mesh-aware: the merger snapshots the active mesh (parallel/sharding ctx)
at construction.  Groups whose cols divide over the "shards" logical axis
scatter shard-locally under `shard_map`
(`kernels.ops.sparse_scatter_merge_sharded`): each shard folds only the
delta entries that land in its column slab — zero cross-shard traffic,
because an index+value delta needs no gathered weights anywhere.  Groups
that don't divide fall back to the unsharded kernel, exactly like the
engine's `group_exec` fallback.

Backends: "kernel" (Pallas scatter-merge, the serving path) and "ref"
(`kernels.ref.sparse_scatter_merge`, the dense oracle) — both bitwise
under mode="replace", which the delta round-trip tests prove.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import get_by_path, set_by_path
from repro.core.selection import GroupSpec
from repro.deltas.format import DeltaArtifact, num_stack
from repro.parallel import sharding as shd


def geometry_key(tensors_meta: dict, backend: str) -> tuple:
    """Hashable geometry fingerprint of a manifest's tensors metadata —
    computable WITHOUT building a merger, so caches (AdapterStore) can
    look up an existing compiled merger before constructing one."""
    return tuple(
        (p, tuple(tensors_meta[p]["shape"]), tensors_meta[p]["rows"],
         tensors_meta[p]["cols"], tensors_meta[p]["k"])
        for p in sorted(tensors_meta)) + (backend,)


class DeltaMerger:
    """One jitted merge program for a fixed tensor geometry set.

    Built from a delta manifest's `tensors` metadata; reusable across
    every artifact of the same geometry (the AdapterStore caches mergers
    by geometry fingerprint so loading N adapters compiles once)."""

    def __init__(self, tensors_meta: dict, *, backend: str = "kernel",
                 mesh=None):
        if backend not in ("kernel", "ref"):
            raise ValueError(f"unknown merge backend {backend!r}")
        self.backend = backend
        self.meta = {p: dict(m) for p, m in tensors_meta.items()}
        self.paths = sorted(self.meta)
        self.mesh = mesh if mesh is not None else shd.active_mesh()
        axes = shd.mesh_axes_for("shards", self.mesh)
        self.shard_axis = axes[0] if len(axes) == 1 else None
        self.mesh_shards = (int(self.mesh.shape[self.shard_axis])
                            if (self.mesh is not None and self.shard_axis)
                            else 1)
        groups: dict = {}
        for path in self.paths:
            m = self.meta[path]
            groups.setdefault((m["rows"], m["cols"], m["k"]),
                              []).append(path)
        self.groups = tuple(
            GroupSpec(rows=r, cols=c, k=k, paths=tuple(ps),
                      stacks=tuple(num_stack(self.meta[q]) for q in ps))
            for (r, c, k), ps in groups.items())
        self.group_exec = {
            (g.rows, g.cols, g.k): self._exec_mode(g) for g in self.groups}
        from repro import obs as obs_mod
        self._merge_jit = obs_mod.instrument_jit(
            self._impl, name="deltas.merge", static_argnames=("mode",))

    def geometry_key(self) -> tuple:
        """Hashable fingerprint the AdapterStore caches mergers by."""
        return geometry_key(self.meta, self.backend)

    def _exec_mode(self, g: GroupSpec) -> str:
        if self.backend == "ref":
            return "ref"
        if (self.mesh is not None and self.shard_axis is not None
                and self.mesh_shards > 1
                and g.cols % self.mesh_shards == 0):
            return "sharded"
        return "kernel"

    # ------------------------------------------------------------- merge
    def merge(self, base_params, delta: DeltaArtifact):
        """base tree + artifact -> merged tree (one jitted program).

        Quantized artifacts (format v2 `value_dtype`, e.g. fp16 values;
        format v3 int8 values with a per-tensor `value_scale`) DECODE
        here: fp16 -> fp32 is an exact upcast, int8 dequantizes
        `val * value_scale` in fp32 — so the merged entry is
        fp32(fp16(w)) / fp32(int8(w) * scale); the only lossy step was
        extraction-time rounding, never the merge itself."""
        from repro.deltas.format import decode_values
        idx = {p: jnp.asarray(delta.tensors[p]["idx"]) for p in self.paths}
        val = {p: jnp.asarray(decode_values(
            np.asarray(delta.tensors[p]["val"]), self.meta[p]))
            for p in self.paths}
        return self._merge_jit(base_params, idx, val,
                               mode=delta.manifest["mode"])

    def _impl(self, params, idx, val, *, mode: str):
        from repro.kernels import ops as kops
        from repro.kernels import ref as kref
        out = params
        for g in self.groups:
            ws = [get_by_path(params, p).reshape(ns, g.rows * g.cols)
                  for p, ns in zip(g.paths, g.stacks)]
            base = jnp.concatenate(ws) if len(ws) > 1 else ws[0]
            ii = jnp.concatenate([idx[p] for p in g.paths]) \
                if len(g.paths) > 1 else idx[g.paths[0]]
            vv = jnp.concatenate([val[p] for p in g.paths]) \
                if len(g.paths) > 1 else val[g.paths[0]]
            exec_mode = self.group_exec[(g.rows, g.cols, g.k)]
            if exec_mode == "ref":
                merged = kref.sparse_scatter_merge(base, ii, vv, mode=mode)
            elif exec_mode == "sharded":
                merged = self._merge_group_sharded(base, ii, vv, g, mode)
            else:
                merged = kops.sparse_scatter_merge(base, ii, vv, mode=mode)
            off = 0
            for p, ns in zip(g.paths, g.stacks):
                leaf = merged[off:off + ns].reshape(self.meta[p]["shape"])
                out = set_by_path(out, p, leaf)
                off += ns
        return out

    def _merge_group_sharded(self, base, ii, vv, g: GroupSpec, mode: str):
        from functools import partial

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels import ops as kops
        body = partial(kops.sparse_scatter_merge_sharded,
                       axis_name=self.shard_axis, n_shards=self.mesh_shards,
                       cols_global=g.cols, mode=mode)
        bspec = shd.logical_to_spec((None, None, "shards"), self.mesh)
        base3 = base.reshape(base.shape[0], g.rows, g.cols)
        merged = shard_map(
            lambda b, i, v: body(b, i, v), mesh=self.mesh,
            in_specs=(bspec, P(), P()), out_specs=bspec,
            check_rep=False)(base3, ii, vv)
        return merged.reshape(base.shape[0], g.rows * g.cols)


def merge_delta(base_params, delta: DeltaArtifact, *,
                backend: str = "kernel", mesh=None, validate: bool = True,
                plan_meta=None):
    """One-shot convenience: validate (base hash + optional consumer
    plan_meta), build a merger for the artifact's geometry, merge."""
    if validate:
        delta.validate_base(base_params)
    if plan_meta is not None:
        delta.validate_plan(plan_meta)
    merger = DeltaMerger(delta.manifest["tensors"], backend=backend,
                         mesh=mesh)
    return merger.merge(base_params, delta)
