"""DeltaHub artifact format (DESIGN.md §4).

A LIFT fine-tune is fully described by its Principal Weights, so the unit
DeltaHub ships is a **sparse delta artifact**: per planned tensor, the
`(indices (ns, k) int32, values (ns, k))` pair keyed by the flattened
checkpoint path, plus a manifest that pins everything needed to refuse a
bad application:

    delta.json          manifest (see below)
    arrays.npz          "<path>\\x1fidx" / "<path>\\x1fval" members

Manifest fields:
  * format_version — this module's DELTA_FORMAT_VERSION;
  * mode — "replace" (values are the fine-tuned entries; merging is
    bitwise-exact) or "add" (values are differences; merging accumulates
    in fp32);
  * base_hash — `tree_hash` of the full base parameter tree the delta was
    extracted against: a delta REFUSES to apply to any other base;
  * selection — the producing run's `SelectionEngine.plan_meta()`
    fingerprint verbatim (geometry, backend, quota policy), so a delta
    refuses a consumer whose plan geometry or quota policy disagrees;
  * tensors — {path: {shape, stack, rows, cols, k, dtype}} for the
    shipped pairs; format v2 adds an optional per-tensor `value_dtype`
    (e.g. "float16") when the shipped values are stored narrower than
    the tensor dtype — consumers upcast on merge; format v3 extends
    `value_dtype` to "int8" with a per-tensor `value_scale` (absmax/127
    over the tensor's shipped values) — consumers dequantize
    `val * value_scale` in fp32 on merge (`decode_values`);
  * step — the source checkpoint step.

The artifact is O(k) per tensor — ~2x density of the dense bytes at equal
dtype (int32 index + value per entry), i.e. ≤ 12 % of the dense
checkpoint at the paper's 5 % density (benchmarks/delta_merge.py tracks
this ratio in CI).  fp16 values (`extract(..., value_dtype="float16")`)
shrink the value half of the payload 2x for fp32 tensors, int8 values
(`value_dtype="int8"`, v3) shrink it 4x — both at the cost of the
bitwise mode="replace" contract: a quantized delta merges to
fp32(fp16(w)) / fp32(int8(w) * scale), not w — ship full-precision
values when bitwise identity to the fine-tuned checkpoint matters.
Refusal semantics are unchanged: a v1 reader refuses v2/v3 artifacts by
format_version exactly as before, and this reader accepts every version
in SUPPORTED_FORMAT_VERSIONS (v1 artifacts simply have no `value_dtype`
fields, v1/v2 no `value_scale`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

import numpy as np

from repro.checkpoint.manager import _flatten

DELTA_FORMAT_VERSION = 3
SUPPORTED_FORMAT_VERSIONS = (1, 2, 3)
MANIFEST_NAME = "delta.json"
ARRAYS_NAME = "arrays.npz"
MODES = ("replace", "add")


class DeltaMismatchError(ValueError):
    """A delta refused to apply: wrong base weights or wrong geometry."""


def num_stack(meta: dict) -> int:
    """Matrices per tensor (prod of the manifest entry's stack dims)."""
    return int(np.prod(meta["stack"])) if meta["stack"] else 1


def value_dtype(meta: dict) -> str:
    """Storage dtype of a tensor's shipped values: the v2 optional
    `value_dtype` field, defaulting to the tensor dtype (always the case
    for v1 artifacts)."""
    return meta.get("value_dtype", meta["dtype"])


def decode_values(val, meta: dict):
    """Shipped values -> tensor dtype: identity for full-precision
    artifacts, exact upcast for v2 narrow floats, fp32 dequantization
    (`val * value_scale`) for v3 int8 values.  Works on numpy and jax
    arrays alike — the ONE decode every consumer (merge, pool packing)
    shares, so an artifact merges identically everywhere."""
    vd = value_dtype(meta)
    if vd == meta["dtype"]:
        return val
    if vd == "int8":
        scale = np.float32(meta.get("value_scale", 1.0))
        return (val.astype("float32") * scale).astype(meta["dtype"])
    return val.astype(meta["dtype"])


def tree_hash(tree) -> str:
    """Order-independent fingerprint of a parameter tree: sha256 over the
    sorted flattened paths with each leaf's shape, dtype and raw bytes.
    Two trees hash equal iff they are bitwise-identical leaf for leaf."""
    h = hashlib.sha256()
    flat = _flatten(tree)
    for path in sorted(flat):
        a = np.asarray(flat[path])
        h.update(path.encode())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class DeltaArtifact:
    """manifest (JSON-able dict) + tensors {path: {"idx", "val"}} on host."""
    manifest: dict
    tensors: dict

    # ------------------------------------------------------------- sizes
    def nbytes(self) -> int:
        """Payload bytes of the shipped index+value pairs."""
        return sum(int(t["idx"].nbytes) + int(t["val"].nbytes)
                   for t in self.tensors.values())

    def dense_nbytes(self) -> int:
        """Bytes of the dense planned tensors this artifact replaces."""
        total = 0
        for path, meta in self.manifest["tensors"].items():
            n = int(np.prod(meta["shape"]))
            total += n * np.dtype(meta["dtype"]).itemsize
        return total

    # ------------------------------------------------------------ saving
    def save(self, directory: str) -> None:
        os.makedirs(directory, exist_ok=True)
        arrays = {}
        for path, t in self.tensors.items():
            arrays[path.replace("/", "\x1f") + "\x1fidx"] = t["idx"]
            arrays[path.replace("/", "\x1f") + "\x1fval"] = t["val"]
        np.savez(os.path.join(directory, ARRAYS_NAME), **arrays)
        with open(os.path.join(directory, MANIFEST_NAME), "w") as f:
            json.dump(self.manifest, f)
            f.flush()
            os.fsync(f.fileno())

    @classmethod
    def load(cls, directory: str) -> "DeltaArtifact":
        with open(os.path.join(directory, MANIFEST_NAME)) as f:
            manifest = json.load(f)
        if manifest.get("format_version") not in SUPPORTED_FORMAT_VERSIONS:
            raise DeltaMismatchError(
                f"delta artifact {directory!r} has format_version "
                f"{manifest.get('format_version')!r}; this build reads "
                f"versions {SUPPORTED_FORMAT_VERSIONS}")
        tensors: dict = {}
        with np.load(os.path.join(directory, ARRAYS_NAME)) as z:
            for key in z.files:
                path, kind = key.rsplit("\x1f", 1)
                path = path.replace("\x1f", "/")
                tensors.setdefault(path, {})[kind] = z[key]
        missing = sorted(set(manifest["tensors"]) ^ set(tensors))
        if missing:
            raise DeltaMismatchError(
                f"delta artifact {directory!r} manifest and arrays "
                f"disagree on tensors (first mismatch: {missing[0]!r})")
        return cls(manifest=manifest, tensors=tensors)

    # --------------------------------------------------------- validation
    def validate_base(self, base_params) -> None:
        """Refuse to apply to the wrong base weights."""
        got = tree_hash(base_params)
        want = self.manifest["base_hash"]
        if got != want:
            raise DeltaMismatchError(
                f"delta was extracted against base {want[:12]}… but is "
                f"being applied to base {got[:12]}… — wrong base "
                f"checkpoint (or the base was modified in place)")

    def validate_plan(self, plan_meta: Optional[dict]) -> None:
        """Refuse a consumer whose selection geometry / quota policy
        disagrees with the producing run's `SelectionEngine.plan_meta()`
        fingerprint (same checks as `SelectionEngine.validate_meta`,
        from the artifact's side)."""
        if plan_meta is None:
            return
        mine = self.manifest.get("selection") or {}
        saved_q = (mine.get("quota"), mine.get("quota_shards", 1))
        got_q = (plan_meta.get("quota"), plan_meta.get("quota_shards", 1))
        if saved_q != got_q:
            raise DeltaMismatchError(
                f"delta quota policy mismatch: artifact was selected "
                f"under quota/shards {saved_q}, consumer runs {got_q}")
        # structured LIFT stores element indices like every other delta,
        # but a block-structure mismatch means the index sets were chosen
        # by a different rule — refuse loudly rather than merge a mask
        # the consumer's engine could never have produced
        if mine.get("block_size", 1) != plan_meta.get("block_size", 1):
            raise DeltaMismatchError(
                f"delta block-structure mismatch: artifact was selected "
                f"with block_size {mine.get('block_size', 1)}, consumer "
                f"runs block_size {plan_meta.get('block_size', 1)}")
        saved = mine.get("tensors", {})
        theirs = plan_meta.get("tensors", {})
        missing = sorted(set(saved) ^ set(theirs))
        if missing:
            raise DeltaMismatchError(
                f"delta plan covers different tensors than the consumer "
                f"(first mismatch: {missing[0]!r})")
        for path, s in saved.items():
            t = theirs[path]
            got = (list(t["shape"]), t["rows"], t["cols"], t["k"])
            want = (list(s["shape"]), s["rows"], s["cols"], s["k"])
            if got != want:
                raise DeltaMismatchError(
                    f"delta geometry mismatch for {path!r}: artifact "
                    f"shape/rows/cols/k {want} vs consumer {got}")


def make_manifest(*, mode: str, base_hash: str, selection: Optional[dict],
                  tensors_meta: dict, step: int) -> dict:
    if mode not in MODES:
        raise ValueError(f"unknown delta mode {mode!r} (expected {MODES})")
    return {
        "format_version": DELTA_FORMAT_VERSION,
        "mode": mode,
        "base_hash": base_hash,
        "selection": selection,
        "tensors": tensors_meta,
        "step": int(step),
    }
