"""Pool-resident delta layout: artifacts -> fixed-geometry adapter pages.

Merge-free serving (DESIGN.md §5) keeps ONE base weight set resident and
composes each decode slot's sparse delta inside the matmul
(`kernels.ops.delta_matmul`).  The deltas themselves live in a paged
adapter pool next to the KV pages: this module turns a DeltaHub artifact
(format v1/v2, `deltas/format.py`) into the pool's device layout —

    idx pages: (n_pages, E) int32   row-major flat replace indices
    val pages: (n_pages, E) float32 RESIDENT values (see below)

Every adapter under one selection plan has the SAME geometry (same
tensors, same k per tensor), so the packing is fixed per plan: tensor
`path` with stack ns and k entries per matrix occupies the contiguous
stream slice [offset(path), offset(path) + ns*k), and every adapter
spans exactly `pages_per_adapter` pages.  The tail and every unused slot
pad with SENTINEL_IDX (>= rows*cols for any tensor), which the delta
matmul drops — the all-sentinel trash page is how base-only slots ride
the same dispatch.

Resident values are the MERGED entries, not the shipped ones: "replace"
artifacts ship them directly (fp16 v2 values upcast exactly), "add"
artifacts gather base[idx] and add in fp32 — elementwise IEEE adds, the
same arithmetic `DeltaMerger` performs — so composing a resident entry
into the base reproduces merge-on-load serving bit for bit.  The pool
never stores a dense merged copy: an adapter costs
8 bytes x k_total + page-rounding slack, ~2x density of the dense bytes
(0.02x at 1 % density, vs 1.0x per AdapterStore entry).
"""
from __future__ import annotations

import numpy as np

from repro.core.lift import get_by_path
from repro.deltas.format import (DeltaArtifact, DeltaMismatchError,
                                 decode_values, num_stack)

# >= rows*cols for any supported tensor (asserted), dropped by the
# "drop"-mode scatter and keyed outside every kernel window
SENTINEL_IDX = np.int32(2 ** 30)


class PoolLayout:
    """Fixed packing of one selection plan's delta entries into pages.

    Built from a delta manifest's `tensors` metadata (the same dict
    `DeltaMerger` consumes); every artifact admitted to the pool must
    carry identical geometry — `pack` refuses anything else, mirroring
    the plan-fingerprint refusal of merge-on-load serving.
    """

    def __init__(self, tensors_meta: dict, *, entries_per_page: int = 2048):
        if entries_per_page < 1:
            raise ValueError(f"entries_per_page must be >= 1, got "
                             f"{entries_per_page}")
        self.meta = {p: dict(m) for p, m in sorted(tensors_meta.items())}
        self.paths = tuple(self.meta)
        if not self.paths:
            raise ValueError("pool layout needs at least one planned tensor")
        self.entries_per_page = int(entries_per_page)
        self.offsets: dict = {}
        off = 0
        for p in self.paths:
            m = self.meta[p]
            if m["rows"] * m["cols"] >= int(SENTINEL_IDX):
                raise ValueError(
                    f"tensor {p!r} has {m['rows']}x{m['cols']} entries — "
                    f"beyond the pool's sentinel index space")
            self.offsets[p] = off
            off += num_stack(m) * m["k"]
        self.total_entries = off
        self.pages_per_adapter = -(-off // self.entries_per_page)

    # ------------------------------------------------------------- sizes
    def adapter_nbytes(self) -> int:
        """Device bytes one resident adapter costs (idx + val pages,
        including page-rounding slack)."""
        per_entry = np.dtype(np.int32).itemsize + np.dtype(np.float32).itemsize
        return self.pages_per_adapter * self.entries_per_page * per_entry

    def dense_nbytes(self) -> int:
        """Bytes of one dense merged copy of the planned tensors — what
        an AdapterStore entry holds resident per adapter."""
        total = 0
        for m in self.meta.values():
            total += int(np.prod(m["shape"])) * np.dtype(m["dtype"]).itemsize
        return total

    def slices(self):
        """{path: (offset, ns, k)} into the flat per-adapter stream."""
        return {p: (self.offsets[p], num_stack(self.meta[p]),
                    self.meta[p]["k"]) for p in self.paths}

    # ------------------------------------------------------------ packing
    def pack(self, base_params, delta: DeltaArtifact):
        """Artifact -> (idx (n_pages, E) int32, val (n_pages, E) f32).

        Host-side (numpy): the caller DMAs the pages into the device
        pool at admission.  Refuses geometry drift; assumes the caller
        already ran `validate_base` (the pool does, once per adapter).
        """
        from repro.deltas.merge import geometry_key
        if (geometry_key(delta.manifest["tensors"], "pool")
                != geometry_key(self.meta, "pool")):
            raise DeltaMismatchError(
                "delta artifact geometry does not match the adapter "
                "pool's layout — one pool serves one selection plan")
        mode = delta.manifest["mode"]
        n = self.pages_per_adapter * self.entries_per_page
        idx_stream = np.full((n,), SENTINEL_IDX, np.int32)
        val_stream = np.zeros((n,), np.float32)
        for p in self.paths:
            m = self.meta[p]
            ns, k = num_stack(m), m["k"]
            idx = np.asarray(delta.tensors[p]["idx"],
                             np.int32).reshape(ns, k)
            # v2 narrow floats upcast exactly, v3 int8 dequantizes — the
            # shared decode, so pool residency == merge-on-load entries
            val = decode_values(np.asarray(delta.tensors[p]["val"]), m)
            val = val.astype(np.float32).reshape(ns, k)
            size = m["rows"] * m["cols"]
            valid = idx < size
            if mode == "add":
                base = np.asarray(get_by_path(base_params, p))
                base = base.reshape(ns, size).astype(np.float32)
                gathered = np.take_along_axis(
                    base, np.where(valid, idx, 0), axis=1)
                val = np.where(valid, gathered + val, 0.0).astype(np.float32)
            idx = np.where(valid, idx, SENTINEL_IDX).astype(np.int32)
            off = self.offsets[p]
            idx_stream[off:off + ns * k] = idx.reshape(-1)
            val_stream[off:off + ns * k] = val.reshape(-1)
        e = self.entries_per_page
        return (idx_stream.reshape(self.pages_per_adapter, e),
                val_stream.reshape(self.pages_per_adapter, e))
