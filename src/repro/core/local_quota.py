"""Shard-local Principal-Weight selection (DESIGN.md §3, "local" mode).

Global top-k over a TP-sharded |W'| needs an all-gather; the TPU-native
variant gives every model-parallel shard a proportional quota
k_local = k / n_shards over ITS column slab, making mask computation AND
the sparse update fully collective-free (indices never leave their shard).

This changes the selection slightly (a shard with unusually many large
entries is capped at its quota).  `overlap_with_global` quantifies the
deviation; on trained-LM spectra it stays >90 % (tests + fig17 bench) —
the paper's method is robust to it (same family of robustness as its
update-interval ablation, App. B.1).

The math is mesh-independent (pure reshape); the launcher picks n_shards =
TP degree.  Index convention: GLOBAL flat indices, sorted ascending —
identical contract to `lift.topk_indices`, so sparse_adam/migrate work
unchanged.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import (LiftConfig, TensorPlan, _leaf_matrices,
                             get_by_path, scores_for)


def local_topk_indices(scores2d: jax.Array, k: int, n_shards: int,
                       axis: int = 1) -> jax.Array:
    """Per-shard-quota top-k.  scores2d: (rows, cols); the sharded dim is
    `axis` (1 = column slabs, the framework's TP layout).  Returns (k,)
    GLOBAL flat indices, sorted ascending.  k must divide by n_shards."""
    rows, cols = scores2d.shape
    if axis == 0:
        idx_t = local_topk_indices(scores2d.T, k, n_shards, axis=1)
        r, c = idx_t // rows, idx_t % rows
        return jnp.sort(c * cols + r)
    assert cols % n_shards == 0 and k % n_shards == 0, (cols, k, n_shards)
    kq = k // n_shards
    w = cols // n_shards
    # (n_shards, rows*w) local score slabs
    slabs = scores2d.reshape(rows, n_shards, w).transpose(1, 0, 2) \
        .reshape(n_shards, rows * w)
    _, loc = jax.lax.top_k(slabs, kq)                 # (n_shards, kq) local
    r = loc // w
    c = loc % w
    shard0 = jnp.arange(n_shards)[:, None] * w
    flat = r * cols + (shard0 + c)
    return jnp.sort(flat.reshape(-1))


def compute_indices_local(params, plan: dict[str, TensorPlan],
                          cfg: LiftConfig, key: jax.Array,
                          n_shards: int, grads=None) -> dict[str, jax.Array]:
    """Drop-in for lift.compute_indices with per-shard quotas."""
    out = {}
    paths = sorted(plan.keys())
    keys = jax.random.split(key, len(paths))
    for kk, path in zip(keys, paths):
        p = plan[path]
        w = _leaf_matrices(get_by_path(params, path), p)
        g = None if grads is None else \
            _leaf_matrices(get_by_path(grads, path), p)
        ns = w.shape[0]
        eff = n_shards if (p.cols % n_shards == 0
                           and p.k % n_shards == 0) else 1
        subkeys = jax.random.split(kk, ns)

        def one(w2d, key1, g2d=None):
            s = scores_for(w2d, cfg, cfg.selection, key1, g2d)
            return local_topk_indices(s, p.k, eff)

        if g is None:
            idx = jax.vmap(lambda a, b: one(a, b))(w, subkeys)
        else:
            idx = jax.vmap(one)(w, subkeys, g)
        out[path] = idx.astype(jnp.int32)
    return out


def overlap_with_global(scores2d: jax.Array, k: int, n_shards: int) -> float:
    """|local-quota selection ∩ global top-k| / k."""
    from repro.core.lift import topk_indices
    g = set(np.asarray(topk_indices(scores2d, k)).tolist())
    l_ = set(np.asarray(local_topk_indices(scores2d, k, n_shards)).tolist())
    return len(g & l_) / max(k, 1)
