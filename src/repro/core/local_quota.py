"""Shard-local Principal-Weight selection (DESIGN.md §3, "local" mode).

Global top-k over a TP-sharded |W'| needs an all-gather; the TPU-native
variant gives every model-parallel shard a proportional quota
k_local = k / n_shards over ITS column slab, making mask computation AND
the sparse update fully collective-free (indices never leave their shard).

This changes the selection slightly (a shard with unusually many large
entries is capped at its quota).  `overlap_with_global` quantifies the
deviation; on trained-LM spectra it stays >90 % (tests + fig17 bench) —
the paper's method is robust to it (same family of robustness as its
update-interval ablation, App. B.1).

The math is mesh-independent (pure reshape); the launcher picks n_shards =
TP degree.  Index convention: GLOBAL flat indices, sorted ascending —
identical contract to `lift.topk_indices`, so sparse_adam/migrate work
unchanged.

Since the SelectionEngine grew a `quota="local"` mode this module is the
dense per-slab MATH (`local_topk_indices`) plus the deviation analysis
(`overlap_with_global`); `compute_indices_local` is a thin compatibility
wrapper over the engine so there is exactly one selection pipeline —
batched, kernel-backed and mesh-aware — for both quota modes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import LiftConfig, TensorPlan


def local_topk_indices(scores2d: jax.Array, k: int, n_shards: int,
                       axis: int = 1, block_size: int = 1) -> jax.Array:
    """Per-shard-quota top-k.  scores2d: (rows, cols); the sharded dim is
    `axis` (1 = column slabs, the framework's TP layout).  Returns (k,)
    GLOBAL flat indices, sorted ascending.  Raises ValueError when the
    sharded dim or k does not divide by n_shards (a ragged quota would
    silently select the wrong count per slab).

    `block_size` > 1 is structured LIFT (App. G.7) under a local quota:
    scores are summed over (bs x bs) blocks, each slab selects its exact
    k/(bs^2 * n_shards) block quota, and the selected blocks expand to
    their member elements — slabs must align to block boundaries."""
    rows, cols = scores2d.shape
    bs = block_size
    if bs > 1:
        if rows % bs or cols % bs or k % (bs * bs):
            raise ValueError(
                f"structured local-quota selection needs rows and cols "
                f"divisible by block_size and k by block_size^2: "
                f"rows={rows}, cols={cols}, k={k}, block_size={bs}")
        blocks = scores2d.reshape(rows // bs, bs,
                                  cols // bs, bs).sum(axis=(1, 3))
        bidx = local_topk_indices(blocks, k // (bs * bs), n_shards,
                                  axis=axis)
        from repro.kernels.ops import expand_block_indices
        return expand_block_indices(bidx, cols // bs, cols, bs)
    if axis == 0:
        idx_t = local_topk_indices(scores2d.T, k, n_shards, axis=1)
        r, c = idx_t // rows, idx_t % rows
        return jnp.sort(c * cols + r)
    if cols % n_shards or k % n_shards:
        raise ValueError(
            f"local-quota selection needs the sharded dim and k divisible "
            f"by n_shards: rows={rows}, cols={cols}, k={k}, "
            f"n_shards={n_shards} (axis={axis})")
    kq = k // n_shards
    w = cols // n_shards
    # (n_shards, rows*w) local score slabs
    slabs = scores2d.reshape(rows, n_shards, w).transpose(1, 0, 2) \
        .reshape(n_shards, rows * w)
    _, loc = jax.lax.top_k(slabs, kq)                 # (n_shards, kq) local
    r = loc // w
    c = loc % w
    shard0 = jnp.arange(n_shards)[:, None] * w
    flat = r * cols + (shard0 + c)
    return jnp.sort(flat.reshape(-1))


def compute_indices_local(params, plan: dict[str, TensorPlan],
                          cfg: LiftConfig, key: jax.Array,
                          n_shards: int, grads=None) -> dict[str, jax.Array]:
    """Drop-in for lift.compute_indices with per-shard quotas.

    Thin wrapper over `SelectionEngine(quota="local")` — one selection
    pipeline for both quota modes.  Raises ValueError naming the first
    tensor whose cols/k do not divide by `n_shards` (the engine's
    construction-time validation); the historical behavior silently fell
    back to a global top-k for such tensors, which made the selected set
    depend on geometry in a way no caller could observe."""
    from repro.core.selection import SelectionEngine
    eng = SelectionEngine(
        plan, cfg.replace(quota="local", quota_shards=n_shards))
    return eng.select(params, key, grads)


def overlap_with_global(scores2d: jax.Array, k: int, n_shards: int) -> float:
    """|local-quota selection ∩ global top-k| / k."""
    from repro.core.lift import topk_indices
    g = set(np.asarray(topk_indices(scores2d, k)).tolist())
    l_ = set(np.asarray(local_topk_indices(scores2d, k, n_shards)).tolist())
    return len(g & l_) / max(k, 1)
