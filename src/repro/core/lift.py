"""LIFT: Low-rank Informed Sparse Fine-Tuning — mask machinery.

Pipeline per eligible weight matrix W (paper §3.2):
  1. rank-r approximation  W' = A B^T           (core/lowrank.py)
  2. Principal Weights     idx = top-k of |W'|  (eq. 2)
  3. fine-tune only idx; optimizer state lives in (k,) vectors (eq. 3)
  4. every `update_interval` steps the mask is recomputed and optimizer
     state migrated (Algorithm 1)

Param trees may stack layers/experts on leading axes; LIFT treats each
(rows x cols) matrix independently (vmapped over the stack).  Which trailing
dims fold into rows vs cols comes from each Spec's `matrix_split`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowrank
from repro.nn.core import is_spec

STACK_AXES = ("layers", "experts")


@dataclasses.dataclass(frozen=True)
class LiftConfig:
    rank: int = 128               # LRA rank r
    match_rank: int = 0           # k = match_rank * (rows + cols) (LoRA-matched)
    density: float = 0.05         # used if match_rank == 0
    method: str = "randomized"    # exact | randomized
    strategy: str = "largest"     # App. B.2: largest | smallest | random | hybrid
    selection: str = "lift"       # lift | magnitude | gradient | movement | random
    scope: str = "all"            # all | mlp  (LIFT_MLP, App. G.4)
    min_dim: int = 32
    include_embed: bool = False
    train_other: bool = False     # dense-train the non-eligible params
    update_interval: int = 200
    block_size: int = 1           # App. G.7 structured LIFT (e.g. 4)
    oversample: int = 8
    power_iters: int = 2
    use_kernel: bool = False      # Pallas streaming selection (kernels/)
    compact_factor: int = 8       # compaction-kernel slot budget, x the
                                  # uniform per-tile share of k
    overflow_retry: bool = True   # auto-retry overflowed tensors with a
                                  # doubled compact_factor (host-side,
                                  # off the hot path; one scalar D2H per
                                  # refresh — see engine.retry_overflow)
    quota: str = "global"         # global | local — "local" gives every
                                  # column-slab shard an exact k/n quota
                                  # (collective-free selection, DESIGN.md §3)
    quota_shards: int = 0         # "local" slab count; 0 = infer from the
                                  # active mesh's "shards" logical axis
    k_multiple: int = 8           # k rounded up (1024 in production so the
                                  # (ns, k) state shards evenly over the mesh)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class TensorPlan:
    path: str
    shape: tuple          # full leaf shape
    stack: tuple          # leading stack dims
    rows: int
    cols: int
    k: int                # selected entries per matrix


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


_MLP_TOKENS = ("mlp", "moe", "cmix", "mixer")


def make_plan(spec_tree, cfg: LiftConfig) -> dict[str, TensorPlan]:
    """Decide which tensors LIFT masks and their matrix geometry."""
    flat, _ = jax.tree_util.tree_flatten_with_path(spec_tree, is_leaf=is_spec)
    plan: dict[str, TensorPlan] = {}
    for path, spec in flat:
        ps = _path_str(path)
        axes, shape = spec.axes, spec.shape
        n_stack = 0
        while n_stack < len(axes) and axes[n_stack] in STACK_AXES:
            n_stack += 1
        mat_dims = shape[n_stack:]
        if len(mat_dims) < 2:
            continue
        split = max(1, min(spec.matrix_split, len(mat_dims) - 1))
        rows = int(np.prod(mat_dims[:split]))
        cols = int(np.prod(mat_dims[split:]))
        if min(rows, cols) < cfg.min_dim:
            continue
        if not cfg.include_embed and "vocab" in axes:
            continue
        if cfg.scope == "mlp" and not any(t in ps for t in _MLP_TOKENS):
            continue
        if cfg.match_rank > 0:
            k = cfg.match_rank * (rows + cols)
        else:
            k = int(cfg.density * rows * cols)
        mult = max(cfg.k_multiple, 1)
        k = -(-k // mult) * mult
        k = int(min(max(k, 1), rows * cols))
        if cfg.block_size > 1:
            bs = cfg.block_size
            if rows % bs != 0 or cols % bs != 0:
                raise ValueError(
                    f"structured LIFT block_size={bs} does not tile tensor "
                    f"{ps!r}: matrix geometry is rows={rows}, cols={cols} "
                    f"(both must be divisible by block_size) — adjust "
                    f"block_size or exclude the tensor via min_dim/scope")
            bs2 = bs ** 2
            k = max(bs2, (k // bs2) * bs2)
        plan[ps] = TensorPlan(ps, tuple(shape), tuple(shape[:n_stack]),
                              rows, cols, k)
    return plan


def get_by_path(tree, path: str):
    if isinstance(tree, dict) and path in tree:  # flat {path: leaf} dicts
        return tree[path]
    node = tree
    for seg in path.split("/"):
        node = node[seg]
    return node


def set_by_path(tree, path: str, value):
    """Functionally replace tree[path] (nested or flat {path: leaf} dicts)."""
    if isinstance(tree, dict) and path in tree:
        new = dict(tree)
        new[path] = value
        return new
    segs = path.split("/")

    def rec(node, i):
        if i == len(segs) - 1:
            new = dict(node)
            new[segs[i]] = value
            return new
        new = dict(node)
        new[segs[i]] = rec(node[segs[i]], i + 1)
        return new

    return rec(tree, 0)


# --------------------------------------------------------------- scoring
def lift_scores(w2d: jax.Array, cfg: LiftConfig,
                key: Optional[jax.Array] = None) -> jax.Array:
    """|W'| for a single (rows, cols) matrix."""
    a, b = lowrank.lowrank_factors(
        w2d, cfg.rank, method=cfg.method, strategy=cfg.strategy, key=key,
        oversample=cfg.oversample, iters=cfg.power_iters)
    if cfg.use_kernel:
        from repro.kernels import ops as kops
        return kops.lowrank_abs(a, b)
    return jnp.abs(a @ b.T)


def scores_for(w2d: jax.Array, cfg: LiftConfig, selection: str,
               key: Optional[jax.Array] = None,
               grad2d: Optional[jax.Array] = None) -> jax.Array:
    if selection == "lift":
        return lift_scores(w2d, cfg, key)
    if selection == "magnitude":
        return jnp.abs(w2d.astype(jnp.float32))
    if selection == "gradient":
        assert grad2d is not None, "gradient selection needs a gradient sample"
        return jnp.abs(grad2d.astype(jnp.float32))
    if selection == "movement":
        assert grad2d is not None, "movement selection needs a gradient sample"
        return (-w2d.astype(jnp.float32) * grad2d.astype(jnp.float32))
    if selection == "random":
        assert key is not None
        return jax.random.uniform(key, w2d.shape, jnp.float32)
    raise ValueError(selection)


def topk_indices(scores2d: jax.Array, k: int, block_size: int = 1) -> jax.Array:
    """Flat indices (sorted ascending) of the top-k score entries.

    block_size > 1 implements structured LIFT (App. G.7): scores are summed
    over (bs x bs) blocks and whole blocks are selected.
    """
    rows, cols = scores2d.shape
    if block_size > 1:
        bs = block_size
        assert rows % bs == 0 and cols % bs == 0, (rows, cols, bs)
        nb_r, nb_c = rows // bs, cols // bs
        blocks = scores2d.reshape(nb_r, bs, nb_c, bs).sum(axis=(1, 3))
        kb = k // (bs * bs)
        _, bidx = jax.lax.top_k(blocks.reshape(-1), kb)
        # the ONE block->element expansion, shared with the streaming
        # paths — bitwise-identical orderings by construction
        from repro.kernels.ops import expand_block_indices
        return expand_block_indices(bidx, nb_c, cols, bs)
    _, idx = jax.lax.top_k(scores2d.reshape(-1), k)
    return jnp.sort(idx)


def mask_from_indices(idx: jax.Array, rows: int, cols: int) -> jax.Array:
    m = jnp.zeros((rows * cols,), jnp.bool_).at[idx].set(True)
    return m.reshape(rows, cols)


# ----------------------------------------------------------- whole trees
def _leaf_matrices(leaf: jax.Array, plan: TensorPlan) -> jax.Array:
    """-> (n_stack_total, rows, cols) view of the leaf."""
    ns = int(np.prod(plan.stack)) if plan.stack else 1
    return leaf.reshape(ns, plan.rows, plan.cols)


def compute_indices(params, plan: dict[str, TensorPlan], cfg: LiftConfig,
                    key: jax.Array, grads=None) -> dict[str, jax.Array]:
    """Principal-Weight indices for every planned tensor.

    Thin wrapper over `core.selection.SelectionEngine` (the single mask
    pipeline: geometry-grouped batching, and with `cfg.use_kernel` the
    streaming threshold+compaction path that never materializes the
    (rows, cols) score matrix).  Callers holding the engine should use it
    directly — this constructs a fresh one per call.

    Returns {path: (n_stack, k) int32} (flat indices into rows*cols,
    sorted ascending per matrix).
    """
    from repro.core.selection import SelectionEngine
    return SelectionEngine(plan, cfg).select(params, key, grads)
