"""Sparse AdamW over Principal Weights (paper Algorithm 1, App. A).

Optimizer state is stored ONLY for the k selected entries of each planned
tensor, as (n_stack, k) vectors — this is the paper's <5 % optimizer-memory
result.  With bf16 params, an fp32 "master" vector of the selected entries
is kept as well (beyond-paper: sparse master weights).

Gather/scatter use `take_along_axis` / `put_along_axis` on the flattened
(n_stack, rows*cols) view; indices are sorted ascending per matrix so the
HBM access pattern is near-sequential (DESIGN.md §3).

`migrate` implements Algorithm 1 lines 5–12: entries surviving a mask
refresh keep their moments, fresh entries restart at zero.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import TensorPlan, get_by_path, set_by_path


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def _flat2d(leaf: jax.Array, plan: TensorPlan) -> jax.Array:
    ns = int(np.prod(plan.stack)) if plan.stack else 1
    return leaf.reshape(ns, plan.rows * plan.cols)


def _stacked_flat(leaf: jax.Array, plan: TensorPlan) -> jax.Array:
    """(stack..., rows*cols) view — keeps the (possibly sharded) stack dims
    unmerged so expert/layer sharding survives the reshape (merging a
    sharded stack dim forces an all-gather; EXPERIMENTS.md §Perf)."""
    stack = plan.stack if plan.stack else (1,)
    return leaf.reshape(*stack, plan.rows * plan.cols)


def _stacked_idx(idx: jax.Array, plan: TensorPlan) -> jax.Array:
    stack = plan.stack if plan.stack else (1,)
    return idx.reshape(*stack, idx.shape[-1])


def init_state(params, indices: dict[str, jax.Array],
               plan: dict[str, TensorPlan], use_master: bool = False):
    """-> {"step": 0, "tensors": {path: {idx, m, v[, master]}}}."""
    tensors = {}
    for path, p in plan.items():
        idx = indices[path]
        entry = {
            "idx": idx,
            "m": jnp.zeros(idx.shape, jnp.float32),
            "v": jnp.zeros(idx.shape, jnp.float32),
        }
        if use_master:
            w = _stacked_flat(get_by_path(params, path), p)
            entry["master"] = jnp.take_along_axis(
                w, _stacked_idx(idx, p), axis=-1
            ).reshape(idx.shape).astype(jnp.float32)
        tensors[path] = entry
    return {"step": jnp.zeros((), jnp.int32), "tensors": tensors}


def apply_updates(params, grads, state, plan: dict[str, TensorPlan],
                  opt: AdamConfig, lr: Optional[jax.Array] = None):
    """One sparse AdamW step.  Returns (new_params, new_state).

    `params`/`grads` here are the *trainable subtree* (planned tensors and,
    optionally, densely-trained extras handled by the caller).
    """
    lr = opt.lr if lr is None else lr
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - opt.b1 ** t
    c2 = 1.0 - opt.b2 ** t

    new_params = params
    new_tensors = {}
    for path, p in plan.items():
        entry = state["tensors"][path]
        idx = entry["idx"]
        idx_s = _stacked_idx(idx, p)
        leaf = get_by_path(params, path)
        # gather BEFORE the f32 cast: the (k,)-sized slice is what upcasts,
        # never the full (rows*cols) gradient (collective-traffic matters)
        g = _stacked_flat(get_by_path(grads, path), p)
        g_sel = jnp.take_along_axis(g, idx_s, axis=-1).astype(jnp.float32)
        g_sel = g_sel.reshape(idx.shape)

        m = opt.b1 * entry["m"] + (1.0 - opt.b1) * g_sel
        v = opt.b2 * entry["v"] + (1.0 - opt.b2) * g_sel * g_sel
        mhat = m / c1
        vhat = v / c2

        w_flat = _stacked_flat(leaf, p)
        if "master" in entry:
            w_sel = entry["master"]
        else:
            w_sel = jnp.take_along_axis(w_flat, idx_s, axis=-1
                                        ).reshape(idx.shape
                                                  ).astype(jnp.float32)
        upd = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * w_sel
        w_new_sel = w_sel - lr * upd

        w_flat = jnp.put_along_axis(
            w_flat, idx_s, w_new_sel.reshape(idx_s.shape).astype(w_flat.dtype),
            axis=-1, inplace=False)
        new_leaf = w_flat.reshape(p.shape)
        new_params = set_by_path(new_params, path, new_leaf)
        new_entry = {"idx": idx, "m": m, "v": v}
        if "master" in entry:
            new_entry["master"] = w_new_sel
        new_tensors[path] = new_entry

    return new_params, {"step": step, "tensors": new_tensors}


def remap_moments(old_idx: jax.Array, new_idx: jax.Array,
                  *moments: jax.Array):
    """Project (ns, k) moment vectors from `old_idx` onto `new_idx`
    (both sorted ascending per matrix): entries whose index survives the
    mask refresh keep their value, fresh entries restart at zero.
    The searchsorted probe is O(k log k) — never O(rows*cols)."""
    k = old_idx.shape[-1]
    pos = jax.vmap(jnp.searchsorted)(old_idx, new_idx)
    pos_c = jnp.clip(pos, 0, k - 1)
    hit = jnp.take_along_axis(old_idx, pos_c, axis=1) == new_idx
    return tuple(
        jnp.where(hit, jnp.take_along_axis(mom, pos_c, axis=1), 0.0)
        for mom in moments)


def migrate(params, state, new_indices: dict[str, jax.Array],
            plan: dict[str, TensorPlan]):
    """Mask refresh (Algorithm 1 lines 5–12): remap m/v onto the new mask.

    `new_indices` is SelectionEngine output ({path: (ns, k) int32, sorted
    ascending per matrix} — `compute_indices` has the same contract)."""
    new_tensors = {}
    for path, p in plan.items():
        entry = state["tensors"][path]
        old_idx, new_idx = entry["idx"], new_indices[path]
        new_m, new_v = remap_moments(old_idx, new_idx,
                                     entry["m"], entry["v"])
        new_entry = {"idx": new_idx, "m": new_m, "v": new_v}
        if "master" in entry:
            w = _stacked_flat(get_by_path(params, path), p)
            new_entry["master"] = jnp.take_along_axis(
                w, _stacked_idx(new_idx, p), axis=-1
            ).reshape(new_idx.shape).astype(jnp.float32)
        new_tensors[path] = new_entry
    return {"step": state["step"], "tensors": new_tensors}


# --------------------------------------------------- dense AdamW (baseline)
def dense_init(params):
    z = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "m": z,
            "v": jax.tree.map(jnp.zeros_like, z)}


def dense_apply(params, grads, state, opt: AdamConfig,
                lr: Optional[jax.Array] = None):
    lr = opt.lr if lr is None else lr
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - opt.b1 ** t
    c2 = 1.0 - opt.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = opt.b1 * m + (1 - opt.b1) * g
        v2 = opt.b2 * v + (1 - opt.b2) * g * g
        u = (m2 / c1) / (jnp.sqrt(v2 / c2) + opt.eps) \
            + opt.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    new_m = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    new_v = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    return new_p, {"step": step, "m": new_m, "v": new_v}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), gn
