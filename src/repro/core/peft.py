"""PEFT baselines the paper compares against: LoRA, PiSSA, DoRA.

Functional formulation: adapters live in their own tree; `merge` produces
the effective params consumed by the (unchanged) model.  Gradients flow
through the merge, so `jax.grad` w.r.t. the adapter tree alone gives
adapter-only training — no module surgery.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import (TensorPlan, get_by_path, set_by_path)
from repro.core.lowrank import exact_lowrank


@dataclasses.dataclass(frozen=True)
class PeftConfig:
    kind: str = "lora"        # lora | pissa | dora
    rank: int = 16
    alpha: float = 32.0
    dropout: float = 0.0      # kept for config parity; not used in eval

    @property
    def scale(self) -> float:
        return self.alpha / self.rank

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


def _mat(leaf, plan: TensorPlan):
    ns = int(np.prod(plan.stack)) if plan.stack else 1
    return leaf.reshape(ns, plan.rows, plan.cols)


def init_adapters(params, plan: dict[str, TensorPlan], pcfg: PeftConfig,
                  key: jax.Array):
    """Returns (adapters, base_params).  PiSSA subtracts the principal
    component from the base (its defining trick)."""
    adapters = {}
    base = params
    paths = sorted(plan.keys())
    keys = jax.random.split(key, len(paths))
    for kk, path in zip(keys, paths):
        p = plan[path]
        r = min(pcfg.rank, p.rows, p.cols)
        ns = int(np.prod(p.stack)) if p.stack else 1
        if pcfg.kind in ("lora", "dora"):
            a = 0.01 * jax.random.normal(kk, (ns, p.rows, r), jnp.float32)
            b = jnp.zeros((ns, r, p.cols), jnp.float32)
        elif pcfg.kind == "pissa":
            w = _mat(get_by_path(params, path), p).astype(jnp.float32)

            def fac(w2d):
                fa, fb = exact_lowrank(w2d, r)
                s = jnp.sqrt(jnp.maximum(
                    jnp.linalg.norm(fa, axis=0), 1e-12))
                return fa / s[None, :], (fb * s[None, :]).T

            a, b = jax.vmap(fac)(w)
            w_res = w - jnp.einsum("nik,nkj->nij", a, b) * 1.0
            base = set_by_path(
                base, path,
                w_res.reshape(p.shape).astype(get_by_path(params, path).dtype))
        else:
            raise ValueError(pcfg.kind)
        entry = {"a": a, "b": b}
        if pcfg.kind == "dora":
            w = _mat(get_by_path(params, path), p).astype(jnp.float32)
            entry["mag"] = jnp.linalg.norm(w, axis=1)     # (ns, cols)
        adapters[path] = entry
    return adapters, base


def merge(base, adapters, plan: dict[str, TensorPlan], pcfg: PeftConfig):
    """Effective params = base ⊕ adapters."""
    out = base
    scale = 1.0 if pcfg.kind == "pissa" else pcfg.scale
    for path, entry in adapters.items():
        p = plan[path]
        leaf = get_by_path(base, path)
        w = _mat(leaf, p).astype(jnp.float32)
        delta = jnp.einsum("nik,nkj->nij", entry["a"], entry["b"]) * scale
        w_new = w + delta
        if pcfg.kind == "dora":
            col = jnp.linalg.norm(w_new, axis=1, keepdims=True)     # (ns,1,c)
            w_new = w_new / jnp.maximum(col, 1e-8) \
                * entry["mag"][:, None, :]
        out = set_by_path(out, path, w_new.reshape(p.shape).astype(leaf.dtype))
    return out


def adapter_param_count(adapters) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(adapters))
