"""Low-rank approximation backends for LIFT.

Two interchangeable backends produce the rank-r factors (A, B) with
W' = A @ B^T (A: m x r carries the singular values):

  * `exact`      — full `jnp.linalg.svd` (the paper's method; O(mn·min(m,n)),
                   single-device only, used for tests and small models).
  * `randomized` — subspace iteration with oversampling (matmul-dominant:
                   MXU-friendly and shardable under pjit; the TPU-native
                   default, DESIGN.md §3).

Also implements the App. B.2 ablation strategies over which part of the
spectrum to keep: largest / smallest / random / hybrid.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def exact_lowrank(w: jax.Array, rank: int,
                  strategy: str = "largest",
                  key: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Rank-r factors of w (m, n) by exact SVD.  Returns (A (m,r), B (n,r))."""
    m, n = w.shape
    rank = min(rank, m, n)
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    nsv = s.shape[0]
    if strategy == "largest":
        sel = jnp.arange(rank)
    elif strategy == "smallest":
        sel = jnp.arange(nsv - rank, nsv)
    elif strategy == "random":
        assert key is not None
        sel = jax.random.permutation(key, nsv)[:rank]
    elif strategy == "hybrid":
        half = rank // 2
        sel = jnp.concatenate([jnp.arange(half),
                               jnp.arange(nsv - (rank - half), nsv)])
    else:
        raise ValueError(strategy)
    a = u[:, sel] * s[sel][None, :]
    b = vt[sel, :].T
    return a, b


def randomized_lowrank(w: jax.Array, rank: int, *,
                       oversample: int = 8, iters: int = 2,
                       key: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Randomized subspace iteration.  Returns (A (m,r), B (n,r)), W' = A B^T.

    Only tall-skinny (m, r+p) / (n, r+p) intermediates are materialized, so
    the factorization of a TP-sharded W runs with local matmuls + small
    collectives under pjit.
    """
    m, n = w.shape
    rank = min(rank, m, n)
    p = min(oversample, max(m, n) - rank)
    key = key if key is not None else jax.random.PRNGKey(0)
    w32 = w.astype(jnp.float32)
    omega = jax.random.normal(key, (n, rank + p), jnp.float32)
    y = w32 @ omega                                   # (m, r+p)
    q, _ = jnp.linalg.qr(y)
    for _ in range(iters):
        z = w32.T @ q                                 # (n, r+p)
        qz, _ = jnp.linalg.qr(z)
        y = w32 @ qz
        q, _ = jnp.linalg.qr(y)
    b_small = q.T @ w32                               # (r+p, n)
    u_s, s, vt = jnp.linalg.svd(b_small, full_matrices=False)
    a = (q @ u_s[:, :rank]) * s[:rank][None, :]
    b = vt[:rank, :].T
    return a, b


def lowrank_factors(w: jax.Array, rank: int, *, method: str = "randomized",
                    strategy: str = "largest",
                    key: Optional[jax.Array] = None,
                    oversample: int = 8, iters: int = 2):
    """Dispatch.  Non-"largest" strategies force the exact backend."""
    if method == "exact" or strategy != "largest":
        return exact_lowrank(w, rank, strategy, key)
    return randomized_lowrank(w, rank, oversample=oversample, iters=iters,
                              key=key)


def reconstruct(a: jax.Array, b: jax.Array) -> jax.Array:
    return a @ b.T


def spectral_norm(w: jax.Array, iters: int = 32,
                  key: Optional[jax.Array] = None) -> jax.Array:
    """Largest singular value by power iteration (fp32)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    w32 = w.astype(jnp.float32)
    v = jax.random.normal(key, (w.shape[1],), jnp.float32)
    v = v / jnp.linalg.norm(v)

    def body(v, _):
        u = w32 @ v
        u = u / jnp.maximum(jnp.linalg.norm(u), 1e-30)
        v2 = w32.T @ u
        s = jnp.linalg.norm(v2)
        return v2 / jnp.maximum(s, 1e-30), s

    v, ss = jax.lax.scan(body, v, None, length=iters)
    return ss[-1]
