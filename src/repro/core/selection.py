"""Streaming Principal-Weight SelectionEngine (DESIGN.md §3).

The single mask-selection path for the whole codebase.  Everything that
needs Principal-Weight indices — trainer init, periodic mask refresh,
checkpoint round-trips, benchmarks — goes through one engine so the
low-rank factorization, score statistics, index extraction and
optimizer-state migration are fused into ONE jitted program per use
(init-select / refresh) instead of a per-tensor Python dispatch loop.

Pipeline per eligible tensor (paper §3.2, Algorithm 1):

    W --(lowrank_factors)--> (A, B) --> score |A B^T| --> top-k indices

with two interchangeable backends for the score->indices step:

  * "dense"     — materialize the (rows, cols) score matrix, `lax.top_k`
                  (the paper's literal method; exact, memory-heavy);
  * "streaming" — Pallas histogram threshold search (`lift_threshold`)
                  followed by the blockwise compaction kernel
                  (`lift_indices`): W' and the score matrix never touch
                  HBM, every intermediate is O(k) or O(tiles).

Backend choice is `LiftConfig.use_kernel` — streaming requires the "lift"
selection rule and unstructured masks (block_size == 1); anything else
falls back to dense inside the same engine program.

Batching: tensors are grouped by (rows, cols, k) geometry; each group is
stacked into one (ns_total, rows, cols) batch so the factorization vmaps
across layers/experts/paths and the selection kernel runs under one
`lax.map` — one XLA program for the whole plan, not N dispatches.

Per-matrix PRNG keys are derived exactly as the historical
`compute_indices` did (split over sorted paths, then over the stack), so
dense-backend results are bit-identical to the pre-engine code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lift as liftmod
from repro.core import lowrank
from repro.core.lift import (LiftConfig, TensorPlan, get_by_path, make_plan,
                             _leaf_matrices)

PLAN_META_VERSION = 1


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Tensors sharing (rows, cols, k) — selected as one stacked batch."""
    rows: int
    cols: int
    k: int
    paths: tuple          # sorted-path order
    stacks: tuple         # matrices per path (prod of stack dims)


def _num_stack(plan: TensorPlan) -> int:
    return int(np.prod(plan.stack)) if plan.stack else 1


class SelectionEngine:
    """Batched, kernel-backed Principal-Weight selection over a plan."""

    def __init__(self, plan: dict[str, TensorPlan], cfg: LiftConfig):
        self.cfg = cfg
        self.plan = dict(plan)
        self.paths = sorted(plan)
        self.backend = ("streaming"
                        if (cfg.use_kernel and cfg.selection == "lift"
                            and cfg.block_size == 1)
                        else "dense")
        groups: dict[tuple, list] = {}
        for path in self.paths:
            p = self.plan[path]
            groups.setdefault((p.rows, p.cols, p.k), []).append(path)
        self.groups = tuple(
            GroupSpec(rows=r, cols=c, k=k, paths=tuple(ps),
                      stacks=tuple(_num_stack(self.plan[q]) for q in ps))
            for (r, c, k), ps in groups.items())
        # jitted lazily at first call so tests can patch the score path
        # before tracing; one program per entry point.
        self._select_jit = jax.jit(self._select_impl)
        self._refresh_jit = jax.jit(self._refresh_impl)

    @classmethod
    def from_spec(cls, spec_tree, cfg: LiftConfig) -> "SelectionEngine":
        return cls(make_plan(spec_tree, cfg), cfg)

    # ----------------------------------------------------------- selection
    def select(self, params, key, grads=None) -> dict[str, jax.Array]:
        """{path: (n_stack, k) int32} — flat indices, sorted per matrix."""
        return self.select_with_stats(params, key, grads)[0]

    def select_with_stats(self, params, key, grads=None):
        """(indices, stats) where stats = {"overflow": i32 scalar} counts
        candidate entries dropped by compaction-capacity overflow (always 0
        on the dense backend; investigate `compact_factor` if nonzero)."""
        return self._select_jit(params, key, grads)

    def refresh_opt(self, params, opt_state, key):
        """Fused mask refresh: select new indices AND migrate the sparse
        optimizer state (Algorithm 1 lines 5-12) in one jitted program.
        `params` may be the planned subtree or the full tree."""
        return self._refresh_jit(params, opt_state, key)

    # ------------------------------------------------------ jitted bodies
    def _select_impl(self, params, key, grads):
        keys = dict(zip(self.paths, jax.random.split(key, len(self.paths))))
        out: dict[str, jax.Array] = {}
        overflow = jnp.zeros((), jnp.int32)
        for g in self.groups:
            ws, gs, ks = [], [], []
            for path in g.paths:
                p = self.plan[path]
                ws.append(_leaf_matrices(get_by_path(params, path), p))
                ks.append(jax.random.split(keys[path], _num_stack(p)))
                if grads is not None:
                    gs.append(_leaf_matrices(get_by_path(grads, path), p))
            w = jnp.concatenate(ws) if len(ws) > 1 else ws[0]
            kk = jnp.concatenate(ks) if len(ks) > 1 else ks[0]
            gg = None
            if grads is not None:
                gg = jnp.concatenate(gs) if len(gs) > 1 else gs[0]
            if self.backend == "streaming":
                idx, ovf = self._stream_group(w, kk, g)
                overflow = overflow + jnp.sum(ovf)
            else:
                idx = self._dense_group(w, kk, gg, g)
            off = 0
            for path, ns in zip(g.paths, g.stacks):
                out[path] = idx[off:off + ns].astype(jnp.int32)
                off += ns
        return out, {"overflow": overflow}

    def _stream_group(self, w, kk, g: GroupSpec):
        """Streaming selection for one (ns, rows, cols) stacked batch:
        factorize (vmapped), then threshold + compaction kernels under one
        lax.map — no (rows, cols) score intermediate anywhere."""
        cfg = self.cfg
        a, b = jax.vmap(
            lambda w2d, k1: lowrank.lowrank_factors(
                w2d, cfg.rank, method=cfg.method, strategy=cfg.strategy,
                key=k1, oversample=cfg.oversample, iters=cfg.power_iters)
        )(w, kk)
        from repro.kernels import ops as kops
        bm, bn = kops.pick_block(g.rows), kops.pick_block(g.cols)
        capacity = kops.compact_capacity(g.rows, g.cols, g.k, bm, bn,
                                         cfg.compact_factor)

        def one(ab):
            idx, _tau, ovf = kops.lift_indices(
                ab[0], ab[1], g.k, capacity=capacity, bm=bm, bn=bn)
            return idx, ovf

        return jax.lax.map(one, (a, b))

    def _dense_group(self, w, kk, gg, g: GroupSpec):
        cfg = self.cfg

        def one(w2d, key1, g2d=None):
            s = liftmod.scores_for(w2d, cfg, cfg.selection, key1, g2d)
            return liftmod.topk_indices(s, g.k, cfg.block_size)

        if gg is None:
            return jax.vmap(lambda a, b: one(a, b))(w, kk)
        return jax.vmap(lambda a, b, c: one(a, b, c))(w, kk, gg)

    def _refresh_impl(self, params, opt_state, key):
        from repro.core import sparse_adam as sa
        idx, stats = self._select_impl(params, key, None)
        return sa.migrate(params, opt_state, idx, self.plan), stats

    # ------------------------------------------------- checkpoint metadata
    def plan_meta(self) -> dict:
        """JSON-able plan fingerprint stored alongside checkpoints so a
        resumed run can prove its selection geometry matches the (ns, k)
        optimizer state on disk before restoring it."""
        return {
            "version": PLAN_META_VERSION,
            "backend": self.backend,
            "selection": self.cfg.selection,
            "block_size": self.cfg.block_size,
            "tensors": {
                path: {"shape": list(p.shape), "stack": list(p.stack),
                       "rows": p.rows, "cols": p.cols, "k": p.k}
                for path, p in self.plan.items()},
        }

    def validate_meta(self, meta: Optional[dict]) -> None:
        """Raise ValueError if a checkpoint's selection metadata is
        incompatible with this engine's plan (geometry or k mismatch —
        e.g. the density/rank flags changed between runs)."""
        if not meta:
            return
        saved = meta.get("tensors", {})
        missing = sorted(set(saved) ^ set(self.plan))
        if missing:
            raise ValueError(
                f"checkpoint selection plan covers different tensors than "
                f"the current config (first mismatch: {missing[0]!r})")
        for path, p in self.plan.items():
            s = saved[path]
            got = (list(p.shape), p.rows, p.cols, p.k)
            want = (list(s["shape"]), s["rows"], s["cols"], s["k"])
            if got != want:
                raise ValueError(
                    f"checkpoint selection geometry mismatch for {path!r}: "
                    f"saved shape/rows/cols/k {want} vs current {got} — "
                    f"restart with the original density/rank/block flags "
                    f"or discard the checkpoint")
