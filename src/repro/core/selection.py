"""Streaming Principal-Weight SelectionEngine (DESIGN.md §3).

The single mask-selection path for the whole codebase.  Everything that
needs Principal-Weight indices — trainer init, periodic mask refresh,
checkpoint round-trips, benchmarks — goes through one engine so the
low-rank factorization, score statistics, index extraction and
optimizer-state migration are fused into ONE jitted program per use
(init-select / refresh) instead of a per-tensor Python dispatch loop.

Pipeline per eligible tensor (paper §3.2, Algorithm 1):

    W --(lowrank_factors)--> (A, B) --> score |A B^T| --> top-k indices

with two interchangeable backends for the score->indices step:

  * "dense"     — materialize the (rows, cols) score matrix, `lax.top_k`
                  (the paper's literal method; exact, memory-heavy);
  * "streaming" — Pallas histogram threshold search (`lift_threshold`)
                  followed by the blockwise compaction kernel
                  (`lift_indices`): W' and the score matrix never touch
                  HBM, every intermediate is O(k) or O(tiles).

Backend choice is `LiftConfig.use_kernel` — streaming requires the "lift"
selection rule; anything else falls back to dense inside the same engine
program.  Structured LIFT (`block_size` > 1, paper App. G.7) runs the
SAME streaming pipeline at block granularity: the kernels block-sum each
tile's scores in VMEM, threshold search + compaction select k/bs^2
blocks, and the block indices expand to elements on the O(k) output —
in every engine mode (fused single-device, shard_map collective, and
quota="local").

Dense non-"lift" backends (magnitude / random / gradient / movement) no
longer gather full tensors under a mesh either: geometry groups whose
cols divide the shard axis run as a "dense-sharded" shard_map collective
(per-shard `lax.top_k` of local slab scores, one O(k) all-gather, exact
(value desc, index asc) merge — bitwise-identical to the single-device
dense selection).

Batching: tensors are grouped by (rows, cols, k) geometry; each group is
stacked into one (ns_total, rows, cols) batch so the factorization vmaps
across layers/experts/paths and the selection kernel runs under one
`lax.map` — one XLA program for the whole plan, not N dispatches.

Per-matrix PRNG keys are derived exactly as the historical
`compute_indices` did (split over sorted paths, then over the stack), so
dense-backend results are bit-identical to the pre-engine code.

Sharding (DESIGN.md §3): the engine captures the active mesh
(`parallel/sharding.py` ctx) at construction.  When the mesh maps the
"shards" logical axis onto >1 devices and the backend is streaming, each
geometry group whose cols divide over the shard axis runs as a shard_map
collective: per-shard histograms psum into the threshold search,
compaction stays shard-local, and the merge is one O(k) all-gather of
candidate indices (`kernels.ops.lift_indices_sharded`) — factors are
consumed where the weights live, never gathered.  Quota modes:

  * quota="global" — one global top-k; the sharded run is
    bitwise-identical to single-device selection (psum'd integer
    histograms -> same tau -> same candidate set);
  * quota="local"  — every column slab gets an exact k/n_shards budget
    (per-shard threshold search, NO cross-shard reduction); unifies the
    former `core/local_quota.py` side path into this engine, on both
    backends (dense `local_topk_indices` / streaming
    `lift_indices_local` / collective `lift_indices_sharded`).

Groups whose geometry does not divide over the mesh fall back to the
unsharded program (see `group_exec`); selected (ns, k) index sets are
constrained along the "topk" logical axis when k divides.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lift as liftmod
from repro.core import lowrank
from repro.core.lift import (LiftConfig, TensorPlan, get_by_path, make_plan,
                             _leaf_matrices)
from repro.core.local_quota import local_topk_indices
from repro.parallel import sharding as shd

PLAN_META_VERSION = 1


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """Tensors sharing (rows, cols, k) — selected as one stacked batch."""
    rows: int
    cols: int
    k: int
    paths: tuple          # sorted-path order
    stacks: tuple         # matrices per path (prod of stack dims)


def _num_stack(plan: TensorPlan) -> int:
    return int(np.prod(plan.stack)) if plan.stack else 1


class SelectionEngine:
    """Batched, kernel-backed Principal-Weight selection over a plan."""

    def __init__(self, plan: dict[str, TensorPlan], cfg: LiftConfig):
        self.cfg = cfg
        self.plan = dict(plan)
        self.paths = sorted(plan)
        self.backend = ("streaming"
                        if (cfg.use_kernel and cfg.selection == "lift")
                        else "dense")
        # mesh snapshot: the engine's jitted programs bake the sharding
        # decision at construction (set the ctx BEFORE building the engine)
        if cfg.quota not in ("global", "local"):
            raise ValueError(f"unknown quota mode {cfg.quota!r} "
                             f"(expected 'global' or 'local')")
        self.mesh = shd.active_mesh()
        axes = shd.mesh_axes_for("shards", self.mesh)
        self.shard_axis = axes[0] if len(axes) == 1 else None
        self.mesh_shards = (int(self.mesh.shape[self.shard_axis])
                            if (self.mesh is not None and self.shard_axis)
                            else 1)
        self.quota_shards = 1
        if cfg.quota == "local":
            self.quota_shards = int(cfg.quota_shards) or self.mesh_shards
            if self.quota_shards < 1:
                raise ValueError(
                    f"quota='local' needs quota_shards >= 1 "
                    f"(got {cfg.quota_shards})")
            bs = cfg.block_size
            for path in self.paths:
                p = self.plan[path]
                if p.cols % self.quota_shards or p.k % self.quota_shards:
                    raise ValueError(
                        f"quota='local' with n_shards={self.quota_shards} "
                        f"does not tile tensor {path!r}: cols={p.cols}, "
                        f"k={p.k} must both be divisible by n_shards — "
                        f"adjust quota_shards / k_multiple or exclude the "
                        f"tensor via min_dim/scope")
                if bs > 1 and self.quota_shards > 1 and (
                        (p.cols // self.quota_shards) % bs
                        or (p.k // self.quota_shards) % (bs * bs)):
                    raise ValueError(
                        f"quota='local' with n_shards={self.quota_shards} "
                        f"does not tile structured tensor {path!r}: slab "
                        f"cols={p.cols // self.quota_shards} must divide "
                        f"by block_size={bs} and the per-slab quota "
                        f"k={p.k // self.quota_shards} by block_size^2 — "
                        f"adjust quota_shards/block_size or exclude the "
                        f"tensor via min_dim/scope")
        groups: dict[tuple, list] = {}
        for path in self.paths:
            p = self.plan[path]
            groups.setdefault((p.rows, p.cols, p.k), []).append(path)
        self.groups = tuple(
            GroupSpec(rows=r, cols=c, k=k, paths=tuple(ps),
                      stacks=tuple(_num_stack(self.plan[q]) for q in ps))
            for (r, c, k), ps in groups.items())
        # {(rows, cols, k): how the group's selection executes} — the
        # parity tests and plan_meta introspect this
        self.group_exec = {
            (g.rows, g.cols, g.k): self._exec_mode(g) for g in self.groups}
        # adapted per-tensor compaction factors (ROADMAP follow-up):
        # `retry_overflow` records every factor it had to raise here, and
        # all later fused programs start at the adapted capacity instead
        # of re-overflowing — the fused select/refresh programs are
        # cached per adapted-factor fingerprint and re-traced only when
        # a retry raises a factor.
        self.adapted_factors: dict[str, int] = {}
        # the adapted-factor fingerprint rides along as a STATIC jit arg:
        # a raised factor changes the fingerprint and forces a re-trace
        # (the factors themselves are read from self.adapted_factors at
        # trace time).  Two jax.jit wrappers over the same bound method
        # share jax's trace cache — a static arg is the reliable key.
        from repro import obs as obs_mod
        self._select_jit = obs_mod.instrument_jit(
            self._select_impl, name="selection.select",
            static_argnames=("factors_fp",))
        self._refresh_jit = obs_mod.instrument_jit(
            self._refresh_impl, name="selection.refresh",
            static_argnames=("factors_fp",))
        # per-(geometry, compact_factor) retry programs (overflow recovery)
        self._retry_cache: dict = {}

    def _mesh_divides(self, g: GroupSpec) -> bool:
        """Can this group's columns slab over the mesh's shard axis?
        Structured groups additionally need block-aligned slabs, so a
        (bs x bs) block never straddles two devices."""
        return (self.mesh is not None and self.shard_axis is not None
                and self.mesh_shards > 1
                and g.cols % self.mesh_shards == 0
                and (g.cols // self.mesh_shards) % self.cfg.block_size == 0)

    _DENSE_SHARDABLE = ("magnitude", "random", "gradient", "movement")

    def _exec_mode(self, g: GroupSpec) -> str:
        """dense | dense-sharded | streaming | streaming-local | sharded |
        sharded-local."""
        if self.backend == "dense":
            # non-"lift" score rules compute per-slab scores straight from
            # the shard's local slab (or position-stable PRNG draws), so
            # they select collectively via per-shard top_k + O(k) merge;
            # dense "lift" needs the full W for factorization and stays
            # unsharded, as does the dense local-quota path (already
            # slab-exact by construction)
            if (self.cfg.selection in self._DENSE_SHARDABLE
                    and self.cfg.quota == "global"
                    and self._mesh_divides(g)):
                return "dense-sharded"
            return "dense"
        local = self.cfg.quota == "local" and self.quota_shards > 1
        sharded = (self._mesh_divides(g)
                   # a local quota only stays collective-free if the slab
                   # count IS the mesh's shard count
                   and (not local or self.quota_shards == self.mesh_shards))
        if sharded:
            return "sharded-local" if local else "sharded"
        return "streaming-local" if local else "streaming"

    @classmethod
    def from_spec(cls, spec_tree, cfg: LiftConfig) -> "SelectionEngine":
        return cls(make_plan(spec_tree, cfg), cfg)

    # ----------------------------------------------------------- selection
    def select(self, params, key, grads=None) -> dict[str, jax.Array]:
        """{path: (n_stack, k) int32} — flat indices, sorted per matrix."""
        return self.select_with_stats(params, key, grads)[0]

    def select_with_stats(self, params, key, grads=None):
        """(indices, stats) where stats = {"overflow": i32 scalar,
        "overflow_by_path": {path: i32 scalar}} counts candidate entries
        dropped by compaction-capacity overflow (always 0 on the dense
        backend).  A nonzero count means a degraded mask for that tensor —
        `retry_overflow` recovers it host-side with a doubled
        `compact_factor` AND persists the raised factor, so later calls
        select at the adapted capacity up front."""
        return self._select_jit(params, key, grads,
                                factors_fp=self._factor_fingerprint())

    def refresh_opt(self, params, opt_state, key):
        """Fused mask refresh: select new indices AND migrate the sparse
        optimizer state (Algorithm 1 lines 5-12) in one jitted program.
        `params` may be the planned subtree or the full tree."""
        return self._refresh_jit(params, opt_state, key,
                                 factors_fp=self._factor_fingerprint())

    def _factor_fingerprint(self) -> tuple:
        """Hashable snapshot of the adapted per-tensor factors — the key
        the fused-program caches re-trace on."""
        return tuple(sorted(self.adapted_factors.items()))

    def _group_factor(self, g: GroupSpec) -> int:
        """A group's compaction factor: the config default raised to the
        largest adapted factor of any tensor in the group (the group is
        selected as one stacked batch, so its capacity is shared)."""
        return max([self.cfg.compact_factor]
                   + [self.adapted_factors.get(p, 0) for p in g.paths])

    # -------------------------------------------- overflow-adaptive retry
    def retry_overflow(self, params, key, indices, stats, *,
                       max_factor: int = 256):
        """Overflow-adaptive compaction capacity (ROADMAP item): when the
        fused program reports dropped candidates for a tensor, re-run
        ONLY that tensor's selection host-side with a doubled
        `compact_factor` (doubling again until clean or `max_factor`),
        off the hot path.  `key` MUST be the key the degraded selection
        ran with — per-path PRNG keys are re-derived identically, so a
        clean retry returns exactly the indices the fused program would
        have returned with enough capacity.

        Every factor this retry raises is PERSISTED in
        `self.adapted_factors`, so subsequent fused selections/refreshes
        start at the adapted capacity instead of re-overflowing (the
        fused programs re-trace once per adaptation).

        Returns (new_indices, retried, unresolved): `indices` with the
        affected paths replaced, the retried path names (log these), and
        the paths still overflowing at `max_factor` (degraded masks).
        Reading the overflow stat forces a device sync — ONE scalar D2H
        in the (overwhelmingly common) clean case, plus one batched
        transfer of the per-path counts only when it is nonzero; callers
        gate the whole call behind `LiftConfig.overflow_retry`."""
        if self.backend != "streaming":
            return indices, [], []
        if int(jax.device_get(stats["overflow"])) == 0:
            return indices, [], []
        by_path = jax.device_get(stats.get("overflow_by_path") or {})
        bad = [p for p in self.paths if int(by_path.get(p, 0)) > 0]
        if not bad:
            return indices, [], []
        keys = dict(zip(self.paths, jax.random.split(key, len(self.paths))))
        out = dict(indices)
        unresolved = []
        for path in bad:
            p = self.plan[path]
            w = _leaf_matrices(get_by_path(params, path), p)
            kk = jax.random.split(keys[path], _num_stack(p))
            factor = max(self.cfg.compact_factor,
                         self.adapted_factors.get(path, 0))
            while True:                  # always at least one doubling
                factor *= 2
                idx, ovf = self._retry_one(w, kk, p, factor)
                if int(jax.device_get(ovf)) == 0 or factor >= max_factor:
                    break
            self.adapted_factors[path] = factor
            sel = idx.astype(jnp.int32)
            if self.mesh is not None:
                sel = shd.shard_logical_if_divisible(
                    sel, (None, "topk"), mesh=self.mesh)
            out[path] = sel
            if int(jax.device_get(ovf)) > 0:
                unresolved.append(path)
        return out, bad, unresolved

    def _retry_one(self, w, kk, plan: TensorPlan, factor: int):
        """One tensor's streaming selection at an enlarged compaction
        capacity (jitted per (geometry, factor), cached) — the SAME
        `_factors` + `_stream_select` body as the fused program, only
        with a bigger factor.  Runs unsharded even for collective groups:
        off the hot path, and a clean global-quota selection is
        capacity-independent, so the result matches what the collective
        path would return un-overflowed."""
        key_t = (plan.rows, plan.cols, plan.k, factor)
        fn = self._retry_cache.get(key_t)
        if fn is None:
            rows, cols, k = plan.rows, plan.cols, plan.k

            def body(w, kk):
                a, b = self._factors(w, kk)
                idx, ovf = self._stream_select(a, b, rows, cols, k, factor)
                return idx.astype(jnp.int32), jnp.sum(ovf)

            from repro import obs as obs_mod
            # workload-keyed by design (one program per geometry +
            # adapted factor): the manifest lists it as {"any": true}
            fn = obs_mod.instrument_jit(body, name="selection.retry")
            self._retry_cache[key_t] = fn
        return fn(w, kk)

    # ------------------------------------------------------ jitted bodies
    def _select_impl(self, params, key, grads, factors_fp=()):
        del factors_fp          # static trace-cache key only (see __init__)
        keys = dict(zip(self.paths, jax.random.split(key, len(self.paths))))
        out: dict[str, jax.Array] = {}
        overflow = jnp.zeros((), jnp.int32)
        by_path: dict[str, jax.Array] = {}
        for g in self.groups:
            ws, gs, ks = [], [], []
            for path in g.paths:
                p = self.plan[path]
                ws.append(_leaf_matrices(get_by_path(params, path), p))
                ks.append(jax.random.split(keys[path], _num_stack(p)))
                if grads is not None:
                    gs.append(_leaf_matrices(get_by_path(grads, path), p))
            w = jnp.concatenate(ws) if len(ws) > 1 else ws[0]
            kk = jnp.concatenate(ks) if len(ks) > 1 else ks[0]
            gg = None
            if grads is not None:
                gg = jnp.concatenate(gs) if len(gs) > 1 else gs[0]
            ovf = None
            if self.backend == "streaming":
                idx, ovf = self._stream_group(w, kk, g)
                overflow = overflow + jnp.sum(ovf)
            elif self.group_exec[(g.rows, g.cols, g.k)] == "dense-sharded":
                idx = self._dense_group_sharded(w, kk, gg, g)
            else:
                idx = self._dense_group(w, kk, gg, g)
            off = 0
            for path, ns in zip(g.paths, g.stacks):
                sel = idx[off:off + ns].astype(jnp.int32)
                if self.mesh is not None:
                    # (ns, k) index sets shard along the "topk" logical
                    # axis when k divides the mapped mesh axes
                    sel = shd.shard_logical_if_divisible(
                        sel, (None, "topk"), mesh=self.mesh)
                out[path] = sel
                by_path[path] = (jnp.sum(ovf[off:off + ns])
                                 if ovf is not None
                                 else jnp.zeros((), jnp.int32))
                off += ns
        return out, {"overflow": overflow, "overflow_by_path": by_path}

    def _factors(self, w, kk):
        """vmapped low-rank factorization of a (ns, rows, cols) stack —
        the one place the lowrank_factors call is spelled out, shared by
        the fused group program and the overflow retry."""
        cfg = self.cfg
        return jax.vmap(
            lambda w2d, k1: lowrank.lowrank_factors(
                w2d, cfg.rank, method=cfg.method, strategy=cfg.strategy,
                key=k1, oversample=cfg.oversample, iters=cfg.power_iters)
        )(w, kk)

    def _local_capacity(self, rows: int, cols: int, k: int,
                        factor: Optional[int] = None) -> int:
        """Per-slab compaction budget for quota='local' — computed once
        here so the single-device (`lift_indices_local`) and collective
        (`lift_indices_sharded`) paths use the identical value and stay
        bitwise-comparable.  In score units: elements, or blocks for
        structured LIFT (`select_tiling` owns the arithmetic)."""
        from repro.kernels import ops as kops
        factor = self.cfg.compact_factor if factor is None else factor
        w = cols // self.quota_shards
        _bm, _bn, cap = kops.select_tiling(rows, w, k // self.quota_shards,
                                           self.cfg.block_size,
                                           factor=factor)
        return cap

    def _stream_select(self, a, b, rows: int, cols: int, k: int,
                       factor: int):
        """Unsharded streaming selection over a stacked factor batch at
        the given compaction factor: threshold + compaction kernels per
        matrix under one lax.map, honoring the quota mode and the
        structured block size.  The SINGLE body behind both the fused
        group program (factor = cfg.compact_factor) and
        `retry_overflow`'s doubled factors — a clean retry is
        bitwise-identical to a clean fused run because they are literally
        this code."""
        from repro.kernels import ops as kops
        bs = self.cfg.block_size
        if self.cfg.quota == "local" and self.quota_shards > 1:
            capacity = self._local_capacity(rows, cols, k, factor)

            def one(ab):
                idx, _taus, ovf = kops.lift_indices_local(
                    ab[0], ab[1], k, n_shards=self.quota_shards,
                    capacity=capacity, block_size=bs)
                return idx, ovf
        else:
            bm, bn, capacity = kops.select_tiling(rows, cols, k, bs,
                                                  factor=factor)

            def one(ab):
                idx, _tau, ovf = kops.lift_indices(
                    ab[0], ab[1], k, capacity=capacity, bm=bm, bn=bn,
                    block_size=bs)
                return idx, ovf

        return jax.lax.map(one, (a, b))

    def _stream_group(self, w, kk, g: GroupSpec):
        """Streaming selection for one (ns, rows, cols) stacked batch:
        factorize (vmapped), then threshold + compaction kernels under one
        lax.map — no (rows, cols) score intermediate anywhere.  Groups
        whose cols divide over the mesh's "shards" axis run the whole
        pipeline as a shard_map collective instead (per-shard histograms,
        shard-local compaction, O(k) all-gather merge)."""
        a, b = self._factors(w, kk)
        mode = self.group_exec[(g.rows, g.cols, g.k)]
        if mode in ("sharded", "sharded-local"):
            return self._stream_group_sharded(a, b, g, mode)
        return self._stream_select(a, b, g.rows, g.cols, g.k,
                                   self._group_factor(g))

    def _stream_group_sharded(self, a, b, g: GroupSpec, mode: str):
        """Collective selection for one stacked factor batch: B slabs stay
        sharded over the "shards" mesh axis (in_specs) and each matrix in
        the stack runs `lift_indices_sharded` under the mapped mesh —
        per-device memory is O(rows/n_shards · r) factors plus the
        O(compact_factor · k / n_shards) candidate buffer."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops as kops
        quota = "local" if mode == "sharded-local" else "global"
        factor = self._group_factor(g)
        capacity = (self._local_capacity(g.rows, g.cols, g.k, factor)
                    if quota == "local" else 0)
        axis, n_shards = self.shard_axis, self.mesh_shards

        def body(a3, b3):
            def one(ab):
                idx, _tau, ovf = kops.lift_indices_sharded(
                    ab[0], ab[1], g.k, axis_name=axis, n_shards=n_shards,
                    cols_global=g.cols, quota=quota, capacity=capacity,
                    compact_factor=factor,
                    block_size=self.cfg.block_size)
                return idx, ovf

            return jax.lax.map(one, (a3, b3))

        bspec = shd.logical_to_spec((None, "shards", None), self.mesh)
        return shard_map(body, mesh=self.mesh, in_specs=(P(), bspec),
                         out_specs=(P(), P()), check_rep=False)(a, b)

    def _dense_group(self, w, kk, gg, g: GroupSpec):
        cfg = self.cfg

        def one(w2d, key1, g2d=None):
            s = liftmod.scores_for(w2d, cfg, cfg.selection, key1, g2d)
            if self.quota_shards > 1:
                return local_topk_indices(s, g.k, self.quota_shards,
                                          block_size=cfg.block_size)
            return liftmod.topk_indices(s, g.k, cfg.block_size)

        if gg is None:
            return jax.vmap(lambda a, b: one(a, b))(w, kk)
        return jax.vmap(lambda a, b, c: one(a, b, c))(w, kk, gg)

    def _dense_group_sharded(self, w, kk, gg, g: GroupSpec):
        """Dense-fallback selection as a shard_map collective (ROADMAP
        PR 2 follow-up): each shard scores ONLY its column slab
        (magnitude/gradient/movement read the local weights; random draws
        position-stable PRNG bits), takes its local top-k, and the merge
        is one O(k) all-gather + exact (value desc, index asc) prefix —
        no full (rows, cols) tensor is ever gathered across the mesh.

        Bitwise-identical to the single-device dense path: per-shard
        `lax.top_k` keeps each shard's best candidates under the same
        total order the global top_k uses (its value-then-lowest-index
        tie-break restricted to a column slab agrees with the global
        flat-index order), so the merged k-prefix is the same set."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.kernels import ops as kops
        cfg = self.cfg
        bs = cfg.block_size
        axis, n_shards = self.shard_axis, self.mesh_shards
        rows, cols = g.rows, g.cols
        nl = cols // n_shards
        kb = g.k // (bs * bs)               # selection units (blocks)
        nbc = cols // bs                    # global unit columns
        nlb = nl // bs                      # this shard's unit columns
        kloc = min(kb, (rows // bs) * nlb)  # per-shard candidate count

        def local_scores(w2d, key1, g2d):
            if cfg.selection == "magnitude":
                return jnp.abs(w2d.astype(jnp.float32))
            if cfg.selection in ("gradient", "movement"):
                assert g2d is not None, \
                    f"{cfg.selection} selection needs a gradient sample"
            if cfg.selection == "gradient":
                return jnp.abs(g2d.astype(jnp.float32))
            if cfg.selection == "movement":
                return (-w2d.astype(jnp.float32)
                        * g2d.astype(jnp.float32))
            # "random": scores are position-stable PRNG draws, identical
            # on every shard — draw the full matrix locally and slice the
            # slab (transient VMEM/registers, but ZERO cross-shard
            # traffic and bitwise parity with the single-device draw)
            s = jax.random.uniform(key1, (rows, cols), jnp.float32)
            col0 = jax.lax.axis_index(axis) * nl
            return jax.lax.dynamic_slice(s, (0, col0), (rows, nl))

        def one(w2d, key1, g2d):
            s = local_scores(w2d, key1, g2d)
            if bs > 1:
                s = s.reshape(rows // bs, bs, nlb, bs).sum(axis=(1, 3))
            v, loc = jax.lax.top_k(s.reshape(-1), kloc)
            shard0 = jax.lax.axis_index(axis) * nlb
            gidx = loc // nlb * nbc + shard0 + loc % nlb
            vall = jax.lax.all_gather(v, axis).reshape(-1)
            gall = jax.lax.all_gather(gidx, axis).reshape(-1)
            # exact top-kb under the single-device total order:
            # value descending, global flat index ascending on ties
            order = jnp.lexsort((gall, -vall))
            sel = jnp.sort(gall[order[:kb]]).astype(jnp.int32)
            if bs > 1:
                sel = kops.expand_block_indices(sel, nbc, cols, bs)
            return sel

        wspec = shd.logical_to_spec((None, None, "shards"), self.mesh)
        if gg is None:
            def body(w3, kk2):
                return jax.vmap(lambda a, b: one(a, b, None))(w3, kk2)

            return shard_map(body, mesh=self.mesh,
                             in_specs=(wspec, P()), out_specs=P(),
                             check_rep=False)(w, kk)

        def body(w3, kk2, gg3):
            return jax.vmap(one)(w3, kk2, gg3)

        return shard_map(body, mesh=self.mesh,
                         in_specs=(wspec, P(), wspec), out_specs=P(),
                         check_rep=False)(w, kk, gg)

    def _refresh_impl(self, params, opt_state, key, factors_fp=()):
        from repro.core import sparse_adam as sa
        idx, stats = self._select_impl(params, key, None, factors_fp)
        return sa.migrate(params, opt_state, idx, self.plan), stats

    # ------------------------------------------------- checkpoint metadata
    def plan_meta(self) -> dict:
        """JSON-able plan fingerprint stored alongside checkpoints so a
        resumed run can prove its selection geometry matches the (ns, k)
        optimizer state on disk before restoring it."""
        return {
            "version": PLAN_META_VERSION,
            "backend": self.backend,
            "selection": self.cfg.selection,
            "block_size": self.cfg.block_size,
            "quota": self.cfg.quota,
            "quota_shards": self.quota_shards,
            "mesh": ({"shard_axis": self.shard_axis,
                      "n_shards": self.mesh_shards}
                     if self.mesh is not None else None),
            "group_exec": {f"{r}x{c}k{k}": mode
                           for (r, c, k), mode in self.group_exec.items()},
            "tensors": {
                path: {"shape": list(p.shape), "stack": list(p.stack),
                       "rows": p.rows, "cols": p.cols, "k": p.k}
                for path, p in self.plan.items()},
        }

    def validate_meta(self, meta: Optional[dict]) -> None:
        """Raise ValueError if a checkpoint's selection metadata is
        incompatible with this engine's plan (geometry, k or quota-policy
        mismatch — e.g. the density/rank/quota flags changed between
        runs)."""
        if not meta:
            return
        if "block_size" in meta \
                and meta["block_size"] != self.cfg.block_size:
            raise ValueError(
                f"checkpoint selection block_size mismatch: saved "
                f"block_size {meta['block_size']} vs current "
                f"{self.cfg.block_size} — the (ns, k) optimizer state on "
                f"disk was selected at a different structure granularity; "
                f"restart with the original --block-size or discard the "
                f"checkpoint")
        if "quota" in meta:  # pre-quota checkpoints pass through
            saved_q = (meta["quota"], meta.get("quota_shards", 1))
            got_q = (self.cfg.quota, self.quota_shards)
            if saved_q != got_q:
                raise ValueError(
                    f"checkpoint selection quota mismatch: saved "
                    f"quota/shards {saved_q} vs current {got_q} — the "
                    f"(ns, k) optimizer state on disk was selected under a "
                    f"different quota policy; restart with the original "
                    f"--quota/--mesh flags or discard the checkpoint")
        saved = meta.get("tensors", {})
        missing = sorted(set(saved) ^ set(self.plan))
        if missing:
            raise ValueError(
                f"checkpoint selection plan covers different tensors than "
                f"the current config (first mismatch: {missing[0]!r})")
        for path, p in self.plan.items():
            s = saved[path]
            got = (list(p.shape), p.rows, p.cols, p.k)
            want = (list(s["shape"]), s["rows"], s["cols"], s["k"])
            if got != want:
                raise ValueError(
                    f"checkpoint selection geometry mismatch for {path!r}: "
                    f"saved shape/rows/cols/k {want} vs current {got} — "
                    f"restart with the original density/rank/block flags "
                    f"or discard the checkpoint")
