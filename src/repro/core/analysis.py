"""Analysis tools reproducing the paper's §4 / §7 / App. C & G studies:
perturbation of selected weights, eigenspace alignment score (App. H.1),
update-matrix rank (App. G.3), spectral-norm change (App. C), and the
weight-update magnitude distribution (Fig. 5).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lift import TensorPlan, get_by_path, set_by_path
from repro.core.lowrank import spectral_norm


def perturb_at_indices(params, indices: dict[str, jax.Array],
                       plan: dict[str, TensorPlan], scale: float,
                       key: jax.Array):
    """Add N(0, scale^2) noise at the selected flat indices (paper §4)."""
    out = params
    paths = sorted(indices.keys())
    keys = jax.random.split(key, len(paths))
    for kk, path in zip(keys, paths):
        p = plan[path]
        leaf = get_by_path(params, path)
        ns = int(np.prod(p.stack)) if p.stack else 1
        flat = leaf.reshape(ns, p.rows * p.cols)
        idx = indices[path]
        noise = scale * jax.random.normal(kk, idx.shape, jnp.float32)
        cur = jnp.take_along_axis(flat, idx, axis=1).astype(jnp.float32)
        flat = jnp.put_along_axis(flat, idx, (cur + noise).astype(flat.dtype),
                                  axis=1, inplace=False)
        out = set_by_path(out, path, flat.reshape(p.shape))
    return out


def alignment_score(w_before: jax.Array, w_after: jax.Array,
                    top_n: int = 128) -> jax.Array:
    """App. H.1: mean squared projection of the fine-tuned top right singular
    vectors onto the pre-trained top subspace.  1 = unchanged eigenspace."""
    n = min(top_n, min(w_before.shape))
    _, _, vt0 = jnp.linalg.svd(w_before.astype(jnp.float32),
                               full_matrices=False)
    _, _, vt1 = jnp.linalg.svd(w_after.astype(jnp.float32),
                               full_matrices=False)
    v0 = vt0[:n]                     # (n, cols)
    v1 = vt1[:n]
    proj = v1 @ v0.T                 # (n, n): v1_i . v0_j
    d = jnp.sum(proj * proj, axis=1)
    return jnp.mean(d)


def update_rank(delta: jax.Array, tol_mult: float = 10.0) -> jax.Array:
    """App. G.3: count of singular values above 10x the default matrix_rank
    tolerance max(m, n) * sigma_max * eps."""
    d32 = delta.astype(jnp.float32)
    s = jnp.linalg.svd(d32, compute_uv=False)
    tol = tol_mult * max(delta.shape) * s[0] * jnp.finfo(jnp.float32).eps
    return jnp.sum(s > tol)


def spectral_norm_change(w_before: jax.Array, w_after: jax.Array,
                         key: Optional[jax.Array] = None) -> jax.Array:
    return spectral_norm(w_after, key=key) - spectral_norm(w_before, key=key)


def update_magnitude_histogram(w_before, w_after, bins: int = 61,
                               lim: float = 0.003):
    """Fig. 5: histogram of (W_after - W_before) entries."""
    delta = (np.asarray(w_after, np.float32)
             - np.asarray(w_before, np.float32)).reshape(-1)
    hist, edges = np.histogram(delta, bins=bins, range=(-lim, lim))
    return hist, edges


def tree_update_stats(before, after):
    """Aggregate |delta| stats over a param tree."""
    total, changed, sq = 0, 0, 0.0
    mx = 0.0
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        d = np.asarray(a, np.float32) - np.asarray(b, np.float32)
        total += d.size
        changed += int((d != 0).sum())
        sq += float((d * d).sum())
        mx = max(mx, float(np.abs(d).max()))
    return {"total": total, "changed": changed,
            "frac_changed": changed / max(total, 1),
            "l2": sq ** 0.5, "max": mx}
