"""Draft sources for speculative multi-token paged decode (DESIGN.md §5).

The PagedEngine's speculative decode step is draft -> verify -> accept:
a DraftSource PROPOSES up to N next tokens per decoding sequence, the
target model scores the current token plus all N drafts in one
`decode_paged_multi` dispatch, and the engine accepts the longest prefix
whose drafts match what its own sampler (`serving.api.sample_token`
on the per-request `request_rng` stream) would have emitted.  Drafts
therefore only ever change HOW MANY tokens a dispatch advances — never
which tokens come out: a wrong draft costs speculation throughput, not
correctness, so draft sources are free to be arbitrarily sloppy.

Two sources, one interface (`propose(items, n) -> {slot: [tokens]}`):

  * `NgramDraft` — prompt-lookup / n-gram drafting, no extra model: the
    longest suffix of the generated-so-far stream that reappears earlier
    in (prompt + output) predicts the tokens that followed it.  Free,
    and strong exactly when generation is repetitive (code, structured
    answers, the synthetic arithmetic serve traffic).
  * `ModelDraft` — a cheap model drafts by greedy decode with its OWN
    paged KV cache (one max_len-sized page per slot + the trash page, so
    inactive rows reuse the pool's trash-page redirect instead of a
    splice).  The LIFT-native drafter: the paper's claim is that ~5% of
    principal weights carry the fine-tune, so the UNMERGED BASE under a
    DeltaHub adapter is a nearly-free draft model whose disagreements
    with the merged target concentrate where the fine-tune matters; a
    smaller `src/repro/configs/` arch works the same way.

A draft model's cache needs no rollback bookkeeping: every propose
round writes positions [p, p + n] before any query reads them, so
rejected-draft K/V left behind by the previous round is overwritten
before it can be attended — the same stale-KV-overwrite invariant the
target's verify dispatch relies on (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class DraftSource:
    """Interface: the engine calls `begin` when a sequence enters its
    decode phase and `propose` once per speculative decode step."""

    def begin(self, slot: int, req) -> None:
        """A sequence finished prefill into `slot` (also called after a
        preemption re-admits it)."""

    def propose(self, items: list, n: int) -> dict:
        """items: [(slot, req, position, token)] — `token` is the
        engine's next dispatch input (the last emitted token), sitting
        at logical `position`.  Returns {slot: [<= n proposed tokens]};
        missing slots / short lists degrade that slot toward one-token
        decode."""
        raise NotImplementedError


class NgramDraft(DraftSource):
    """Prompt-lookup drafting: match the longest (<= max_ngram) suffix
    of the stream earlier in prompt + output and propose the tokens that
    followed the most recent match."""

    def __init__(self, max_ngram: int = 3):
        self.max_ngram = max(1, int(max_ngram))

    def propose(self, items: list, n: int) -> dict:
        out = {}
        for slot, req, _pos, _tok in items:
            ctx = np.concatenate(
                [np.asarray(req.prompt, np.int64),
                 np.asarray(req.out_tokens or [], np.int64)])
            d = self._lookup(ctx, n)
            if d:
                out[slot] = d
        return out

    def _lookup(self, ctx: np.ndarray, n: int) -> list:
        L = len(ctx)
        for m in range(min(self.max_ngram, L - 1), 0, -1):
            pat = ctx[L - m:]
            # every length-m window with a start before the suffix (the
            # original per-start scan, vectorized — the drafter runs on
            # the engine's hot path, once per decoding sequence per
            # dispatch); the most recent occurrence wins — local
            # repetition is the strongest predictor of what follows
            wins = np.lib.stride_tricks.sliding_window_view(
                ctx[:L - 1], m)
            hits = np.nonzero((wins == pat).all(axis=1))[0]
            if len(hits):
                start = int(hits[-1])
                return [int(t) for t in ctx[start + m:start + m + n]]
        return []


class ModelDraft(DraftSource):
    """Greedy draft decode with a separate (usually cheaper) model.

    The drafter serves the same slots as the target through its own
    paged cache sized one page of max_len tokens per slot: slot s owns
    physical page s + 1, page 0 is the trash page, and rows that are not
    drafting this round dispatch with a zero block table — their writes
    vanish into the trash exactly like the target engine's inactive
    slots.  `propose` runs n + 1 batched decode steps (feeding the
    engine's token, then each draft) so the drafter's cache ends the
    round written through position p + n with no holes even when every
    draft is accepted and the target moves on to a bonus token.
    """

    def __init__(self, model, params, batch_slots: int, max_len: int, *,
                 backend: str = "auto", prefill_buckets: bool = True,
                 min_bucket: int = 16, obs=None):
        family = getattr(model.cfg, "family", "")
        if not hasattr(model, "init_paged_cache") or family == "hybrid":
            raise ValueError(
                f"family {family!r} cannot draft: the drafter needs a "
                f"paged KV cache (recurrent state has no trash-page "
                f"redirect for inactive rows)")
        self.model = model
        self.params = params
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.kv = model.init_paged_cache(batch_slots + 1, max_len)
        # static table: one max_len page per slot, never reallocated
        self.bt = (np.arange(batch_slots, dtype=np.int32) + 1)[:, None]
        self._bucketing = prefill_buckets and family == "dense"
        self.min_bucket = min_bucket
        from repro import obs as obs_mod
        self._decode = obs_mod.instrument_jit(
            lambda p, t, kv, bt, pos: model.decode_paged(
                p, t, kv, bt, pos, backend=backend),
            name="serve.draft.decode", obs=obs)
        self._prefill = obs_mod.instrument_jit(
            lambda p, b, kv, bt, wu, lp: model.prefill_paged(
                p, b, kv, bt, start_pos=jnp.int32(0), write_upto=wu,
                last_pos=lp, whole_prompt=True),
            name="serve.draft.prefill", obs=obs)

    def _bucket_len(self, s: int) -> int:
        if not self._bucketing:
            return s
        b = self.min_bucket
        while b < s:
            b *= 2
        return max(s, min(b, self.max_len))

    def begin(self, slot: int, req) -> None:
        """Prefill the prompt into the slot's page (the previous
        occupant's K/V is fully overwritten before any read — prefill
        writes every prompt position ahead of its reads)."""
        S = len(req.prompt)
        C = self._bucket_len(S)
        chunk = np.zeros((1, C), np.int32)
        chunk[0, :S] = req.prompt
        _, self.kv = self._prefill(
            self.params, {"tokens": jnp.asarray(chunk)}, self.kv,
            jnp.asarray(self.bt[slot:slot + 1]), jnp.int32(S),
            jnp.int32(S - 1))

    def propose(self, items: list, n: int) -> dict:
        if not items or n <= 0:
            return {}
        B = self.batch_slots
        tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        bt = np.zeros((B, 1), np.int32)
        slots = []
        for slot, _req, p, t in items:
            slots.append(slot)
            tok[slot, 0] = t
            pos[slot] = p
            bt[slot] = self.bt[slot]
        drafts: dict = {s: [] for s in slots}
        for step in range(n + 1):
            logits, self.kv = self._decode(
                self.params, jnp.asarray(tok), self.kv, jnp.asarray(bt),
                jnp.asarray(pos))
            nxt = np.argmax(np.asarray(logits[:, 0]), axis=-1)
            if step < n:
                for s in slots:
                    drafts[s].append(int(nxt[s]))
            tok = nxt.astype(np.int32)[:, None]
            pos = pos + 1
        return drafts


def make_draft_source(name: str, *, model=None, params=None,
                      batch_slots: int = 0, max_len: int = 0,
                      backend: str = "auto", max_ngram: int = 3,
                      prefill_buckets: bool = True,
                      min_bucket: int = 16, obs=None) -> DraftSource:
    """Engine-facing factory.  "ngram" needs no model; "model" drafts
    with (model, params) — the unmerged base under adapters, or a
    smaller arch."""
    if name == "ngram":
        return NgramDraft(max_ngram)
    if name == "model":
        if model is None or params is None:
            raise ValueError(
                "draft_source='model' needs a draft model and params "
                "(pass draft_model/draft_params to PagedEngine, or use "
                "draft_source='ngram')")
        return ModelDraft(model, params, batch_slots, max_len,
                          backend=backend, prefill_buckets=prefill_buckets,
                          min_bucket=min_bucket, obs=obs)
    raise ValueError(f"unknown draft source {name!r} "
                     f"(expected 'ngram' or 'model')")
