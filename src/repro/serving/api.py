"""The serving API surface (DESIGN.md §4/§5): the request/config types,
the per-request sampler, and the adapter store every engine shares.

`ServingConfig` is the ONE serving configuration — `make_engine()` in
`repro.serving` builds the unified paged engine from it for every model
family (dense, MoE, sliding-window, zamba hybrids, rwkv6).  The old
dense `Engine`/`EngineConfig` pair is gone from the public API; the
dense code path survives only as `repro.serving.oracle.DenseOracle`, a
test oracle the identity tests compare token streams against.

Sampling is PER-REQUEST (`request_rng(seed, uid)`): a request's token
stream depends only on its own prompt, adapter and uid — never on
scheduling order — so any engine produces identical streams for the
same request set at any temperature, and a preempted-and-restarted
request regenerates exactly the tokens it would have produced
uninterrupted.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 -> greedy
    adapter_id: Optional[str] = None   # None -> base weights
    out_tokens: Optional[list] = None
    error: Optional[str] = None   # set if the request failed (e.g. its
                                  # adapter was evicted before scheduling)
    rng: Optional[object] = None  # per-request sampler, (re)seeded at
                                  # admission — see request_rng


def request_rng(seed: int, uid: int) -> np.random.Generator:
    """The per-request sampling stream.  Seeded by (engine seed, uid) so
    token streams are scheduling-independent and preemption-safe."""
    return np.random.default_rng((seed, uid))


def sample_token(logits: np.ndarray, temperature: float,
                 rng: Optional[np.random.Generator]) -> int:
    """Greedy (temperature <= 0) or temperature sampling from a (V,)
    logits row — the one sampler every serving engine shares."""
    if temperature <= 0.0:
        return int(np.argmax(logits))
    p = np.exp((logits - logits.max()) / temperature)
    p = p / p.sum()
    return int(rng.choice(len(p), p=p))


@dataclasses.dataclass
class ServingConfig:
    """The unified serving configuration (`make_engine()` consumes it).

    Core knobs:
      * batch_slots / max_len / eos_id / seed — the continuous-batching
        envelope every family shares;
      * page_size / num_pages — the shared `KVPool`: KV pages for
        attention families (sliding-window configs use a ring of
        `ring_shape` pages per slot), "state"-class slab pages charging
        rwkv6 / mamba recurrent state;
      * exhaustion — decode-growth policy on pool exhaustion ("preempt"
        the youngest, or "stall" the grower);
      * chunked_prefill / prefill_chunk / prefill_buckets / min_bucket —
        prefill shaping (chunking and bucketing are dense-family-only);
      * prefix_cache — refcounted prompt-prefix page sharing;
      * backend — paged-attention read ("auto" | "kernel" | "lax");
      * speculate / draft_source — multi-token speculative decode
        (dense, non-windowed families only);
      * overlay_backend — merge-free adapter-overlay composition.
    """
    batch_slots: int = 4
    max_len: int = 256
    eos_id: int = 2
    seed: int = 0
    page_size: int = 16
    num_pages: int = 64
    chunked_prefill: bool = False
    prefill_chunk: int = 32
    prefill_buckets: bool = True  # power-of-two prompt padding
    min_bucket: int = 16
    prefix_cache: bool = False
    exhaustion: str = "preempt"
    backend: str = "auto"
    speculate: int = 0
    draft_source: str = "ngram"
    overlay_backend: str = "lax"


class AdapterStore:
    """LRU-bounded cache of merged (base + delta) parameter trees.

    `load` folds a `DeltaArtifact` into the base weights with the
    scatter-merge kernel (backend "kernel") or the dense reference
    ("ref") — ONE jitted program per adapter geometry, compiled once and
    reused across adapters (mergers are cached by geometry fingerprint).
    Validation is on by default: a delta refuses the wrong base hash,
    and — when the store is given the consumer's `plan_meta` — an
    incompatible selection-plan fingerprint (geometry / quota policy).
    """

    def __init__(self, base_params, *, capacity: int = 4,
                 backend: str = "kernel", mesh=None, validate: bool = True,
                 plan_meta: Optional[dict] = None):
        from repro.deltas.format import tree_hash
        self.base = base_params
        self.capacity = max(1, capacity)
        self.backend = backend
        self.mesh = mesh
        self.validate = validate
        self.plan_meta = plan_meta
        self.base_hash = tree_hash(base_params) if validate else None
        self._merged: collections.OrderedDict = collections.OrderedDict()
        self._mergers: dict = {}
        self.evictions = 0

    def load(self, adapter_id: str, delta) -> None:
        """Merge `delta` (a DeltaArtifact) and cache it under
        `adapter_id`; evicts the least-recently-used adapter beyond
        `capacity`.  Re-loading an id replaces it."""
        from repro.deltas.format import DeltaMismatchError
        from repro.deltas.merge import DeltaMerger
        if self.validate:
            want = delta.manifest["base_hash"]
            if want != self.base_hash:
                raise DeltaMismatchError(
                    f"adapter {adapter_id!r} was extracted against base "
                    f"{want[:12]}… but this store serves base "
                    f"{self.base_hash[:12]}…")
            if self.plan_meta is not None:
                delta.validate_plan(self.plan_meta)
        from repro.deltas.merge import geometry_key
        key = geometry_key(delta.manifest["tensors"], self.backend)
        merger = self._mergers.get(key)
        if merger is None:
            merger = self._mergers[key] = DeltaMerger(
                delta.manifest["tensors"], backend=self.backend,
                mesh=self.mesh)
        self._merged.pop(adapter_id, None)
        self._merged[adapter_id] = merger.merge(self.base, delta)
        while len(self._merged) > self.capacity:
            self._merged.popitem(last=False)
            self.evictions += 1

    def evict(self, adapter_id: str) -> None:
        self._merged.pop(adapter_id, None)

    def adapter_ids(self) -> list:
        return list(self._merged)

    def params_for(self, adapter_id: Optional[str]):
        """Merged weights for `adapter_id` (None -> base); marks the
        adapter most-recently-used.  Unknown ids raise KeyError — the
        scheduler checks at submit time."""
        if adapter_id is None:
            return self.base
        if adapter_id not in self._merged:
            raise KeyError(f"adapter {adapter_id!r} is not loaded "
                           f"(loaded: {list(self._merged)})")
        self._merged.move_to_end(adapter_id)
        return self._merged[adapter_id]


def _splice(cache_batched, cache_one, slot: int):
    """Insert batch=1 cache into slot `slot` of the batched cache."""
    def ins(big, small):
        return jax.lax.dynamic_update_slice_in_dim(big, small, slot, axis=1)
    return jax.tree.map(ins, cache_batched, cache_one)
