"""Continuous-batching scheduler over the paged KV pool (DESIGN.md §5).

Request lifecycle:

    QUEUED --admit--> PREFILL --final chunk--> DECODE --eos/budget--> DONE
       ^                 |                        |
       +----------- preempt (pages freed, restart from scratch) ------+

  * admission is PAGE-AWARE: a request is placed the moment a batch slot
    AND enough pages for its prompt exist — mid-flight, no batch drain;
    when pages are short the request WAITS at the queue head (admission
    never preempts running work for new work);
  * decode growth (a sequence crossing a page boundary) must make
    progress: on exhaustion the policy either preempts the youngest
    other sequence ("preempt") or stalls the growing sequence until
    pages free up ("stall"; if every live sequence stalls, the youngest
    is force-preempted to break the deadlock);
  * preemption releases the sequence's pages and requeues the request at
    the queue FRONT with its tokens cleared — per-request sampling
    (`serving.api.request_rng`) regenerates exactly the same stream
    on re-admission, so preemption is invisible in the output; recurrent
    families instead CHECKPOINT through the engine's `on_checkpoint`
    hook (state snapshot taken before the pages are released, emitted
    tokens kept) and resume mid-decode without re-running prefill;
  * prefix pages are reference-counted: with `prefix_cache` enabled,
    finished requests publish their full prompt pages keyed by the
    (adapter, token-prefix) chain, and admission reuses matching pages
    instead of recomputing their KV (the page is retained per consumer
    and reclaimed by LRU eviction only when no live request holds it).

Same-adapter batching follows the dense engine: one parameter tree per
decode dispatch, so while any slot is busy only requests matching the
batch's active adapter admit; an idle batch switches to the queue head.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.api import Request
from repro.serving.kvpool.pool import KVPool


@dataclasses.dataclass
class SeqState:
    """One admitted request's paged-serving state."""
    req: Request
    slot: int
    pages: list                  # physical pages, logical order (ring
                                 # order for sliding-window sequences)
    n_ctx: int                   # prompt length S
    prefill_pos: int             # next position to prefill (page-aligned
                                 # when a shared prefix was reused)
    phase: str                   # "prefill" | "decode" | "stalled"
    admit_order: int
    ring: Optional[int] = None   # ring length R for sliding-window
                                 # sequences (all R pages allocated at
                                 # placement; grow() is then a no-op)
    slab: list = dataclasses.field(default_factory=list)
    #                            # "state"-class pages charging this
    #                            # slot's recurrent state to the pool


class PagedScheduler:
    """Queue + slot + page bookkeeping; the engine owns the dispatches."""

    def __init__(self, pool: KVPool, batch_slots: int, *,
                 exhaustion: str = "preempt", prefix_cache: bool = False,
                 max_step_tokens: int = 1, mixed_adapters: bool = False):
        if exhaustion not in ("preempt", "stall"):
            raise ValueError(f"unknown exhaustion policy {exhaustion!r} "
                             f"(expected 'preempt' or 'stall')")
        if max_step_tokens < 1:
            raise ValueError(f"max_step_tokens must be >= 1, got "
                             f"{max_step_tokens}")
        self.pool = pool
        self.batch_slots = batch_slots
        self.exhaustion = exhaustion
        self.prefix_cache = prefix_cache
        # decode growth accounting: a sequence may advance up to this
        # many tokens per engine step (1 + draft_len under speculation);
        # grow() refuses a larger request instead of silently
        # under-allocating
        self.max_step_tokens = max_step_tokens
        # merge-free adapter-pool serving composes each slot's delta in
        # the forward pass, so a decode batch may mix adapters freely —
        # admission is plain FIFO instead of same-adapter filtered
        self.mixed_adapters = mixed_adapters
        self.queue: list[Request] = []
        self.seqs: list[Optional[SeqState]] = [None] * batch_slots
        self._order = 0
        self.preemptions = 0
        self.forced_preemptions = 0
        self.prefix_hits = 0
        self.stalls = 0
        # observability hook: the engine re-stamps a preempted request's
        # queue clock here (preemption restarts the wait; the admission
        # requeue_front path does NOT reset it — the request never
        # stopped waiting)
        self.on_preempt_requeue = None
        # checkpoint hook: called with the SeqState BEFORE its pages are
        # released on preemption; returns True when the engine
        # snapshotted enough state to resume mid-decode, in which case
        # the request keeps its emitted tokens instead of restarting
        self.on_checkpoint = None

    # ------------------------------------------------------------- queue
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.seqs)

    def busy(self) -> bool:
        return any(s is not None for s in self.seqs)

    def pop_next(self, active_adapter) -> Optional[Request]:
        """FIFO within the batch's active adapter; an idle batch may
        switch adapters (the engine activates on placement).  With
        `mixed_adapters` (adapter-pool serving) the filter drops away —
        plain FIFO regardless of what the busy slots serve."""
        if not self.queue:
            return None
        if self.mixed_adapters or not self.busy():
            return self.queue.pop(0)
        for i, r in enumerate(self.queue):
            if r.adapter_id == active_adapter:
                return self.queue.pop(i)
        return None

    def requeue_front(self, req: Request) -> None:
        self.queue.insert(0, req)

    # --------------------------------------------------------- placement
    def _chain(self, req: Request, j: int):
        """Prefix-page chain key: page j is reusable iff the (adapter,
        first (j+1)*page_size prompt tokens) match exactly."""
        ps = self.pool.page_size
        return (req.adapter_id, bytes(req.prompt[:(j + 1) * ps].tobytes()))

    def _reuse_cap(self, n_ctx: int) -> int:
        """Full prompt pages eligible for sharing.  Capped below the last
        prompt token so at least one token is always prefilled — the
        engine needs the last real token's logits."""
        return (n_ctx - 1) // self.pool.page_size

    def place(self, req: Request, slot: int, *,
              ring: Optional[int] = None, slab_pages: int = 0,
              n_pages: Optional[int] = None) -> Optional[SeqState]:
        """Allocate prompt pages (reusing cached prefix pages) and bind
        `req` to `slot`.  Returns None when pages are short — the caller
        requeues the request at the front and stops admitting (admission
        waits; it never preempts running sequences).

        `ring=R` places a sliding-window sequence: ALL R ring pages are
        allocated up front (the ring never grows — `pages[r]` is the
        physical page of ring index r) and prefix reuse is disabled
        (ring cells are overwritten in place, so their contents are not
        position-stable).  `slab_pages` additionally charges that many
        "state"-class pages for the slot's recurrent state arena.
        `n_pages` overrides the KV page count (checkpoint restore: the
        engine re-materializes exactly the pages it snapshotted)."""
        ps = self.pool.page_size
        S = len(req.prompt)
        if ring is not None:
            n_kv = ring
        elif n_pages is not None:
            n_kv = n_pages
        else:
            n_kv = -(-S // ps)
        reused: list = []
        if self.prefix_cache and ring is None and n_pages is None:
            for j in range(self._reuse_cap(S)):
                page = self.pool.cache_get(self._chain(req, j))
                if page is None:
                    break
                reused.append(page)
        got = self.pool.alloc(n_kv - len(reused))
        slab = self.pool.alloc(slab_pages, cls="state") \
            if got is not None else None
        if got is None or slab is None:
            for p in reused + (got or []):
                self.pool.release(p)
            return None
        self.prefix_hits += len(reused)
        seq = SeqState(req=req, slot=slot, pages=reused + got, n_ctx=S,
                       prefill_pos=len(reused) * ps, phase="prefill",
                       admit_order=self._order, ring=ring, slab=slab)
        self._order += 1
        self.seqs[slot] = seq
        return seq

    # ------------------------------------------------------ decode growth
    def grow(self, seq: SeqState, position: int, n_tokens: int = 1):
        """Ensure the pages holding [position, position + n_tokens)
        exist before the decode writes.  Returns (ok, preempted_slots):
        on exhaustion, policy "preempt" frees the youngest OTHER
        sequence's pages and retries; "stall" parks this sequence until
        pages free up (partial progress is kept — already-appended pages
        stay with the sequence, so a retry resumes where the allocation
        stopped).

        n_tokens > 1 is the speculative engine's MANDATORY growth (the
        current token plus drafts it has committed to verifying); it is
        bounded by `max_step_tokens` so page accounting can never be
        outrun by a growth storm the pool wasn't sized for.  Exhaustion
        policy is identical at every n_tokens — preempt-youngest /
        stall / forced-preempt deadlock break are unchanged."""
        if n_tokens > self.max_step_tokens:
            raise ValueError(
                f"grow({n_tokens} tokens) exceeds max_step_tokens="
                f"{self.max_step_tokens} — the engine must construct the "
                f"scheduler with max_step_tokens >= 1 + draft_len")
        if seq.ring is not None:
            # a sliding-window ring owns all R pages from placement and
            # overwrites cells in place — it never grows
            return True, []
        ps = self.pool.page_size
        last_lp = (position + n_tokens - 1) // ps
        preempted: list[int] = []
        while len(seq.pages) <= last_lp:
            got = self.pool.alloc(1)
            if got is not None:
                seq.pages.append(got[0])
                continue
            if self.exhaustion == "preempt":
                victim = self._youngest(exclude=seq.slot)
                if victim is not None:
                    self.preempt(victim.slot)
                    preempted.append(victim.slot)
                    continue
            seq.phase = "stalled"
            self.stalls += 1
            return False, preempted
        return True, preempted

    def try_extend(self, seq: SeqState, position: int,
                   n_tokens: int) -> int:
        """Best-effort growth for OPTIONAL tokens (speculative drafts):
        allocate pages toward covering [position, position + n_tokens)
        WITHOUT preempting or stalling — speculation must never evict
        someone else's real work for tokens that may be rejected.
        Returns how many of the n_tokens the sequence's pages now cover;
        the engine clamps its draft list to that."""
        ps = self.pool.page_size
        last_lp = (position + n_tokens - 1) // ps
        while len(seq.pages) <= last_lp:
            got = self.pool.alloc(1)
            if got is None:
                break
            seq.pages.append(got[0])
        return max(0, min(n_tokens, len(seq.pages) * ps - position))

    def _youngest(self, exclude: int) -> Optional[SeqState]:
        live = [s for s in self.seqs
                if s is not None and s.slot != exclude]
        return max(live, key=lambda s: s.admit_order, default=None)

    def break_deadlock(self) -> Optional[int]:
        """Every live sequence is stalled and nothing can free a page:
        force-preempt the youngest so the rest make progress.  Returns
        the freed slot (the engine clears its host state)."""
        stalled = [s for s in self.seqs
                   if s is not None and s.phase == "stalled"]
        if not stalled or any(s is not None and s.phase != "stalled"
                              for s in self.seqs):
            return None
        victim = max(stalled, key=lambda s: s.admit_order)
        self.preempt(victim.slot)
        self.forced_preemptions += 1
        return victim.slot

    # --------------------------------------------------------- retirement
    def preempt(self, slot: int) -> None:
        """Release the sequence's pages and restart it from the queue
        front.  The engine's `on_checkpoint` hook runs FIRST (pages and
        device state are still live to snapshot); when it reports a
        checkpoint the request keeps its emitted tokens and resumes
        mid-decode on re-admission, otherwise tokens are cleared and the
        per-request rng regenerates the identical stream from scratch."""
        seq = self.seqs[slot]
        assert seq is not None, slot
        checkpointed = (self.on_checkpoint is not None
                        and self.on_checkpoint(seq))
        for p in seq.pages + seq.slab:
            self.pool.release(p)
        if not checkpointed:
            seq.req.out_tokens = []
        self.requeue_front(seq.req)
        self.seqs[slot] = None
        self.preemptions += 1
        if self.on_preempt_requeue is not None:
            self.on_preempt_requeue(seq.req)

    def finish(self, slot: int, publish_prefix: bool = True) -> SeqState:
        """Retire a completed sequence: publish its full prompt pages to
        the prefix cache (when enabled), then drop its references."""
        seq = self.seqs[slot]
        assert seq is not None, slot
        if self.prefix_cache and publish_prefix and seq.ring is None:
            for j in range(self._reuse_cap(seq.n_ctx)):
                self.pool.cache_put(self._chain(seq.req, j), seq.pages[j])
        for p in seq.pages + seq.slab:
            self.pool.release(p)
        self.seqs[slot] = None
        return seq
