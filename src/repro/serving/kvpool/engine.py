"""The unified serving engine: continuous batching over ONE shared page
pool for EVERY model family (DESIGN.md §5).

Built from a `ServingConfig` through `repro.serving.make_engine`:

  * KV memory is a POOL of fixed-size pages shared by every batch slot
    (`nn.attention.PagedKVCache` + `kvpool.pool.KVPool`), not a dense
    (slots, max_len) cache: resident KV bytes track the LIVE tokens, not
    slots x worst-case prompt, and admission is page-aware — a request
    that cannot get pages waits or preempts by policy instead of OOMing;
  * prefill writes straight into the shared pages through the request's
    block table — no batch=1 cache materialization and no tree-wide
    splice into the batched cache;
  * long prompts can prefill in fixed-size chunks that INTERLEAVE with
    decode steps (`chunked_prefill`): one chunk of one prefilling
    sequence advances per engine step while the decoding slots keep
    producing tokens, and every chunk runs through ONE compiled program
    (fixed chunk shape) instead of one program per length bucket;
  * decode attention reads the pool through per-slot block tables — the
    Pallas paged-attention kernel on TPU, a gather + the dense oracle's
    exact grouped-einsum read elsewhere (`ops.paged_attention_decode`),
    which keeps paged decode bitwise-comparable to the dense cache.

Family routing — how each family's decode state lives in the pool:

  * dense / moe — linear block tables over KV pages;
  * sliding-window — a RING of `attention.ring_shape` pages per slot,
    allocated in full at placement and overwritten in place (virtual
    in-ring write positions, modular block-table walk at read);
  * hybrid (zamba) — shared-attention KV pages + the mamba recurrent
    state in a per-slot device arena CHARGED to the pool as
    "state"-class slab pages; preemption checkpoints state + pages so
    restart resumes mid-decode instead of re-running prefill;
  * rwkv6 — no KV at all: the full recurrent state lives in a per-slot
    arena charged as slab pages, prefill/decode run the exact dense
    programs (`serve.recurrent.*`), and preemption checkpoints the
    state slice.

Chunked prefill, length buckets and prefix caching remain
mask-safety-gated: only the dense non-windowed family uses them.

Token streams are identical to the dense reference
(`serving.oracle.DenseOracle`) per request — bitwise on the
monolithic-prefill path, greedy-identical under chunking — proven by
tests/test_paged_kv.py, tests/test_unified_serving.py and
benchmarks/paged_decode.py.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.serving.api import (AdapterStore, Request, ServingConfig,
                               _splice, request_rng, sample_token)
from repro.serving.kvpool.adapter_pool import AdapterPool, pool_overlay
from repro.serving.kvpool.pool import KVPool
from repro.serving.kvpool.scheduler import PagedScheduler, SeqState

_stat_view = obs_mod.stat_view


class PagedEngine:
    def __init__(self, model, params, cfg: ServingConfig,
                 adapters: Optional[AdapterStore] = None,
                 draft_model=None, draft_params=None,
                 adapter_pool: Optional[AdapterPool] = None,
                 obs: Optional[obs_mod.ObsContext] = None):
        mcfg = model.cfg
        family = getattr(mcfg, "family", "")
        window = getattr(mcfg, "sliding_window", None)
        if getattr(mcfg, "is_encoder", False):
            raise ValueError("encoder-only models have no decode serving")
        if window is not None and window >= cfg.max_len:
            raise ValueError(
                f"sliding_window={window} >= max_len={cfg.max_len}: the "
                f"window never slides inside this engine's envelope — "
                f"raise max_len or serve the config as full attention")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.adapters = adapters
        self.active_adapter: Optional[str] = None
        self._hybrid = family == "hybrid"
        self._recurrent = family == "rwkv6"
        self._window = window

        # merge-free adapter-pool serving (DESIGN.md §5): params stay the
        # BASE weights forever; each slot's sparse delta is composed into
        # the forward matmuls from the pool's (idx, val) pages
        self.apool = adapter_pool
        if adapter_pool is not None:
            if adapters is not None:
                raise ValueError(
                    "pass adapters (merge-on-load AdapterStore) OR "
                    "adapter_pool (merge-free), not both — the store "
                    "survives only as the reference path the pool mode "
                    "is token-identical to")
            if family != "dense":
                raise ValueError(
                    f"adapter-pool serving is dense-family only (family="
                    f"{family!r}): the per-slot overlay is threaded "
                    f"through the dense attention + MLP projections")
            if adapter_pool.layout is None:
                raise ValueError(
                    "the adapter pool has no layout yet — register at "
                    "least one adapter before constructing the engine "
                    "(the layout fixes the overlay geometry the compiled "
                    "dispatches bake in)")
            nl = mcfg.num_layers
            for path, (_, ns, _) in adapter_pool.layout.slices().items():
                parts = path.split("/")
                ok = (len(parts) == 3 and parts[0] == "blocks"
                      and ((parts[1] == "attn" and parts[2] in
                            ("wq", "wk", "wv", "wo"))
                           or (parts[1] == "mlp" and parts[2] in
                               ("up", "gate", "down")))
                      and ns == nl)
                if not ok:
                    raise ValueError(
                        f"adapter-pool serving cannot overlay planned "
                        f"tensor {path!r} (stack {ns}, model layers "
                        f"{nl}): only the per-layer block projections "
                        f"blocks/attn/{{wq,wk,wv,wo}} and "
                        f"blocks/mlp/{{up,gate,down}} are composable "
                        f"in-matmul — extract deltas with a plan that "
                        f"excludes embeddings/head (include_embed=False)")

        if (self._hybrid or self._recurrent) and cfg.exhaustion == "stall":
            raise ValueError(
                "exhaustion='stall' is unsupported for recurrent-state "
                "families (zamba mamba / rwkv6): a stalled slot's "
                "recurrent state would keep advancing on the dummy "
                "dispatch inputs (attention writes go to the trash page, "
                "recurrent state has no such redirect) — use "
                "exhaustion='preempt', which checkpoints the state and "
                "resumes mid-stream")
        self._spec_n = int(cfg.speculate)
        if self._spec_n < 0:
            raise ValueError(f"speculate must be >= 0, got {cfg.speculate}")
        if self._spec_n and (family != "dense" or window is not None):
            # recurrent state advances per input token and cannot rewind
            # a rejected draft; moe: capacity dispatch routes by the
            # dispatch's token count, so an N-token verify would change
            # real tokens' expert routing vs one-token decode; a sliding
            # window's ring pages are overwritten in place — a rejected
            # draft's stale writes may have already evicted real keys
            raise ValueError(
                f"speculative decode is dense-family only (family="
                f"{family!r}, sliding_window={window}): rejected drafts "
                f"need position-addressed state that can be overwritten "
                f"(linear paged KV), and routing must not depend on the "
                f"dispatch's token count")
        B, ps = cfg.batch_slots, cfg.page_size
        self.nmax = -(-cfg.max_len // ps)       # block-table width
        self._ring = None
        if window is not None:
            from repro.nn.attention import ring_shape
            self._ring = ring_shape(mcfg, ps)
            self.nmax = max(self.nmax, self._ring)
        # full (non-rolling) KV pages hold exactly max_len positions:
        # prompts beyond that fail fast at submit and decode budgets are
        # clamped; recurrent state and ring pages have no such limit
        # (mirrors DenseOracle._len_limited)
        self._len_limited = not self._recurrent and window is None

        # family state placement: KV page arrays (none at all for rwkv6 —
        # its whole decode state is the recurrent arena), the recurrent
        # state arenas, and the "state"-class slab page charge that makes
        # recurrent state visible to the pool's accounting
        self.kv = None
        self.state = None
        self._slab_pages = 0
        if self._recurrent:
            self.state = model.init_cache(B, cfg.max_len)
            sd = jax.tree.leaves(self.state)[0].dtype
            from repro.nn.rwkv6 import state_nbytes
            # no KV arrays exist to price a page from: charge slabs at
            # the NOMINAL kv-page byte size this config would have had
            nkv = getattr(mcfg, "num_kv_heads", None) \
                or getattr(mcfg, "num_heads", 1)
            self._page_bytes = (2 * ps * nkv * mcfg.head_dim
                                * jnp.dtype(sd).itemsize)
            self._slab_pages = max(
                1, -(-state_nbytes(mcfg, sd) // self._page_bytes))
        elif self._hybrid:
            self.kv = model.init_paged_cache(B, cfg.num_pages, ps)
            total = sum(leaf.nbytes
                        for leaf in jax.tree.leaves(self.kv.kv))
            self._page_bytes = total // cfg.num_pages
            sd = self.kv.mamba.conv_x.dtype
            from repro.nn.mamba2 import state_nbytes
            self._slab_pages = max(
                1, -(-state_nbytes(mcfg, sd) // self._page_bytes))
        else:
            self.kv = model.init_paged_cache(cfg.num_pages, ps)
            total = sum(leaf.nbytes for leaf in jax.tree.leaves(self.kv))
            self._page_bytes = total // cfg.num_pages

        # pool floor: one sequence's worst-case pages + the trash page
        if self._recurrent:
            need = self._slab_pages + 1
        elif self._ring is not None:
            need = self._ring + 1
        else:
            need = self.nmax + self._slab_pages + 1
        if cfg.num_pages < need:
            raise ValueError(
                f"num_pages={cfg.num_pages} cannot hold even one full "
                f"sequence: need >= {need} (worst-case KV pages + state "
                f"slab pages + the trash page)")
        pool = KVPool(cfg.num_pages, ps)
        # chunked prefill / prefix sharing are mask-safety-gated like the
        # dense oracle's buckets: recurrent state (rwkv6 / zamba mamba)
        # and MoE capacity dispatch are chunk/pad-sensitive, and a ring
        # page holds keys from several window generations — its contents
        # cannot be shared across prompts or revisited chunk-by-chunk
        plain_dense = family == "dense" and window is None
        self._chunked = cfg.chunked_prefill and plain_dense
        self._bucketing = cfg.prefill_buckets and plain_dense
        self.sched = PagedScheduler(
            pool, B, exhaustion=cfg.exhaustion,
            prefix_cache=cfg.prefix_cache and plain_dense,
            max_step_tokens=1 + self._spec_n,
            mixed_adapters=adapter_pool is not None)
        self.sched.on_checkpoint = self._on_checkpoint

        # telemetry (DESIGN.md §11): the registry is the one store for
        # the engine's counters — the legacy stat attributes are
        # registry-backed property views (see class tail).  Default is a
        # PRIVATE per-engine registry sharing the process tracer/auditor.
        self.obs = obs if obs is not None else obs_mod.engine_context()
        self._tr = self.obs.tracer
        self._obs_on = self.obs.enabled
        # hot-tile histograms resolved ONCE (a registry lookup per decode
        # step is measurable at interpret-mode step times), and the raw
        # clock pre-bound — tiles record bare perf_counter stamps; Span
        # objects and histogram buckets materialize at Tracer.drain()
        self._h_prefill = self.obs.registry.histogram("serve.prefill_s")
        self._h_decode = self.obs.registry.histogram("serve.decode_step_s")
        self._pc = time.perf_counter

        self.draft = None
        if self._spec_n:
            from repro.serving.draft import make_draft_source
            if cfg.draft_source == "model" and draft_model is None:
                # default model drafter: the engine's own arch on the
                # UNMERGED base weights — under DeltaHub adapters the
                # LIFT drafter (the fine-tune lives in ~5% principal
                # weights, so base/merged disagreements concentrate
                # exactly where the adapter matters); without adapters
                # it degenerates to self-drafting
                draft_model = model
                draft_params = (adapters.base if adapters is not None
                                else params)
            self.draft = make_draft_source(
                cfg.draft_source, model=draft_model,
                params=draft_params, batch_slots=B, max_len=cfg.max_len,
                backend=cfg.backend, prefill_buckets=cfg.prefill_buckets,
                min_bucket=cfg.min_bucket, obs=self.obs)

        self.bt = np.zeros((B, self.nmax), np.int32)
        if adapter_pool is not None:
            ppa = adapter_pool.layout.pages_per_adapter
            # per-slot adapter page table; all-zero row -> trash page ->
            # all-sentinel delta -> base weights
            self.apt = np.zeros((B, ppa), np.int32)
            self._apages: list = [[] for _ in range(B)]
        self.positions = np.zeros((B,), np.int32)
        self.tokens = np.zeros((B, 1), np.int32)
        self.budget = np.zeros((B,), np.int32)
        self.done: list[Request] = []
        self._pf_rr = 0                          # prefill round-robin
        self.prefill_compilations = 0
        self._seen_prefill: set = set()
        self.decode_compilations = 0
        self._seen_decode: set = set()
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.peak_live_tokens = 0
        self.checkpoints = 0                     # preempts that snapshotted
        self.restores = 0                        # checkpointed re-admissions
        self.spec_drafted = 0                    # drafts sent to verify
        self.spec_accepted = 0                   # drafts that matched
        self.spec_emitted = 0                    # tokens out of verify
        self.spec_slot_steps = 0                 # (sequence, dispatch) pairs
        self.sched.on_preempt_requeue = self._restamp_queue

        backend = cfg.backend
        jit = lambda fn, name: obs_mod.instrument_jit(fn, name=name,
                                                      obs=self.obs)
        if self._recurrent:
            # rwkv6 runs the EXACT dense programs over the state arena —
            # that's what makes its streams bitwise the dense oracle's
            self._prefill_rec = jit(
                lambda p, b, c, last: model.prefill(p, b, c,
                                                    last_pos=last),
                "serve.recurrent.prefill")
            self._decode_fn = jit(
                lambda p, t, c, pos: model.decode(p, t, c, pos),
                "serve.recurrent.decode")
        elif adapter_pool is not None:
            # overlay-threaded dispatches: the per-slot adapter overlay
            # is gathered from the pool pages INSIDE the jitted program
            # (static layout slices), so mixing adapters never retraces
            slices = adapter_pool.layout.slices()
            nl, ovb = mcfg.num_layers, cfg.overlay_backend
            ov_of = lambda ip, vp, apt: pool_overlay(ip, vp, apt, slices,
                                                     nl)
            self._decode_fn = jit(
                lambda p, t, kv, bt, pos, ip, vp, apt: model.decode_paged(
                    p, t, kv, bt, pos, backend=backend,
                    overlay=ov_of(ip, vp, apt), overlay_backend=ovb),
                "serve.paged.decode")
            if self._spec_n:
                self._verify_fn = jit(
                    lambda p, t, kv, bt, pos, ip, vp, apt:
                    model.decode_paged_multi(
                        p, t, kv, bt, pos, backend=backend,
                        overlay=ov_of(ip, vp, apt), overlay_backend=ovb),
                    "serve.paged.verify")
            self._prefill_whole = jit(
                lambda p, b, kv, bt, sp, wu, lp, ip, vp, apt:
                model.prefill_paged(
                    p, b, kv, bt, start_pos=sp, write_upto=wu,
                    last_pos=lp, whole_prompt=True,
                    overlay=ov_of(ip, vp, apt), overlay_backend=ovb),
                "serve.paged.prefill_whole")
            self._prefill_chunk_fn = jit(
                lambda p, b, kv, bt, sp, wu, lp, ip, vp, apt:
                model.prefill_paged(
                    p, b, kv, bt, start_pos=sp, write_upto=wu,
                    last_pos=lp, whole_prompt=False,
                    overlay=ov_of(ip, vp, apt), overlay_backend=ovb),
                "serve.paged.prefill_chunk")
        else:
            self._decode_fn = jit(
                lambda p, t, kv, bt, pos: model.decode_paged(
                    p, t, kv, bt, pos, backend=backend),
                "serve.paged.decode")
            if self._spec_n:
                self._verify_fn = jit(
                    lambda p, t, kv, bt, pos: model.decode_paged_multi(
                        p, t, kv, bt, pos, backend=backend),
                    "serve.paged.verify")
            self._prefill_whole = jit(
                lambda p, b, kv, bt, sp, wu, lp: model.prefill_paged(
                    p, b, kv, bt, start_pos=sp, write_upto=wu, last_pos=lp,
                    whole_prompt=True),
                "serve.paged.prefill_whole")
            self._prefill_chunk_fn = jit(
                lambda p, b, kv, bt, sp, wu, lp: model.prefill_paged(
                    p, b, kv, bt, start_pos=sp, write_upto=wu, last_pos=lp,
                    whole_prompt=False),
                "serve.paged.prefill_chunk")

    # ----------------------------------------------------------- client
    def submit(self, req: Request):
        if req.adapter_id is not None:
            if self.apool is not None:
                self.apool.check(req.adapter_id)  # fail fast if absent
            elif self.adapters is None:
                raise ValueError(
                    f"request {req.uid} names adapter {req.adapter_id!r} "
                    f"but the engine has no AdapterStore or adapter pool")
            else:
                self.adapters.params_for(req.adapter_id)  # fail fast
        req.out_tokens = []
        if self._obs_on:
            # submit time anchors the e2e envelope span; the queue clock
            # restarts on preemption (see _restamp_queue)
            req._obs_t_sub = req._obs_t_q = self._tr.now()
        if self._len_limited and len(req.prompt) + 1 > self.cfg.max_len:
            req.error = (f"prompt length {len(req.prompt)} exceeds "
                         f"max_len={self.cfg.max_len} - 1 — the sequence "
                         f"must hold the prompt plus at least one "
                         f"generated token")
            self.done.append(req)
            return
        self.sched.submit(req)

    def run(self, max_steps: int = 100_000) -> list[Request]:
        steps = 0
        while self.sched.has_work() and steps < max_steps:
            self.step()
            steps += 1
        if self._obs_on:
            self._tr.drain()        # materialize buffered step tiles
        return self.done

    # --------------------------------------------------------- scheduler
    def step(self):
        self._admit()
        self._prefill_step()
        self._unstall()
        if any(s is not None and s.phase == "decode"
               for s in self.sched.seqs):
            self._decode_step()
        elif all(s is None or s.phase == "stalled"
                 for s in self.sched.seqs):
            freed = self.sched.break_deadlock()
            if freed is not None:
                self._clear_slot(freed)

    def _activate(self, adapter_id: Optional[str]):
        if adapter_id == self.active_adapter:
            return
        self.params = (self.adapters.params_for(adapter_id)
                       if self.adapters is not None else self.params)
        self.active_adapter = adapter_id

    def _admit(self):
        # freed pages must reach STALLED sequences before new admissions:
        # admitting while anything is stalled re-steals the pages a
        # forced preemption just freed and livelocks the pool
        if any(s is not None and s.phase == "stalled"
               for s in self.sched.seqs):
            return
        while True:
            free = [i for i, s in enumerate(self.sched.seqs) if s is None]
            if not free:
                return
            req = self.sched.pop_next(self.active_adapter)
            if req is None:
                return
            apages = []
            if self.apool is not None:
                # merge-free: pin the adapter's delta pages for the
                # request's lifetime (prefetch-on-admission — cache hits
                # cost nothing); params stay the base weights
                t_acq = self._tr.now() if self._obs_on else 0.0
                apages = self.apool.acquire(req.adapter_id)
                if apages is None:      # adapter pool exhausted: wait
                    self.sched.requeue_front(req)
                    return
                if self._obs_on:
                    self._tr.add("pool.acquire", "pool", t_acq,
                                 self._tr.now(), uid=req.uid,
                                 uids=(req.uid,),
                                 adapter=req.adapter_id)
            else:
                try:
                    self._activate(req.adapter_id)
                except KeyError as e:   # LRU-evicted between submit/admit
                    req.error = str(e)
                    req.out_tokens = req.out_tokens or []
                    self.done.append(req)
                    continue
            rs = getattr(req, "_resume", None)
            pkw: dict = {}
            if self._recurrent:
                # no KV pages at all — only the state slab charge
                pkw = dict(n_pages=0, slab_pages=self._slab_pages)
            elif self._hybrid:
                pkw = dict(slab_pages=self._slab_pages)
                if rs is not None:
                    pkw["n_pages"] = rs["n_pages"]
            elif self._ring is not None:
                pkw = dict(ring=self._ring)
            seq = self.sched.place(req, free[0], **pkw)
            if seq is None:             # page-aware admission: wait
                if self.apool is not None:
                    self.apool.release(apages)
                self.sched.requeue_front(req)
                return
            if self.apool is not None:
                slot = seq.slot
                self._apages[slot] = apages
                self.apt[slot] = 0
                for j, p in enumerate(apages):
                    self.apt[slot, j] = p
            if self._obs_on:
                tq = getattr(req, "_obs_t_q", None)
                now = self._tr.now()
                if tq is not None:
                    self.obs.registry.histogram(
                        "serve.queue_wait_s").observe(now - tq)
                    self._tr.add("queue.wait", "queue", tq, now,
                                 uid=req.uid, uids=(req.uid,))
            if rs is not None:
                self._resume_checkpoint(seq, rs)
            else:
                self._start_prefill(seq)

    # ----------------------------------------------------------- prefill
    def _bucket_len(self, s: int) -> int:
        if not self._bucketing:
            return s
        b = self.cfg.min_bucket
        while b < s:
            b *= 2
        return max(s, min(b, self.cfg.max_len))

    def _start_prefill(self, seq: SeqState):
        slot = seq.slot
        self.bt[slot] = 0
        for j, p in enumerate(seq.pages):
            self.bt[slot, j] = p
        seq.req.rng = request_rng(self.cfg.seed, seq.req.uid)
        if self._recurrent:
            self._prefill_recurrent(seq)
        elif not self._chunked:
            # monolithic: one prefill dispatch for the (un-reused part of
            # the) prompt, then straight into the decode phase
            start = seq.prefill_pos
            rem = seq.n_ctx - start
            C = self._bucket_len(rem)
            whole = start == 0
            t0, co = self._tile_open(subjects=(seq.req.uid,))
            logits = self._run_prefill(seq, start, C, whole=whole)
            self._finish_prefill(seq, logits)
            self._tile_close("prefill", "prefill", t0, co,
                             uids=(seq.req.uid,),
                             hist=self._h_prefill, C=C)

    def _prefill_recurrent(self, seq: SeqState):
        """rwkv6 prefill: the EXACT dense-oracle path — exact-length
        prompt, batch-1 state, spliced into the slot's row of the state
        arena — so the unified engine's token streams stay bitwise the
        oracle's (rwkv ops are row-wise independent; other slots'
        arena rows are untouched by the splice)."""
        slot, S = seq.slot, seq.n_ctx
        prompt = np.zeros((1, S), np.int32)
        prompt[0] = seq.req.prompt
        if (S, True) not in self._seen_prefill:
            self._seen_prefill.add((S, True))
            self.prefill_compilations += 1
        t0, co = self._tile_open(subjects=(seq.req.uid,))
        one = self.model.init_cache(1, self.cfg.max_len)
        logits, one = self._prefill_rec(
            self.params, {"tokens": jnp.asarray(prompt)}, one,
            jnp.int32(S - 1))
        self.state = _splice(self.state, one, slot)
        self.prefill_chunks += 1
        self._note_live()
        self._finish_prefill(seq, logits)
        self._tile_close("prefill", "prefill", t0, co,
                         uids=(seq.req.uid,), hist=self._h_prefill, C=S)

    def _prefill_step(self):
        """Chunked prefill: advance ONE chunk of one prefilling sequence
        per engine step (round-robin), interleaving with decode."""
        if not self._chunked:
            return
        slots = [s.slot for s in self.sched.seqs
                 if s is not None and s.phase == "prefill"]
        if not slots:
            return
        slot = slots[self._pf_rr % len(slots)]
        self._pf_rr += 1
        seq = self.sched.seqs[slot]
        start = seq.prefill_pos
        C = self.cfg.prefill_chunk
        end = min(start + C, seq.n_ctx)
        t0, co = self._tile_open(subjects=(seq.req.uid,))
        logits = self._run_prefill(seq, start, C, whole=False)
        seq.prefill_pos = end
        if end == seq.n_ctx:
            self._finish_prefill(seq, logits)
        self._tile_close("prefill.chunk", "prefill", t0, co,
                         uids=(seq.req.uid,),
                         hist=self._h_prefill, start=start, end=end)

    def _run_prefill(self, seq: SeqState, start: int, C: int, *,
                     whole: bool):
        """One prefill dispatch of C tokens at positions
        [start, start + C) for `seq` (right-padded past the prompt; pad
        writes go to the trash page, pad logits are never read)."""
        slot, S = seq.slot, seq.n_ctx
        chunk = np.zeros((1, C), np.int32)
        real = min(S, start + C) - start
        chunk[0, :real] = seq.req.prompt[start:start + real]
        if (C, whole) not in self._seen_prefill:
            self._seen_prefill.add((C, whole))
            self.prefill_compilations += 1
        last = max(0, min(S - 1 - start, C - 1))
        fn = self._prefill_whole if whole else self._prefill_chunk_fn
        bt_row = jnp.asarray(self.bt[slot:slot + 1])
        batch = {"tokens": jnp.asarray(chunk)}
        if self._hybrid:
            from repro.models.zamba import ZambaCache
            if start == 0:
                mamba1 = self.model.init_mamba_state(1)
            else:                        # pragma: no cover - hybrid never
                raise AssertionError("hybrid prefill is monolithic")
            logits, c1 = fn(self.params, batch,
                            ZambaCache(mamba1, self.kv.kv), bt_row,
                            jnp.int32(start), jnp.int32(S),
                            jnp.int32(last))
            self.kv = ZambaCache(_splice(self.kv.mamba, c1.mamba, slot),
                                 c1.kv)
        elif self.apool is not None:
            logits, self.kv = fn(self.params, batch, self.kv, bt_row,
                                 jnp.int32(start), jnp.int32(S),
                                 jnp.int32(last), self.apool.idx_pages,
                                 self.apool.val_pages,
                                 jnp.asarray(self.apt[slot:slot + 1]))
        else:
            logits, self.kv = fn(self.params, batch, self.kv, bt_row,
                                 jnp.int32(start), jnp.int32(S),
                                 jnp.int32(last))
        self.prefill_chunks += 1
        self._note_live()
        return logits

    def _finish_prefill(self, seq: SeqState, logits):
        slot, req, S = seq.slot, seq.req, seq.n_ctx
        nxt = sample_token(np.asarray(logits[0, -1]), req.temperature,
                           req.rng)
        req.out_tokens.append(int(nxt))
        seq.phase = "decode"
        seq.prefill_pos = S
        self.tokens[slot, 0] = nxt
        self.positions[slot] = S
        # clamp like the dense oracle: full-cache decode writes must
        # stay in [0, max_len) — at most max_len - S tokens can be
        # generated (ring pages and recurrent state never fill up)
        budget = req.max_new_tokens
        if self._len_limited:
            budget = min(budget, self.cfg.max_len - S)
        self.budget[slot] = budget - 1
        if self.draft is not None:
            self.draft.begin(slot, req)

    # ------------------------------------------------------------ decode
    def _sync_bt(self, seq: SeqState):
        """Mirror the sequence's full page list into its block-table
        row (multi-token growth can append several pages per step)."""
        self.bt[seq.slot] = 0
        for j, p in enumerate(seq.pages):
            self.bt[seq.slot, j] = p

    def _unstall(self):
        for seq in list(self.sched.seqs):
            if seq is None or seq.phase != "stalled":
                continue
            # growth for an earlier sequence may have preempted this one
            # mid-loop: growing a dead snapshot would leak its page and
            # re-pollute the cleared block-table row
            if self.sched.seqs[seq.slot] is not seq:
                continue
            seq.phase = "decode"        # retry growth below
            ok, preempted = self.sched.grow(seq, int(self.positions[seq.slot]))
            for s in preempted:
                self._clear_slot(s)
            if ok:
                self._sync_bt(seq)

    def _grow_all(self):
        """Mandatory page growth for every decoding sequence BEFORE the
        dispatch — a sequence that cannot get its write page stalls or
        preempts by policy (identical for one-token and speculative
        steps: speculation only adds BEST-EFFORT growth on top)."""
        for seq in list(self.sched.seqs):
            if seq is None or seq.phase != "decode":
                continue
            if self.sched.seqs[seq.slot] is not seq:
                continue            # preempted by an earlier grow this loop
            ok, preempted = self.sched.grow(seq, int(self.positions[seq.slot]))
            for s in preempted:
                self._clear_slot(s)
            if ok:
                self._sync_bt(seq)
            elif self._hybrid:
                # recurrent state cannot survive a stall (it would keep
                # advancing on dummy dispatch inputs) — restart instead
                self.sched.preempt(seq.slot)
                self._clear_slot(seq.slot)

    def _decode_step(self):
        if self._recurrent:
            return self._decode_step_recurrent()
        if self._spec_n:
            return self._decode_step_spec()
        self._grow_all()
        live = [s.slot for s in self.sched.seqs
                if s is not None and s.phase == "decode"]
        if not live:
            return
        # inactive / prefilling / stalled slots dispatch with an all-zero
        # block table and position 0: their writes land in the trash page
        bt_d = np.zeros_like(self.bt)
        pos_d = np.zeros_like(self.positions)
        tok_d = np.zeros_like(self.tokens)
        for slot in live:
            bt_d[slot] = self.bt[slot]
            pos_d[slot] = self.positions[slot]
            tok_d[slot] = self.tokens[slot]
        self._note_decode_shape(1)
        uids = tuple(self.sched.seqs[s].req.uid for s in live)
        t0, co = self._tile_open(subjects=uids)
        if self.apool is not None:
            # inactive rows keep an all-zero adapter page table: the
            # trash page's all-sentinel delta composes to exactly the
            # base weights
            apt_d = np.zeros_like(self.apt)
            for slot in live:
                apt_d[slot] = self.apt[slot]
            logits, self.kv = self._decode_fn(
                self.params, jnp.asarray(tok_d), self.kv,
                jnp.asarray(bt_d), jnp.asarray(pos_d),
                self.apool.idx_pages, self.apool.val_pages,
                jnp.asarray(apt_d))
        else:
            logits, self.kv = self._decode_fn(
                self.params, jnp.asarray(tok_d), self.kv,
                jnp.asarray(bt_d), jnp.asarray(pos_d))
        logits = np.asarray(logits[:, 0])
        self.decode_steps += 1
        for slot in live:
            seq = self.sched.seqs[slot]
            req = seq.req
            self.positions[slot] += 1
            if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
                self._finish(slot)
                continue
            if self.budget[slot] <= 0:
                self._finish(slot)
                continue
            nxt = sample_token(logits[slot], req.temperature, req.rng)
            req.out_tokens.append(int(nxt))
            self.tokens[slot, 0] = nxt
            self.budget[slot] -= 1
        self._tile_close("decode", "decode", t0, co, uids=uids,
                         hist=self._h_decode, batch=len(live))
        self._note_live()

    def _decode_step_recurrent(self):
        """rwkv6 decode: the dense oracle's full-batch dispatch over the
        state arena — no pages to grow, no block tables.  Inactive slots
        integrate dummy tokens into their arena rows exactly like the
        oracle's finished slots do; rwkv ops are row-wise independent,
        so live rows are bitwise unaffected."""
        live = [s.slot for s in self.sched.seqs
                if s is not None and s.phase == "decode"]
        if not live:
            return
        self._note_decode_shape(1)
        uids = tuple(self.sched.seqs[s].req.uid for s in live)
        t0, co = self._tile_open(subjects=uids)
        logits, self.state = self._decode_fn(
            self.params, jnp.asarray(self.tokens), self.state,
            jnp.asarray(self.positions))
        logits = np.asarray(logits[:, 0])
        self.decode_steps += 1
        for slot in live:
            seq = self.sched.seqs[slot]
            req = seq.req
            self.positions[slot] += 1
            if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
                self._finish(slot)
                continue
            if self.budget[slot] <= 0:
                self._finish(slot)
                continue
            nxt = sample_token(logits[slot], req.temperature, req.rng)
            req.out_tokens.append(int(nxt))
            self.tokens[slot, 0] = nxt
            self.budget[slot] -= 1
        self._tile_close("decode", "decode", t0, co, uids=uids,
                         hist=self._h_decode, batch=len(live))
        self._note_live()

    # ---------------------------------------------- checkpointed preempt
    def _on_checkpoint(self, seq: SeqState) -> bool:
        """Scheduler preempt hook, called BEFORE the pages are released:
        recurrent-state families snapshot their decode state to host so
        re-admission RESUMES mid-stream instead of re-running prefill.
        Recurrent state is small and exact; attention-only families
        return False — their state IS the (about-to-be-released) pages,
        and the classic restart path regenerates the identical stream
        from the per-request rng."""
        if not (self._recurrent or self._hybrid) or seq.phase != "decode":
            return False
        slot = seq.slot
        if self._recurrent:
            snap = {"state": jax.tree.map(
                lambda a: np.asarray(a[:, slot:slot + 1]), self.state)}
        else:
            idx = np.asarray(seq.pages, np.int32)
            snap = {
                "mamba": jax.tree.map(
                    lambda a: np.asarray(a[:, slot:slot + 1]),
                    self.kv.mamba),
                "k_pages": np.asarray(self.kv.kv.k[:, idx]),
                "v_pages": np.asarray(self.kv.kv.v[:, idx]),
                "n_pages": len(seq.pages),
            }
        snap["positions"] = int(self.positions[slot])
        snap["token"] = int(self.tokens[slot, 0])
        snap["budget"] = int(self.budget[slot])
        seq.req._resume = snap
        self.checkpoints += 1
        return True

    def _resume_checkpoint(self, seq: SeqState, snap: dict):
        """Re-admission of a checkpointed preempt: restore the host
        snapshot into the slot and jump straight into the decode phase —
        no prefill re-run, no rng reseed (the sampling stream CONTINUES
        where the checkpoint left it).  Plain unjitted `.at[]` writes:
        restores are rare by construction."""
        slot, req = seq.slot, seq.req
        self.bt[slot] = 0
        for j, p in enumerate(seq.pages):
            self.bt[slot, j] = p
        if self._recurrent:
            self.state = _splice(
                self.state, jax.tree.map(jnp.asarray, snap["state"]),
                slot)
        else:
            from repro.models.zamba import ZambaCache
            kv = self.kv.kv
            k, v = kv.k, kv.v
            for j, p in enumerate(seq.pages):
                k = k.at[:, p].set(jnp.asarray(snap["k_pages"][:, j]))
                v = v.at[:, p].set(jnp.asarray(snap["v_pages"][:, j]))
            mamba = _splice(
                self.kv.mamba, jax.tree.map(jnp.asarray, snap["mamba"]),
                slot)
            self.kv = ZambaCache(mamba, type(kv)(k, v))
        seq.phase = "decode"
        seq.prefill_pos = seq.n_ctx
        self.positions[slot] = snap["positions"]
        self.tokens[slot, 0] = snap["token"]
        self.budget[slot] = snap["budget"]
        self.restores += 1
        del req._resume
        self._note_live()

    def _decode_step_spec(self):
        """Draft -> verify -> accept-prefix (DESIGN.md §5).

        One fixed-shape (B, 1 + N) verify dispatch scores the current
        token plus up to N drafted tokens per decoding sequence; the
        accept loop then REPLAYS the one-token decode bookkeeping
        sub-step by sub-step — advance position, check eos/budget,
        sample from this position's verify logits on the per-request rng
        — and stops consuming logits at the first sampled token that
        disagrees with its draft (later verify rows were conditioned on
        the rejected draft and are discarded; the sampled token itself
        is exactly what one-token decode would have emitted, so the
        stream is bitwise-identical for ANY draft quality, temperature
        and scheduling).  Rejected drafts leave stale K/V in the pages;
        it sits beyond the accepted position and is overwritten by the
        next dispatch's writes before any query mask can reach it."""
        N = self._spec_n
        self._grow_all()
        cands = [s for s in self.sched.seqs
                 if s is not None and s.phase == "decode"]
        if not cands:
            return
        cand_uids = tuple(s.req.uid for s in cands)
        t0, co = self._tile_open(subjects=cand_uids)
        # draft proposals (host-side / drafter-model; sloppy drafts only
        # cost speculation throughput, never correctness)
        proposals = self.draft.propose(
            [(s.slot, s.req, int(self.positions[s.slot]),
              int(self.tokens[s.slot, 0])) for s in cands], N)
        dmap: dict = {}
        for seq in cands:
            if self.sched.seqs[seq.slot] is not seq:
                continue                 # preempted after drafting
            slot = seq.slot
            p = int(self.positions[slot])
            # hard caps first: never draft past the sequence capacity or
            # the request budget (those tokens could not be emitted)
            cap = min(N, self.cfg.max_len - 1 - p,
                      max(0, int(self.budget[slot])))
            d = list(proposals.get(slot, []))[:max(0, cap)]
            if d:
                # best-effort page growth for the drafts — never
                # preempts or stalls; unfunded drafts are dropped
                fit = self.sched.try_extend(seq, p, 1 + len(d)) - 1
                d = d[:max(0, fit)]
                self._sync_bt(seq)
            dmap[slot] = d
        live = [slot for slot, _ in dmap.items()
                if self.sched.seqs[slot] is not None
                and self.sched.seqs[slot].phase == "decode"]
        self._tile_close("draft", "draft", t0, co, uids=cand_uids,
                         drafted=sum(len(d) for d in dmap.values()))
        if not live:
            return
        M = 1 + N
        bt_d = np.zeros_like(self.bt)
        pos_d = np.zeros_like(self.positions)
        tok_d = np.zeros((self.cfg.batch_slots, M), np.int32)
        for slot in live:
            bt_d[slot] = self.bt[slot]
            pos_d[slot] = self.positions[slot]
            tok_d[slot, 0] = self.tokens[slot, 0]
            d = dmap[slot]
            if d:
                tok_d[slot, 1:1 + len(d)] = d
        self._note_decode_shape(M)
        uids = tuple(self.sched.seqs[s].req.uid for s in live)
        t1, co1 = self._tile_open(subjects=uids)
        if self.apool is not None:
            apt_d = np.zeros_like(self.apt)
            for slot in live:
                apt_d[slot] = self.apt[slot]
            logits, self.kv = self._verify_fn(
                self.params, jnp.asarray(tok_d), self.kv,
                jnp.asarray(bt_d), jnp.asarray(pos_d),
                self.apool.idx_pages, self.apool.val_pages,
                jnp.asarray(apt_d))
        else:
            logits, self.kv = self._verify_fn(
                self.params, jnp.asarray(tok_d), self.kv,
                jnp.asarray(bt_d), jnp.asarray(pos_d))
        logits = np.asarray(logits)              # (B, M, V)
        self._tile_close("verify", "verify", t1, co1, uids=uids,
                         hist=self._h_decode, batch=len(live))
        self.decode_steps += 1
        self.spec_slot_steps += len(live)
        t2, co2 = self._tile_open(subjects=uids)
        # accumulate the spec counters locally — the registry-backed
        # properties take a lock per assignment, once per STEP is enough
        n_drafted = n_emitted = n_accepted = 0
        for slot in live:
            seq = self.sched.seqs[slot]
            req = seq.req
            d = dmap[slot]
            n_drafted += len(d)
            for i in range(len(d) + 1):
                # sub-step i == the one-token decode step at base+i
                self.positions[slot] += 1
                if req.out_tokens and \
                        req.out_tokens[-1] == self.cfg.eos_id:
                    self._finish(slot)
                    break
                if self.budget[slot] <= 0:
                    self._finish(slot)
                    break
                nxt = sample_token(logits[slot, i], req.temperature,
                                   req.rng)
                req.out_tokens.append(int(nxt))
                self.tokens[slot, 0] = nxt
                self.budget[slot] -= 1
                n_emitted += 1
                if i < len(d):
                    if int(nxt) != int(d[i]):
                        break            # rejection: rows > i discarded
                    n_accepted += 1
        self.spec_drafted += n_drafted
        self.spec_emitted += n_emitted
        self.spec_accepted += n_accepted
        self._tile_close("accept", "accept", t2, co2, uids=uids)
        self._note_live()

    def _finish(self, slot: int):
        seq = self.sched.finish(slot)
        req = seq.req
        if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
            req.out_tokens = req.out_tokens[:-1]
        self.done.append(req)
        self._clear_slot(slot)
        if self._obs_on:
            reg = self.obs.registry
            reg.counter("serve.requests_done").inc()
            reg.counter("serve.tokens_emitted").inc(len(req.out_tokens))
            t_sub = getattr(req, "_obs_t_sub", None)
            if t_sub is not None:
                now = self._tr.now()
                reg.histogram("serve.request_latency_s").observe(
                    now - t_sub)
                self._tr.add("request", "request", t_sub, now,
                             uid=req.uid, uids=(req.uid,),
                             tokens=len(req.out_tokens))

    def _clear_slot(self, slot: int):
        self.bt[slot] = 0
        self.positions[slot] = 0
        self.tokens[slot, 0] = 0
        self.budget[slot] = 0
        if self.apool is not None:
            # drop the in-flight references; the pages stay cached until
            # LRU pressure evicts them (a preempted request re-acquires
            # on re-admission — usually pure cache hits)
            self.apool.release(self._apages[slot])
            self._apages[slot] = []
            self.apt[slot] = 0

    # ----------------------------------------------------- observability
    def _restamp_queue(self, req: Request):
        """Scheduler preempt hook: the request is back in the queue —
        its wait clock restarts (its placed time is already covered by
        the step tiles it was subject/co-resident in)."""
        if self._obs_on:
            req._obs_t_q = self._tr.now()

    def _note_decode_shape(self, m: int):
        """ONE compile-count site for both decode paths: a decode/verify
        dispatch compiles once per token width m (1, or 1 + speculate)."""
        if m not in self._seen_decode:
            self._seen_decode.add(m)
            self.decode_compilations += 1

    def _tile_open(self, subjects: tuple):
        """Open one tile of the engine step loop: returns (t0, co_uids)
        where t0 is a RAW perf_counter stamp and co_uids are the OTHER
        placed requests — they sit in the batch while this tile runs, so
        its duration is their 'batch' time in the per-request
        decomposition (obs.tracing)."""
        if not self._obs_on:
            return 0.0, ()
        co = ()
        if self._tr.enabled:
            seqs = self.sched.seqs
            # fast path: every placed sequence is a subject (the usual
            # monolithic-prefill decode tile) -> no co-residents
            if len(seqs) - seqs.count(None) != len(subjects):
                subj = set(subjects)
                co = tuple(s.req.uid for s in seqs
                           if s is not None and s.req.uid not in subj)
        return self._pc(), co

    def _tile_close(self, name: str, cat: str, t0: float, co: tuple,
                    *, uids: tuple, hist=None, **attrs):
        """One buffered record — Span/histogram work happens at
        `Tracer.drain()`, not here (in engine context every extra call
        runs icache-cold and costs ~10x its tight-loop time).  `hist` is
        a resolved Histogram (self._h_*), not a name."""
        if not self._obs_on:
            return
        self._tr.tile(name, cat, t0, self._pc(), uids, co, hist,
                      attrs or None)

    # ------------------------------------------------------------- stats
    def _note_live(self):
        live = sum((int(self.positions[s.slot]) if s.phase == "decode"
                    else s.prefill_pos)
                   for s in self.sched.seqs if s is not None)
        self.peak_live_tokens = max(self.peak_live_tokens, live)

    def _mirror(self, prefix: str, d: dict) -> dict:
        """Publish a stats dict's scalars into the registry as gauges at
        the READ point (stats calls are never on the hot path), so one
        `render_snapshot` shows engine + scheduler + pool together."""
        reg = self.obs.registry
        for k, v in d.items():
            if isinstance(v, bool):
                reg.gauge(f"{prefix}.{k}").set(int(v))
            elif isinstance(v, (int, float)):
                reg.gauge(f"{prefix}.{k}").set(v)
        return d

    def metrics_snapshot(self) -> dict:
        """Registry snapshot with the scheduler/pool gauges refreshed
        and the buffered step tiles drained into their histograms —
        what launch/serve.py renders and dumps (--metrics-out)."""
        self._tr.drain()
        self.kv_stats()
        self.pool_stats()
        if self._spec_n:
            self.spec_stats()
        return self.obs.registry.snapshot()

    def kv_stats(self) -> dict:
        """KV-memory accounting for benchmarks/paged_decode.py: resident
        paged bytes at the peak vs the dense engine's slots x max_len
        allocation, plus the live-token bound the pool must respect.
        A thin view: engine-owned counts read from the registry (the
        property views), scheduler/pool counts are mirrored into it."""
        if self.kv is None:     # rwkv6: no KV arrays — nominal pricing
            page_bytes = float(self._page_bytes)
        else:
            pages_tree = self.kv.kv if self._hybrid else self.kv
            total = sum(leaf.nbytes
                        for leaf in jax.tree.leaves(pages_tree))
            page_bytes = total / self.cfg.num_pages
        per_token = page_bytes / self.cfg.page_size
        pool = self.sched.pool
        peak_kv = pool.peak_pages_in_use * page_bytes
        dense_kv = per_token * self.cfg.batch_slots * self.cfg.max_len
        # page-granularity slack: every live sequence may round up to one
        # partial page, plus whatever the prefix cache pins
        bound = (self.peak_live_tokens
                 + (self.cfg.batch_slots + pool.cached_pages())
                 * self.cfg.page_size) * per_token
        return self._mirror("kvpool", {
            "page_size": self.cfg.page_size,
            "num_pages": self.cfg.num_pages,
            "page_bytes": page_bytes,
            "peak_pages_in_use": pool.peak_pages_in_use,
            "peak_kv_bytes": peak_kv,
            "dense_kv_bytes": dense_kv,
            "kv_bytes_ratio": peak_kv / dense_kv,
            "peak_live_tokens": self.peak_live_tokens,
            "live_bound_bytes": bound,
            "within_live_bound": bool(peak_kv <= bound),
            "preemptions": self.sched.preemptions,
            "prefix_hits": self.sched.prefix_hits,
            "stalls": self.sched.stalls,
            "evictions": pool.evictions,
            "state_pages": self._slab_pages,
            "checkpoints": self.checkpoints,
            "restores": self.restores,
        })

    def pool_stats(self) -> dict:
        """Adapter-pool accounting (merge-free serving): residency,
        bytes per adapter vs one dense merged copy, upload/eviction
        counts.  Empty when the engine runs merge-on-load."""
        if self.apool is None:
            return {}
        return self._mirror("apool", self.apool.stats())

    def spec_stats(self) -> dict:
        """Speculative-decode accounting for the bench rows: acceptance
        and the effective tokens a sequence advances per verify dispatch
        it takes part in (> 1 is the whole point — each dispatch costs
        ~one decode pass per sequence; one-token decode is exactly 1)."""
        return self._mirror("spec", {
            "speculate": self._spec_n,
            "drafted": self.spec_drafted,
            "accepted": self.spec_accepted,
            "accept_rate": (self.spec_accepted / self.spec_drafted
                            if self.spec_drafted else 0.0),
            "emitted": self.spec_emitted,
            "effective_tokens_per_step":
                self.spec_emitted / max(1, self.spec_slot_steps),
            "decode_steps": self.decode_steps,
            "decode_compilations": self.decode_compilations,
        }) | {"draft_source":
              self.cfg.draft_source if self._spec_n else ""}

    # registry-backed attribute views: the counters live in
    # self.obs.registry; these keep every existing read/write site and
    # test working unchanged (DESIGN.md §11)
    prefill_compilations = _stat_view("serve.prefill_compilations")
    decode_compilations = _stat_view("serve.decode_compilations")
    decode_steps = _stat_view("serve.decode_steps")
    prefill_chunks = _stat_view("serve.prefill_chunks")
    peak_live_tokens = _stat_view("serve.peak_live_tokens")
    checkpoints = _stat_view("serve.checkpoints")
    restores = _stat_view("serve.restores")
    spec_drafted = _stat_view("serve.spec.drafted")
    spec_accepted = _stat_view("serve.spec.accepted")
    spec_emitted = _stat_view("serve.spec.emitted")
    spec_slot_steps = _stat_view("serve.spec.slot_steps")
