"""PagedKV subsystem (DESIGN.md §5): block-paged KV pool, page-aware
continuous-batching scheduler, and the paged serving engine."""
from repro.serving.kvpool.engine import PagedEngine, PagedEngineConfig  # noqa: F401
from repro.serving.kvpool.pool import KVPool, TRASH_PAGE  # noqa: F401
from repro.serving.kvpool.scheduler import PagedScheduler, SeqState  # noqa: F401
