"""PagedKV subsystem (DESIGN.md §5): block-paged KV pool, page-aware
continuous-batching scheduler, the unified serving engine (built via
`repro.serving.make_engine`), and the draft sources its speculative
multi-token decode verifies against."""
from repro.serving.draft import (DraftSource, ModelDraft,  # noqa: F401
                                 NgramDraft, make_draft_source)
from repro.serving.kvpool.adapter_pool import AdapterPool, pool_overlay  # noqa: F401
from repro.serving.kvpool.engine import PagedEngine  # noqa: F401
from repro.serving.kvpool.pool import KVPool, TRASH_PAGE  # noqa: F401
from repro.serving.kvpool.scheduler import PagedScheduler, SeqState  # noqa: F401
