"""Paged adapter pool: merge-free multi-tenant delta serving
(DESIGN.md §5).

The dense-engine `AdapterStore` keeps one MERGED copy of the base
weights per resident adapter — fine for a handful, hopeless for "a
million adapters".  This pool keeps ONE base weight set resident and
stores each adapter as its packed sparse delta (`deltas.PoolLayout`:
(idx, val) entry streams split into fixed-size pages), composed into the
forward matmuls per batch slot by `kernels.ops.overlay_matmul` — a
decode batch mixes adapters per slot with no weight materialization.

Allocator machinery is the KV pool's own (`kvpool.pool.KVPool`):

  * page 0 is the TRASH page — all-SENTINEL indices (the device arrays
    initialize that way and eviction never rewrites them), so base-only
    slots and inactive dispatch rows ride the same gather with a
    delta that drops out entirely;
  * every adapter page is published to the pool's LRU cache keyed by
    (adapter_id, page_index): an admitted request `acquire`s its
    adapter's pages (cache hit = no device write; miss = alloc +
    one-page upload, i.e. prefetch-on-admission), holds one reference
    per page while in flight, and `release`s at finish/preempt —
    referenced pages are NEVER evicted (the KVPool invariant), while
    idle adapters stay resident until page pressure LRU-evicts them.

Registration is host-side only: `register` validates the artifact
(base hash + selection plan, exactly like merge-on-load) and packs it
into page images; no device memory moves until a request needs the
adapter.  One pool serves ONE selection plan — the layout is fixed by
the first registered artifact and later registrations must match.
"""
from __future__ import annotations

import collections
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.deltas.format import DeltaMismatchError, tree_hash
from repro.deltas.pool_layout import SENTINEL_IDX, PoolLayout
from repro.serving.kvpool.pool import KVPool


def pool_overlay(idx_pages, val_pages, apt, slices: dict, num_layers: int):
    """Build the per-layer overlay pytree a decode dispatch consumes.

    idx_pages/val_pages: (P, E) device pool arrays; apt: (B, ppa) int32
    per-slot adapter page table (all-zero row -> trash page -> base);
    slices: `PoolLayout.slices()` ({path: (offset, ns, k)}, static).
    Returns {"attn": {name: {"idx", "val"}}, "mlp": {...}} with
    (num_layers, B, k) leaves — traceable under jit (static slicing
    only), shape-stable across steps.
    """
    B = apt.shape[0]
    fi = idx_pages[apt].reshape(B, -1)
    fv = val_pages[apt].reshape(B, -1)
    ov: dict = {}
    for path, (off, ns, k) in sorted(slices.items()):
        grp, nm = path.split("/")[-2:]
        assert ns == num_layers, (path, ns, num_layers)
        li = fi[:, off:off + ns * k].reshape(B, ns, k).transpose(1, 0, 2)
        lv = fv[:, off:off + ns * k].reshape(B, ns, k).transpose(1, 0, 2)
        ov.setdefault(grp, {})[nm] = {"idx": li, "val": lv}
    return ov


class AdapterPool:
    """Refcounted, LRU-evicted pool of page-resident sparse adapters."""

    def __init__(self, base_params, *, num_pages: int,
                 entries_per_page: int = 2048, validate: bool = True,
                 plan_meta: Optional[dict] = None):
        if num_pages < 2:
            raise ValueError(f"the adapter pool needs at least 2 pages "
                             f"(trash + 1 allocatable), got {num_pages}")
        self.base = base_params
        self.num_pages = int(num_pages)
        self.entries_per_page = int(entries_per_page)
        self.validate = validate
        self.plan_meta = plan_meta
        self.base_hash = tree_hash(base_params) if validate else None
        self.layout: Optional[PoolLayout] = None
        # page_size=1: the KV pool's page_size is KV-token granularity,
        # meaningless here — only the allocator (free list + refcounts +
        # LRU chain cache) is reused
        self.pool = KVPool(num_pages, 1)
        E = self.entries_per_page
        # all-sentinel idx everywhere: page 0 (trash) stays that way
        # forever, every other page is fully overwritten on upload
        self.idx_pages = jnp.full((num_pages, E), int(SENTINEL_IDX),
                                  jnp.int32)
        self.val_pages = jnp.zeros((num_pages, E), jnp.float32)
        self._packed: dict = {}          # adapter_id -> (idx, val) images
        self.uploads = 0                 # device page writes

    # ------------------------------------------------------- registration
    def register(self, adapter_id: str, delta) -> None:
        """Validate + host-pack `delta` (a DeltaArtifact) under
        `adapter_id`.  No device traffic; re-registering replaces."""
        if self.validate:
            want = delta.manifest["base_hash"]
            if want != self.base_hash:
                raise DeltaMismatchError(
                    f"adapter {adapter_id!r} was extracted against base "
                    f"{want[:12]}… but this pool serves base "
                    f"{self.base_hash[:12]}…")
            if self.plan_meta is not None:
                delta.validate_plan(self.plan_meta)
        if self.layout is None:
            self.layout = PoolLayout(delta.manifest["tensors"],
                                     entries_per_page=self.entries_per_page)
            need = self.layout.pages_per_adapter + 1
            if self.num_pages < need:
                raise ValueError(
                    f"num_pages={self.num_pages} cannot hold even one "
                    f"adapter: need >= {need} (pages_per_adapter="
                    f"{self.layout.pages_per_adapter} + the trash page)")
        self._packed[adapter_id] = self.layout.pack(self.base, delta)

    def check(self, adapter_id: str) -> None:
        if adapter_id not in self._packed:
            raise KeyError(f"adapter {adapter_id!r} is not registered "
                           f"(registered: {list(self._packed)})")

    def adapter_ids(self) -> list:
        return list(self._packed)

    # --------------------------------------------------- acquire / release
    def acquire(self, adapter_id: Optional[str]) -> Optional[list]:
        """Pin `adapter_id`'s pages for one in-flight request.

        Returns the physical page list (logical order — the request's
        adapter-page-table row), [] for the base model (adapter None),
        or None when even LRU eviction cannot free enough pages (the
        caller waits, exactly like KV-page admission).  Cached pages hit
        without device traffic; missing ones are uploaded here
        (prefetch-on-admission).  Every page gains one reference the
        caller MUST drop with `release` — while held, the pool will
        never evict or reuse it."""
        if adapter_id is None:
            return []
        self.check(adapter_id)
        idx_img, val_img = self._packed[adapter_id]
        pages: list = []
        for i in range(self.layout.pages_per_adapter):
            chain = (adapter_id, i)
            p = self.pool.cache_get(chain)      # +1 ref on hit
            if p is None:
                got = self.pool.alloc(1)        # evicts idle LRU pages
                if got is None:
                    for q in pages:
                        self.pool.release(q)
                    return None
                p = got[0]                      # ref = 1 (ours)
                self.idx_pages = self.idx_pages.at[p].set(idx_img[i])
                self.val_pages = self.val_pages.at[p].set(val_img[i])
                self.uploads += 1
                self.pool.cache_put(chain, p)   # cache's own ref
            pages.append(p)
        return pages

    def release(self, pages: list) -> None:
        """Drop one in-flight reference per page.  Pages stay resident
        under the cache's reference until LRU eviction reclaims them."""
        for p in pages:
            self.pool.release(p)

    # -------------------------------------------------------------- stats
    def resident_adapters(self) -> int:
        """Adapters whose every page is currently device-resident."""
        if self.layout is None:
            return 0
        counts = collections.Counter(
            c[0] for c in self.pool.cached_chains())
        return sum(1 for n in counts.values()
                   if n == self.layout.pages_per_adapter)

    def stats(self) -> dict:
        lay = self.layout
        a_bytes = lay.adapter_nbytes() if lay else 0
        d_bytes = lay.dense_nbytes() if lay else 0
        return {
            "num_pages": self.num_pages,
            "entries_per_page": self.entries_per_page,
            "pages_per_adapter": lay.pages_per_adapter if lay else 0,
            "registered_adapters": len(self._packed),
            "resident_adapters": self.resident_adapters(),
            "pages_in_use": self.pool.pages_in_use(),
            "adapter_nbytes": a_bytes,
            "dense_nbytes": d_bytes,
            "adapter_bytes_ratio": (a_bytes / d_bytes) if d_bytes else 0.0,
            "pool_device_bytes": int(self.idx_pages.nbytes
                                     + self.val_pages.nbytes),
            "uploads": self.uploads,
            "evictions": self.pool.evictions,
        }
