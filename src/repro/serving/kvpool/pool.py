"""Host-side page allocator for the block-paged KV pool (DESIGN.md §5).

The device half of the pool is `nn.attention.PagedKVCache` (the
(P, page_size, H_kv, D) page arrays the models read and write through
block tables); this module is the HOST half: a free-list allocator with
reference counts and an LRU of reusable prefix pages.

Invariants:
  * physical page 0 is the TRASH page — never allocated, never cached;
    inactive batch slots and masked-off padding write there and nothing
    reads it back;
  * a page is on the free list iff its refcount is 0;
  * prefix-cached pages carry the cache's own reference, so a cached
    page that no live request uses has refcount exactly 1 and is the
    only kind of page eviction may reclaim — pages referenced by live
    requests are never handed out twice;
  * every allocated page belongs to exactly ONE page class ("kv" for
    block-table KV pages, "state" for recurrent state slabs) from
    `alloc()` until its refcount returns to 0 — classes share the free
    list but a live page never serves both, and only "kv" pages may
    enter the prefix cache.

All bookkeeping is O(1) per page operation; the allocator never touches
device memory (the engine owns the arrays; physical page ids are just
indices into them).
"""
from __future__ import annotations

import collections
from typing import Optional

TRASH_PAGE = 0


class KVPool:
    """Free-list page allocator with refcounts and an LRU prefix cache."""

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"the pool needs at least 2 pages (trash + 1 "
                             f"allocatable), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.free: collections.deque = collections.deque(
            range(1, num_pages))
        self.refs = [0] * num_pages
        # chain key (bytes fingerprint of the page's token prefix) ->
        # physical page; insertion order == LRU order
        self._cached: collections.OrderedDict = collections.OrderedDict()
        self._chain_of: dict = {}       # page -> chain key
        self.cls_of: list = [None] * num_pages   # page -> class while live
        self._in_use = {"kv": 0, "state": 0}
        self.evictions = 0
        self.peak_pages_in_use = 0

    # ------------------------------------------------------------- sizes
    def pages_in_use(self, cls: Optional[str] = None) -> int:
        """Allocated pages (live requests + prefix cache), excluding the
        trash page; `cls` restricts the count to one page class."""
        if cls is not None:
            return self._in_use[cls]
        return self.num_pages - 1 - len(self.free)

    def _note_usage(self):
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use())

    # -------------------------------------------------------- alloc/free
    def alloc(self, n: int, cls: str = "kv") -> Optional[list]:
        """n fresh pages of class `cls` with refcount 1, or None if even
        evicting every unreferenced cached page cannot satisfy the
        request (the caller waits or preempts — the pool never
        over-commits)."""
        if cls not in self._in_use:
            raise ValueError(f"unknown page class {cls!r}")
        while len(self.free) < n and self._evict_one():
            pass
        if len(self.free) < n:
            return None
        out = [self.free.popleft() for _ in range(n)]
        for p in out:
            self.refs[p] = 1
            self.cls_of[p] = cls
        self._in_use[cls] += n
        self._note_usage()
        return out

    def retain(self, page: int) -> None:
        assert page != TRASH_PAGE and self.refs[page] > 0, page
        self.refs[page] += 1

    def release(self, page: int) -> None:
        assert page != TRASH_PAGE and self.refs[page] > 0, page
        self.refs[page] -= 1
        if self.refs[page] == 0:
            # cached pages always hold the cache's reference, so hitting
            # zero means the page is fully unreferenced
            self._in_use[self.cls_of[page]] -= 1
            self.cls_of[page] = None
            self.free.append(page)

    def _evict_one(self) -> bool:
        for chain, page in self._cached.items():   # oldest first
            if self.refs[page] == 1:               # cache is the only ref
                del self._cached[chain]
                del self._chain_of[page]
                self.refs[page] = 0
                self._in_use[self.cls_of[page]] -= 1
                self.cls_of[page] = None
                self.free.append(page)
                self.evictions += 1
                return True
        return False

    # ------------------------------------------------------ prefix cache
    def cache_get(self, chain) -> Optional[int]:
        """Look up a prefix page by its chain key; retains it for the
        caller and marks it most-recently-used."""
        page = self._cached.get(chain)
        if page is None:
            return None
        self._cached.move_to_end(chain)
        self.refs[page] += 1
        return page

    def cache_put(self, chain, page: int) -> bool:
        """Publish `page` under `chain` (cache takes its own reference).
        No-op when the chain is already cached (first writer wins)."""
        if chain in self._cached or page in self._chain_of:
            return False
        assert page != TRASH_PAGE and self.refs[page] > 0, page
        assert self.cls_of[page] == "kv", \
            f"only kv pages enter the prefix cache, page {page} is " \
            f"{self.cls_of[page]!r}"
        self._cached[chain] = page
        self._chain_of[page] = chain
        self.refs[page] += 1
        self._note_usage()
        return True

    def cached_pages(self) -> int:
        return len(self._cached)

    def cached_chains(self) -> list:
        """Chain keys currently published, LRU order (oldest first)."""
        return list(self._cached)
