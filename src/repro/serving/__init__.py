"""Public serving API (DESIGN.md §4/§5): ONE config, ONE engine factory.

    from repro.serving import ServingConfig, make_engine

    engine = make_engine(model, params, ServingConfig(num_pages=64))
    engine.submit(Request(uid=0, prompt=tokens))
    done = engine.run()

`make_engine` builds the unified paged engine for EVERY model family —
dense, MoE, sliding-window (ring pages), zamba hybrids (KV pages +
mamba state slabs), rwkv6 (state slabs only) — all sharing the same
`KVPool`, scheduler and per-request sampling.  The legacy dense engine
is not part of this surface; it survives as the non-exported test
oracle `repro.serving.oracle.DenseOracle`.
"""
from __future__ import annotations

from typing import Optional

from repro.serving.api import (AdapterStore, Request, ServingConfig,
                               request_rng, sample_token)

__all__ = ["AdapterStore", "Request", "ServingConfig", "make_engine",
           "request_rng", "sample_token"]


def make_engine(model, params, cfg: ServingConfig, *,
                adapters: Optional[AdapterStore] = None,
                adapter_pool=None, draft_model=None, draft_params=None,
                obs=None):
    """Build the serving engine for `model` from a `ServingConfig`.

    Every family routes to the unified paged engine; family-specific
    state placement (KV pages, ring pages, state slabs) is the engine's
    concern, not the caller's.  `adapters` is a merged-weights
    `AdapterStore` (one adapter per decode batch); `adapter_pool` is the
    merge-free paged `AdapterPool` (mixed adapters per batch; mutually
    exclusive with `adapters`); `draft_model`/`draft_params` feed
    speculative decode when `cfg.speculate > 0`.
    """
    from repro.serving.kvpool.engine import PagedEngine
    return PagedEngine(model, params, cfg, adapters=adapters,
                       adapter_pool=adapter_pool, draft_model=draft_model,
                       draft_params=draft_params, obs=obs)
