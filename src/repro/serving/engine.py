"""Continuous-batching serving engine.

vLLM-style slot scheduler on top of the model's prefill/decode steps:
  * fixed B decode slots; the decode step always runs the full batch
    (inactive slots are masked),
  * new requests prefill with batch=1 and are spliced into a free slot of
    the batched cache (tree-wide dynamic_update_slice on the batch axis),
  * finished sequences (EOS / max_new_tokens) free their slot immediately.

Greedy or temperature sampling; deterministic under a seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 -> greedy
    out_tokens: Optional[list] = None


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4
    max_len: int = 256
    eos_id: int = 2
    seed: int = 0


def _cache_batch_size(cache) -> int:
    leaf = jax.tree.leaves(cache)[0]
    return leaf.shape[1]  # (L, B, ...)


def _splice(cache_batched, cache_one, slot: int):
    """Insert batch=1 cache into slot `slot` of the batched cache."""
    def ins(big, small):
        return jax.lax.dynamic_update_slice_in_dim(big, small, slot, axis=1)
    return jax.tree.map(ins, cache_batched, cache_one)


class Engine:
    def __init__(self, model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_cache(cfg.batch_slots, cfg.max_len)
        self.positions = np.zeros((cfg.batch_slots,), np.int32)
        self.active: list[Optional[Request]] = [None] * cfg.batch_slots
        self.tokens = np.zeros((cfg.batch_slots, 1), np.int32)
        self.budget = np.zeros((cfg.batch_slots,), np.int32)
        self.rng = np.random.default_rng(cfg.seed)
        self.queue: list[Request] = []
        self.done: list[Request] = []

        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode(p, t, c, pos))

    # ----------------------------------------------------------- client
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done

    # --------------------------------------------------------- scheduler
    def step(self):
        self._admit()
        if any(a is not None for a in self.active):
            self._decode_step()

    def _admit(self):
        for slot in range(self.cfg.batch_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
            one_cache = self.model.init_cache(1, self.cfg.max_len)
            logits, one_cache = self._prefill(
                self.params, {"tokens": prompt}, one_cache)
            self.cache = _splice(self.cache, one_cache, slot)
            nxt = self._sample(np.asarray(logits[0, -1]), req.temperature)
            req.out_tokens.append(int(nxt))
            self.active[slot] = req
            self.tokens[slot, 0] = nxt
            self.positions[slot] = len(req.prompt)
            self.budget[slot] = req.max_new_tokens - 1

    def _decode_step(self):
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.positions))
        logits = np.asarray(logits[:, 0])
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.positions[slot] += 1
            if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
                self._finish(slot)
                continue
            if self.budget[slot] <= 0:
                self._finish(slot)
                continue
            nxt = self._sample(logits[slot], req.temperature)
            req.out_tokens.append(int(nxt))
            self.tokens[slot, 0] = nxt
            self.budget[slot] -= 1

    def _finish(self, slot: int):
        req = self.active[slot]
        if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
            req.out_tokens = req.out_tokens[:-1]
        self.done.append(req)
        self.active[slot] = None

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        if temperature <= 0.0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / temperature)
        p = p / p.sum()
        return int(self.rng.choice(len(p), p=p))
