"""Dense-cache reference engine — a TEST ORACLE, not a public API.

This is the pre-unification continuous-batching engine over a dense
per-slot KV cache (vLLM-style slot scheduler, batch-1 prefill spliced
into the batched cache, same-adapter batching, per-request sampling).
Production serving is the unified paged engine behind
`repro.serving.make_engine`; this module survives ONLY so the identity
tests can prove the paged engine's token streams bitwise-equal to the
dense reference for every family.  It is deliberately NOT exported from
`repro.serving`.

Prefill compiles once per power-of-two length *bucket*, not once per
prompt length: prompts are right-padded (mask-aware — causal attention
keeps real positions blind to pads, `LM.prefill(last_pos=...)` gathers
the real last-token logits, and decode never attends an un-overwritten
pad slot because its key_pos exceeds every query position).  Families
where padding changes real-token math opt out and keep the
exact-length path: recurrent state (rwkv6 / zamba hybrids), rolling
sliding-window caches, and MoE capacity-limited dispatch (pads consume
expert capacity slots).
"""
from __future__ import annotations

import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs as obs_mod
from repro.serving.api import (AdapterStore, Request, ServingConfig,
                               _splice, request_rng, sample_token)

__all__ = ["DenseOracle"]


class DenseOracle:
    def __init__(self, model, params, cfg: ServingConfig,
                 adapters: Optional[AdapterStore] = None,
                 obs: Optional[obs_mod.ObsContext] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.adapters = adapters
        self.active_adapter: Optional[str] = None
        self.cache = model.init_cache(cfg.batch_slots, cfg.max_len)
        self.positions = np.zeros((cfg.batch_slots,), np.int32)
        self.active: list[Optional[Request]] = [None] * cfg.batch_slots
        self.tokens = np.zeros((cfg.batch_slots, 1), np.int32)
        self.budget = np.zeros((cfg.batch_slots,), np.int32)
        self.queue: list[Request] = []
        self.done: list[Request] = []

        # full (non-rolling) KV caches hold exactly max_len positions:
        # prompts beyond that fail fast at submit and decode budgets are
        # clamped so writes never wrap (recurrent state and SWA rolling
        # buffers have no such limit)
        mcfg_ = model.cfg
        self._len_limited = (getattr(mcfg_, "family", "") != "rwkv6"
                             and getattr(mcfg_, "sliding_window", None)
                             is None)

        # bucketing is only mask-safe for the dense KV family: recurrent
        # state (rwkv6 / zamba mamba blocks) integrates pad tokens, a
        # rolling sliding-window cache would evict real tokens in favor
        # of pads, and MoE capacity-limited dispatch routes/drops by the
        # PADDED token count (pads consume expert capacity slots)
        mcfg = model.cfg
        self._bucketing = (cfg.prefill_buckets
                          and getattr(mcfg, "family", "") == "dense"
                          and getattr(mcfg, "sliding_window", None) is None)

        # telemetry (DESIGN.md §11): engine counters live in the
        # context's registry (`prefill_compilations`/`decode_steps` are
        # property views over it); jit entry points are auditor-wrapped
        self.obs = obs if obs is not None else obs_mod.engine_context()
        self._tr = self.obs.tracer
        self._obs_on = self.obs.enabled
        # hot-tile histograms resolved ONCE (a registry lookup per decode
        # step is measurable at interpret-mode step times); tiles record
        # raw perf_counter stamps, materialized at Tracer.drain()
        self._h_prefill = self.obs.registry.histogram("serve.prefill_s")
        self._h_decode = self.obs.registry.histogram("serve.decode_step_s")
        self._pc = time.perf_counter
        self.prefill_compilations = 0
        self.decode_steps = 0
        self._seen_buckets: set = set()

        self._prefill = obs_mod.instrument_jit(
            lambda p, b, c, last: model.prefill(p, b, c, last_pos=last),
            name="serve.dense.prefill", obs=self.obs)
        self._decode = obs_mod.instrument_jit(
            lambda p, t, c, pos: model.decode(p, t, c, pos),
            name="serve.dense.decode", obs=self.obs)

    # ----------------------------------------------------------- client
    def submit(self, req: Request):
        if req.adapter_id is not None:
            if self.adapters is None:
                raise ValueError(
                    f"request {req.uid} names adapter {req.adapter_id!r} "
                    f"but the engine has no AdapterStore")
            self.adapters.params_for(req.adapter_id)  # fail fast if absent
        req.out_tokens = []
        if self._obs_on:
            # submit time anchors the e2e envelope span and queue wait
            req._obs_t_sub = req._obs_t_q = self._tr.now()
        if self._len_limited and len(req.prompt) + 1 > self.cfg.max_len:
            # fail fast: a clamped prefill + wrapping decode writes would
            # silently corrupt the cache (the pre-fix behavior)
            req.error = (f"prompt length {len(req.prompt)} exceeds "
                         f"max_len={self.cfg.max_len} - 1 — the cache "
                         f"must hold the prompt plus at least one "
                         f"generated token")
            self.done.append(req)
            return
        self.queue.append(req)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        if self._obs_on:
            self._tr.drain()        # materialize buffered step tiles
        return self.done

    # --------------------------------------------------------- scheduler
    def step(self):
        self._admit()
        if any(a is not None for a in self.active):
            self._decode_step()

    def _bucket_len(self, s: int) -> int:
        """Power-of-two padded prefill length (>= s, <= max_len when s
        allows); identity when bucketing is off."""
        if not self._bucketing:
            return s
        b = self.cfg.min_bucket
        while b < s:
            b *= 2
        return max(s, min(b, self.cfg.max_len))

    def _next_request(self) -> Optional[Request]:
        """Same-adapter slot batching: while any slot is busy only
        requests matching the batch's active adapter are admitted (FIFO
        within the adapter); an idle batch switches the active adapter to
        the head of the queue.

        The submit-time adapter check is a fast-fail, not a reservation:
        the store's LRU may have evicted the adapter by the time the
        request is scheduled.  That fails ONLY the affected request
        (`req.error`, finished with no tokens) — never the whole run.
        Requests matching the batch's CURRENT adapter are immune: the
        engine holds the merged tree in `self.params` regardless of the
        store's cache."""
        while self.queue:
            if not any(a is not None for a in self.active):
                req = self.queue.pop(0)
                try:
                    self._activate(req.adapter_id)
                except KeyError as e:
                    req.error = str(e)
                    req.out_tokens = req.out_tokens or []
                    self.done.append(req)
                    continue
                return req
            for i, r in enumerate(self.queue):
                if r.adapter_id == self.active_adapter:
                    return self.queue.pop(i)
            return None
        return None

    def _activate(self, adapter_id: Optional[str]):
        if adapter_id == self.active_adapter:
            return
        self.params = (self.adapters.params_for(adapter_id)
                       if self.adapters is not None else self.params)
        self.active_adapter = adapter_id

    def _admit(self):
        for slot in range(self.cfg.batch_slots):
            if self.active[slot] is not None:
                continue
            req = self._next_request()
            if req is None:
                break
            t0, co = self._tile_open(subjects=(req.uid,))
            if self._obs_on:
                # queue spans use the tracer's epoch-relative clock
                # (t0 is a raw perf_counter stamp for the tile record)
                tq = getattr(req, "_obs_t_q", None)
                if tq is not None:
                    now = self._tr.now()
                    self.obs.registry.histogram(
                        "serve.queue_wait_s").observe(now - tq)
                    self._tr.add("queue.wait", "queue", tq, now,
                                 uid=req.uid, uids=(req.uid,))
            s = len(req.prompt)
            padded = self._bucket_len(s)
            prompt = np.zeros((1, padded), np.int32)
            prompt[0, :s] = req.prompt
            if padded not in self._seen_buckets:
                self._seen_buckets.add(padded)
                self.prefill_compilations += 1
            one_cache = self.model.init_cache(1, self.cfg.max_len)
            logits, one_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(prompt)}, one_cache,
                jnp.int32(s - 1))
            self.cache = _splice(self.cache, one_cache, slot)
            req.rng = request_rng(self.cfg.seed, req.uid)
            nxt = sample_token(np.asarray(logits[0, -1]), req.temperature,
                               req.rng)
            req.out_tokens.append(int(nxt))
            self._tile_close("prefill", "prefill", t0, co,
                             uids=(req.uid,), hist=self._h_prefill,
                             padded=padded)
            self.active[slot] = req
            self.tokens[slot, 0] = nxt
            self.positions[slot] = s
            # clamp so decode writes never wrap past the cache: at most
            # max_len - s tokens can be generated for a full cache
            budget = req.max_new_tokens
            if self._len_limited:
                budget = min(budget, self.cfg.max_len - s)
            self.budget[slot] = budget - 1

    def _decode_step(self):
        uids = tuple(r.uid for r in self.active if r is not None)
        t0, co = self._tile_open(subjects=uids)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache,
            jnp.asarray(self.positions))
        logits = np.asarray(logits[:, 0])
        self.decode_steps += 1
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.positions[slot] += 1
            if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
                self._finish(slot)
                continue
            if self.budget[slot] <= 0:
                self._finish(slot)
                continue
            nxt = sample_token(logits[slot], req.temperature, req.rng)
            req.out_tokens.append(int(nxt))
            self.tokens[slot, 0] = nxt
            self.budget[slot] -= 1
        self._tile_close("decode", "decode", t0, co, uids=uids,
                         hist=self._h_decode, batch=len(uids))

    def _finish(self, slot: int):
        req = self.active[slot]
        if req.out_tokens and req.out_tokens[-1] == self.cfg.eos_id:
            req.out_tokens = req.out_tokens[:-1]
        self.done.append(req)
        self.active[slot] = None
        if self._obs_on:
            reg = self.obs.registry
            reg.counter("serve.requests_done").inc()
            reg.counter("serve.tokens_emitted").inc(len(req.out_tokens))
            t_sub = getattr(req, "_obs_t_sub", None)
            if t_sub is not None:
                now = self._tr.now()
                reg.histogram("serve.request_latency_s").observe(
                    now - t_sub)
                self._tr.add("request", "request", t_sub, now,
                             uid=req.uid, uids=(req.uid,),
                             tokens=len(req.out_tokens))

    # ----------------------------------------------------- observability
    def _tile_open(self, subjects: tuple):
        """Open one tile of the engine step loop (see the PagedEngine
        twin): co_uids are the OTHER active requests — they sit in the
        batch while this tile runs."""
        if not self._obs_on:
            return 0.0, ()
        co = ()
        if self._tr.enabled:
            subj = set(subjects)
            co = tuple(r.uid for r in self.active
                       if r is not None and r.uid not in subj)
        return self._pc(), co

    def _tile_close(self, name: str, cat: str, t0: float, co: tuple,
                    *, uids: tuple, hist=None, **attrs):
        """One buffered record (raw perf_counter stamps) — Span and
        histogram materialization happens at `Tracer.drain()`."""
        if not self._obs_on:
            return
        self._tr.tile(name, cat, t0, self._pc(), uids, co, hist,
                      attrs or None)

    def metrics_snapshot(self) -> dict:
        """Registry snapshot with buffered step tiles drained."""
        self._tr.drain()
        return self.obs.registry.snapshot()

    # registry-backed attribute views (DESIGN.md §11)
    prefill_compilations = obs_mod.stat_view("serve.prefill_compilations")
    decode_steps = obs_mod.stat_view("serve.decode_steps")
