"""Model configuration shared by every architecture family."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    # family: dense | moe | rwkv6 | hybrid | encoder
    family: str = "dense"
    num_layers: int = 2
    d_model: int = 64
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 16
    d_ff: int = 128
    vocab_size: int = 256

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    causal: bool = True
    attn_logit_softcap: Optional[float] = None

    # MLP options: silu -> SwiGLU, gelu -> GeGLU, plain -> fc1/act/fc2
    mlp_act: str = "silu"
    mlp_glu: bool = True

    # embedding / head
    tie_embeddings: bool = False
    scale_embeddings: bool = False     # gemma-style sqrt(d_model) scaling
    # tokens -> standard LM; embeddings -> frontend-stub (audio/VLM backbones)
    input_mode: str = "tokens"

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_groups: int = 1   # dispatch groups; == data-shard count in production

    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # Mamba2 (hybrid family)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64
    shared_attn_period: int = 0  # zamba2: shared block every k-th layer

    # normalization
    norm_eps: float = 1e-6

    # numerics / lowering
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    scan_layers: bool = True
    unroll_layers: bool = False        # cost-accounting mode (see DESIGN.md §7)
    attn_chunk: int = 0                # 0 -> naive attention; else online-softmax
    loss_chunk: int = 0                # 0 -> full logits; else chunked CE
    seq_shard_activations: bool = False

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
