from repro.models.config import ModelConfig  # noqa: F401
from repro.models.lm import LM  # noqa: F401
from repro.models.zamba import ZambaLM  # noqa: F401


def build_model(cfg: ModelConfig):
    if cfg.family == "hybrid":
        return ZambaLM(cfg)
    return LM(cfg)
