"""Decoder / encoder LM assembly for every architecture family.

One `LM` class covers the uniform-stack families (dense, moe, rwkv6,
encoder) with scan-over-layers (stacked per-layer params keep the HLO small:
an 80-layer model compiles as one while loop).  The zamba2 hybrid (periodic
shared attention block) lives in models/zamba.py.

Batch format (training):
    {"tokens": (B, S) int32}  or  {"embeds": (B, S, d)}   (frontend stubs)
    {"labels": (B, S) int32, "loss_mask": (B, S) f32}

Decode state is a pytree stacked over layers; `prefill` fills it, `decode`
advances one token.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn import core as nncore
from repro.nn import layers as L
from repro.nn import mlp as mlpmod
from repro.nn import moe as moemod
from repro.nn import rwkv6 as rwkvmod
from repro.nn.attention import (KVCache, PagedKVCache, attention,
                                attention_decode, attention_decode_paged,
                                attention_prefill, attention_prefill_paged,
                                attention_spec, attention_verify_paged)
from repro.parallel.sharding import shard_logical


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class LM:
    """Uniform-stack language model (dense / moe / rwkv6 / encoder)."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.family in ("dense", "moe", "rwkv6", "encoder"), cfg.family
        self.cfg = cfg

    # ------------------------------------------------------------- specs
    def block_spec(self):
        cfg = self.cfg
        if cfg.family == "rwkv6":
            return {
                "ln1": L.rmsnorm_spec(cfg.d_model),
                "tmix": rwkvmod.time_mix_spec(cfg),
                "ln2": L.rmsnorm_spec(cfg.d_model),
                "cmix": rwkvmod.channel_mix_spec(cfg),
            }
        spec = {
            "ln1": L.rmsnorm_spec(cfg.d_model),
            "attn": attention_spec(cfg),
            "ln2": L.rmsnorm_spec(cfg.d_model),
        }
        if cfg.family == "moe":
            spec["moe"] = moemod.moe_spec(cfg)
        else:
            spec["mlp"] = mlpmod.mlp_spec(cfg)
        return spec

    def spec(self):
        cfg = self.cfg
        spec = {
            "embed": L.embedding_spec(cfg.vocab_size, cfg.d_model),
            "blocks": nncore.stack_specs(self.block_spec(), cfg.num_layers),
            "final_norm": L.rmsnorm_spec(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            spec["lm_head"] = L.lm_head_spec(cfg.d_model, cfg.vocab_size)
        return spec

    def init(self, key):
        return nncore.init_params(key, self.spec(),
                                  dtype=_dtype(self.cfg.param_dtype))

    def axes(self):
        return nncore.axes_tree(self.spec())

    def param_shapes(self):
        return nncore.shape_tree(self.spec(),
                                 dtype=_dtype(self.cfg.param_dtype))

    # ------------------------------------------------------------ blocks
    def _block(self, params, x, positions=None):
        """Training/plain-forward block.  Returns (x, aux)."""
        cfg = self.cfg
        if cfg.family == "rwkv6":
            h, _, _ = rwkvmod.time_mix(
                params["tmix"], L.rmsnorm(params["ln1"], x, cfg.norm_eps),
                cfg, chunk=cfg.ssm_chunk or 64, unroll=cfg.unroll_layers)
            x = x + h
            h, _ = rwkvmod.channel_mix(
                params["cmix"], L.rmsnorm(params["ln2"], x, cfg.norm_eps), cfg)
            return x + h, 0.0
        h = attention(params["attn"], L.rmsnorm(params["ln1"], x, cfg.norm_eps),
                      cfg, positions)
        x = x + h
        hn = L.rmsnorm(params["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            h, aux = moemod.moe(params["moe"], hn, cfg)
        else:
            h, aux = mlpmod.mlp(params["mlp"], hn, cfg), 0.0
        return x + h, aux

    def _embed_in(self, params, batch):
        cfg = self.cfg
        if "embeds" in batch:
            x = batch["embeds"].astype(_dtype(cfg.compute_dtype))
            x = shard_logical(x, ("batch", "seq", "embed"))
        else:
            scale = cfg.d_model ** 0.5 if cfg.scale_embeddings else None
            x = L.embed(params["embed"], batch["tokens"], scale,
                        _dtype(cfg.compute_dtype))
        return x

    def _head_w(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"]["table"].T
        return params["lm_head"]["w"]

    # ----------------------------------------------------------- forward
    def forward(self, params, batch):
        """-> (hidden (B, S, d), aux_loss)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)

        def body(carry, lyr):
            x, aux = carry
            x2, a = self._block(lyr, x)
            return (x2, aux + a), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

        if cfg.scan_layers and not cfg.unroll_layers:
            (x, aux), _ = jax.lax.scan(body, (x, 0.0), params["blocks"])
        else:
            carry = (x, 0.0)
            for i in range(cfg.num_layers):
                lyr = jax.tree.map(lambda a: a[i], params["blocks"])
                carry, _ = body(carry, lyr)
            x, aux = carry
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def loss(self, params, batch):
        """-> (scalar loss, metrics dict)."""
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        ce = L.cross_entropy(h, self._head_w(params).astype(h.dtype),
                             batch["labels"], batch.get("loss_mask"),
                             chunk=cfg.loss_chunk, unroll=cfg.unroll_layers)
        loss = ce + cfg.router_aux_coef * aux
        return loss, {"ce": ce, "aux": aux}

    def logits(self, params, batch):
        h, _ = self.forward(params, batch)
        return h @ self._head_w(params).astype(h.dtype)

    # ----------------------------------------------------------- serving
    def cache_axes(self):
        """Logical-axis tree matching init_cache's structure."""
        cfg = self.cfg
        if cfg.family == "rwkv6":
            return rwkvmod.RwkvState(
                tm_last=("layers", "batch", "embed"),
                cm_last=("layers", "batch", "embed"),
                wkv=("layers", "batch", "heads", None, None))
        return KVCache(
            k=("layers", "batch", "cache_seq", None, "head_dim"),
            v=("layers", "batch", "cache_seq", None, "head_dim"),
            key_pos=("layers", "batch", "cache_seq"))

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = _dtype(cfg.compute_dtype)
        if cfg.family == "rwkv6":
            one = rwkvmod.RwkvState.init(batch, cfg, dt)
        else:
            window = min(cfg.sliding_window or max_len, max_len)
            one = KVCache.init(batch, window, cfg.num_kv_heads,
                               cfg.head_dim, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape)
            .copy(), one)

    def init_paged_cache(self, num_pages: int, page_size: int):
        """Per-layer-stacked page pool for the PagedKV serving engine
        (DESIGN.md §5): (L, P, page_size, H_kv, D) zeros, shared by every
        batch slot.  Attention families page their cache (sliding-window
        configs included — their block tables address a ring of
        `attention.ring_shape` pages); rwkv6 has no KV at all, its
        recurrent state lives in the engine's per-slot arena and is
        charged to the pool as "state"-class slab pages."""
        cfg = self.cfg
        if cfg.family == "rwkv6":
            raise ValueError("rwkv6 keeps fixed recurrent state — no KV "
                             "cache to page; the paged engine serves it "
                             "from a state arena charged as slab pages")
        dt = _dtype(cfg.compute_dtype)
        one = PagedKVCache.init(num_pages, page_size, cfg.num_kv_heads,
                                cfg.head_dim, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape)
            .copy(), one)

    def prefill_paged(self, params, batch, pages, block_table, *,
                      start_pos, write_upto, last_pos,
                      whole_prompt: bool = True, overlay=None,
                      overlay_backend: str = "lax"):
        """Prefill one chunk of ONE sequence through the paged pool.

        batch: tokens (1, C) at absolute positions
        [start_pos, start_pos + C); block_table: (1, nmax) the sequence's
        block table; `write_upto` caps K/V writes (right-padding beyond
        the real prompt goes to the trash page); `last_pos` gathers the
        logits at that CHUNK-LOCAL position.  `whole_prompt` (static)
        keeps the bitwise-identical-to-dense intra-chunk attention read
        when the chunk covers the entire prompt (see
        `attention_prefill_paged`).  `overlay` (optional) is a per-layer
        adapter-overlay pytree — {"attn": {...}, "mlp": {...}} with
        (L, 1, k) idx/val leaves — composed into every planned projection
        by `ops.overlay_matmul` (merge-free serving, DESIGN.md §5).
        Returns (logits (1, 1, V), pages)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)

        def body(x, lc):
            lyr, pg = lc[0], lc[1]
            ov = lc[2] if len(lc) > 2 else None
            xn = L.rmsnorm(lyr["ln1"], x, cfg.norm_eps)
            h, new_pg = attention_prefill_paged(
                lyr["attn"], xn, cfg, pg, block_table,
                start_pos=start_pos, write_upto=write_upto,
                whole_prompt=whole_prompt,
                ov=ov["attn"] if ov else None, ov_backend=overlay_backend)
            x = x + h
            xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moemod.moe(lyr["moe"], xn2, cfg)
            else:
                h = mlpmod.mlp(lyr["mlp"], xn2, cfg,
                               ov["mlp"] if ov else None, overlay_backend)
            return x + h, new_pg

        x, pages = self._scan_serve(params, x, pages, body, overlay)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        x = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(last_pos, jnp.int32), 1, axis=1)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, pages

    def decode_paged(self, params, tokens, pages, block_tables, positions,
                     backend: str = "auto", overlay=None,
                     overlay_backend: str = "lax"):
        """One-token decode through the paged pool.  tokens: (B, 1);
        block_tables: (B, nmax); positions: (B,).  Inactive slots carry
        an all-zero block table and position 0 — their writes land in the
        trash page.  `overlay` (optional) is a per-layer adapter-overlay
        pytree with (L, B, k) idx/val leaves: each batch slot's sparse
        delta composes into the planned projections inside the matmul
        (merge-free multi-adapter serving, DESIGN.md §5); slots serving
        the base model carry all-sentinel indices.  -> (logits, pages)."""
        cfg = self.cfg
        if cfg.is_encoder:
            raise ValueError("encoder-only models have no decode step")
        x = self._embed_in(params, {"tokens": tokens})

        def body(x, lc):
            lyr, pg = lc[0], lc[1]
            ov = lc[2] if len(lc) > 2 else None
            xn = L.rmsnorm(lyr["ln1"], x, cfg.norm_eps)
            h, new_pg = attention_decode_paged(
                lyr["attn"], xn, cfg, pg, block_tables, positions,
                backend=backend, ov=ov["attn"] if ov else None,
                ov_backend=overlay_backend)
            x = x + h
            xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moemod.moe(lyr["moe"], xn2, cfg)
            else:
                h = mlpmod.mlp(lyr["mlp"], xn2, cfg,
                               ov["mlp"] if ov else None, overlay_backend)
            return x + h, new_pg

        x, pages = self._scan_serve(params, x, pages, body, overlay)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, pages

    def decode_paged_multi(self, params, tokens, pages, block_tables,
                           positions, backend: str = "auto", overlay=None,
                           overlay_backend: str = "lax"):
        """Speculative verify: n_q consecutive decode tokens per
        sequence in one dispatch.  tokens: (B, n_q) — token i of row b
        sits at position positions[b] + i; block_tables: (B, nmax);
        positions: (B,).  Returns (logits (B, n_q, V), pages): logits
        row i is the model's next-token distribution after token i,
        bitwise-equal to what `decode_paged` would produce one token at
        a time (every sub-op is row-wise — the verify attention read
        applies a per-row causal mask and everything else never mixes
        positions), which is the speculative engine's acceptance rule.

        Only the dense family takes this path: MoE capacity dispatch
        routes by the dispatch's token count, so an n_q-token verify
        would change real tokens' expert routing vs one-token decode —
        the engine refuses speculation for moe/hybrid models."""
        cfg = self.cfg
        if cfg.is_encoder:
            raise ValueError("encoder-only models have no decode step")
        x = self._embed_in(params, {"tokens": tokens})

        def body(x, lc):
            lyr, pg = lc[0], lc[1]
            ov = lc[2] if len(lc) > 2 else None
            xn = L.rmsnorm(lyr["ln1"], x, cfg.norm_eps)
            h, new_pg = attention_verify_paged(
                lyr["attn"], xn, cfg, pg, block_tables, positions,
                backend=backend, ov=ov["attn"] if ov else None,
                ov_backend=overlay_backend)
            x = x + h
            xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
            x = x + mlpmod.mlp(lyr["mlp"], xn2, cfg,
                               ov["mlp"] if ov else None, overlay_backend)
            return x, new_pg

        x, pages = self._scan_serve(params, x, pages, body, overlay)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, pages

    def prefill(self, params, batch, cache, last_pos=None):
        """batch: tokens/embeds (B, S).  Returns (last-token logits, cache).

        `last_pos` (traced int32 scalar, optional) reads the logits at
        that position instead of S-1 — the serving engine's bucketed
        prefill right-pads prompts to a power-of-two length and gathers
        the real last token here, so one compiled program serves every
        prompt length in the bucket (mask-aware: causal attention keeps
        positions <= last_pos blind to the padding, and decode never
        attends a pad slot — its key_pos exceeds every query position
        until the slot is overwritten)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)

        def body(x, lyr_and_cache):
            lyr, c = lyr_and_cache
            xn = L.rmsnorm(lyr["ln1"], x, cfg.norm_eps)
            if cfg.family == "rwkv6":
                h, tm_last, wkv = rwkvmod.time_mix(
                    lyr["tmix"], xn, cfg, last=c.tm_last, state=c.wkv,
                    chunk=cfg.ssm_chunk or 64, unroll=cfg.unroll_layers)
                x = x + h
                xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
                h, cm_last = rwkvmod.channel_mix(lyr["cmix"], xn2, cfg,
                                                 last=c.cm_last)
                new_c = rwkvmod.RwkvState(tm_last.astype(c.tm_last.dtype),
                                          cm_last.astype(c.cm_last.dtype),
                                          wkv)
            else:
                h, new_c = attention_prefill(lyr["attn"], xn, cfg, c)
                x = x + h
                xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
                if cfg.family == "moe":
                    h, _ = moemod.moe(lyr["moe"], xn2, cfg)
                else:
                    h = mlpmod.mlp(lyr["mlp"], xn2, cfg)
            return x + h, new_c

        x, cache = self._scan_serve(params, x, cache, body)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if last_pos is None:
            x = x[:, -1:, :]
        else:
            x = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(last_pos, jnp.int32), 1, axis=1)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, cache

    def decode(self, params, tokens, cache, positions):
        """tokens: (B, 1) int32; positions: (B,).  -> (logits, cache)."""
        cfg = self.cfg
        if cfg.is_encoder:
            raise ValueError("encoder-only models have no decode step")
        x = self._embed_in(params, {"tokens": tokens})

        def body(x, lyr_and_cache):
            lyr, c = lyr_and_cache
            xn = L.rmsnorm(lyr["ln1"], x, cfg.norm_eps)
            if cfg.family == "rwkv6":
                h, tm_last, wkv = rwkvmod.time_mix(
                    lyr["tmix"], xn, cfg, last=c.tm_last, state=c.wkv,
                    chunk=1)
                x = x + h
                xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
                h, cm_last = rwkvmod.channel_mix(lyr["cmix"], xn2, cfg,
                                                 last=c.cm_last)
                new_c = rwkvmod.RwkvState(tm_last.astype(c.tm_last.dtype),
                                          cm_last.astype(c.cm_last.dtype),
                                          wkv)
            else:
                h, new_c = attention_decode(lyr["attn"], xn, cfg, c, positions)
                x = x + h
                xn2 = L.rmsnorm(lyr["ln2"], x, cfg.norm_eps)
                if cfg.family == "moe":
                    h, _ = moemod.moe(lyr["moe"], xn2, cfg)
                else:
                    h = mlpmod.mlp(lyr["mlp"], xn2, cfg)
            return x + h, new_c

        x, cache = self._scan_serve(params, x, cache, body)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, cache

    def _scan_serve(self, params, x, cache, body, overlay=None):
        """Scan `body` over the layer stack.  `overlay` (optional) rides
        as a third scanned operand — a per-layer pytree with leading
        layer axis (adapter overlays for merge-free serving); when None
        the scanned tuple is exactly the pre-overlay (blocks, cache), so
        overlay-free callers compile the identical HLO as before.

        Planned projection leaves of params["blocks"] may be
        quantized-operand dicts (int8 base + principal overlay,
        `quant.QuantArtifact.to_params`, DESIGN.md §12): every leaf
        leads with the layer axis, so the scan slices {"q", "scale",
        "idx", "val"} per layer like any other leaf and the nn layers'
        `weight_operand`/`overlay_matmul` fuse dequant into the dots."""
        cfg = self.cfg
        xs = ((params["blocks"], cache) if overlay is None
              else (params["blocks"], cache, overlay))
        if cfg.scan_layers and not cfg.unroll_layers:
            def scan_body(x, lc):
                x2, new_c = body(x, lc)
                return x2, new_c
            x, new_cache = jax.lax.scan(scan_body, x, xs)
            return x, new_cache
        new_layers = []
        for i in range(cfg.num_layers):
            lc = jax.tree.map(lambda a: a[i], xs)
            x, nc = body(x, lc)
            new_layers.append(nc)
        new_cache = jax.tree.map(lambda *a: jnp.stack(a), *new_layers)
        return x, new_cache
