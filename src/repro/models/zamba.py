"""Zamba2-style hybrid: Mamba-2 backbone with a *shared* transformer block.

Every `shared_attn_period`-th layer, the hidden state is concatenated with
the original embedding (width 2*d_model), run through ONE shared attention+
MLP block (same parameters each invocation), and projected back to d_model
through a per-invocation linear.  The backbone layers are Mamba-2 blocks.

The stack is non-uniform, so layers are a python loop (38 mamba bodies + ~6
shared invocations still compile quickly); dry-run cost extrapolation uses
depth P and 2P with P = shared_attn_period (DESIGN.md §7).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.lm import LM, _dtype
from repro.nn import core as nncore
from repro.nn import layers as L
from repro.nn import mlp as mlpmod
from repro.nn.attention import (KVCache, PagedKVCache, attention,
                                attention_decode, attention_decode_paged,
                                attention_prefill, attention_prefill_paged,
                                attention_spec)
from repro.nn.core import Spec
from repro.nn.mamba2 import MambaState, mamba2, mamba2_spec


class ZambaCache(NamedTuple):
    mamba: MambaState      # stacked over mamba layers
    kv: KVCache            # stacked over shared-block invocations


class ZambaLM(LM):
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "hybrid"
        assert cfg.shared_attn_period > 0
        self.cfg = cfg

    @property
    def n_shared(self) -> int:
        return self.cfg.num_layers // self.cfg.shared_attn_period

    def shared_cfg(self) -> ModelConfig:
        cfg = self.cfg
        return cfg.replace(d_model=2 * cfg.d_model, family="dense",
                           sliding_window=None)

    def spec(self):
        cfg = self.cfg
        d = cfg.d_model
        scfg = self.shared_cfg()
        mamba_block = {
            "ln": L.rmsnorm_spec(d),
            "mixer": mamba2_spec(cfg),
        }
        shared = {
            "ln1": L.rmsnorm_spec(2 * d),
            "attn": attention_spec(scfg),
            "ln2": L.rmsnorm_spec(2 * d),
            "mlp": mlpmod.mlp_spec(scfg),
        }
        return {
            "embed": L.embedding_spec(cfg.vocab_size, d),
            "blocks": nncore.stack_specs(mamba_block, cfg.num_layers),
            "shared": shared,
            "down_proj": Spec((self.n_shared, 2 * d, d),
                              ("layers", "mlp", "embed")),
            "final_norm": L.rmsnorm_spec(d),
            "lm_head": L.lm_head_spec(d, cfg.vocab_size),
        }

    # ------------------------------------------------------------ forward
    def _shared_apply(self, params, x, e0, inv_idx, mode="train",
                      cache=None, positions=None, paged=None):
        """x: (B, S, d) hidden; e0: (B, S, d) original embeddings.
        `paged` carries the PagedKV context (block tables, chunk offsets,
        read backend) when mode is *_paged — the shared-block KV then
        lives in the page pool instead of a dense per-slot cache."""
        cfg = self.cfg
        scfg = self.shared_cfg()
        u = jnp.concatenate([x, e0], axis=-1)
        un = L.rmsnorm(params["shared"]["ln1"], u, cfg.norm_eps)
        new_kv = None
        if mode == "train":
            a = attention(params["shared"]["attn"], un, scfg)
        elif mode == "prefill":
            a, new_kv = attention_prefill(params["shared"]["attn"], un, scfg,
                                          cache)
        elif mode == "prefill_paged":
            a, new_kv = attention_prefill_paged(
                params["shared"]["attn"], un, scfg, cache,
                paged["block_tables"], start_pos=paged["start_pos"],
                write_upto=paged["write_upto"], whole_prompt=True)
        elif mode == "decode_paged":
            a, new_kv = attention_decode_paged(
                params["shared"]["attn"], un, scfg, cache,
                paged["block_tables"], positions,
                backend=paged["backend"])
        else:
            a, new_kv = attention_decode(params["shared"]["attn"], un, scfg,
                                         cache, positions)
        u = u + a
        un = L.rmsnorm(params["shared"]["ln2"], u, cfg.norm_eps)
        u = u + mlpmod.mlp(params["shared"]["mlp"], un, scfg)
        dp = params["down_proj"][inv_idx].astype(x.dtype)
        return x + u @ dp, new_kv

    def _iter_layers(self, params, x, e0, mode, cache=None, positions=None,
                     paged=None):
        cfg = self.cfg
        new_mamba, new_kv = [], []
        inv = 0
        for i in range(cfg.num_layers):
            lyr = jax.tree.map(lambda a: a[i], params["blocks"])
            st = None if cache is None else \
                jax.tree.map(lambda a: a[i], cache.mamba)
            xn = L.rmsnorm(lyr["ln"], x, cfg.norm_eps)
            h, new_st = mamba2(lyr["mixer"], xn, cfg, state=st,
                               chunk=cfg.ssm_chunk, unroll=cfg.unroll_layers)
            x = x + h
            if st is not None:
                new_mamba.append(new_st)
            if (i + 1) % cfg.shared_attn_period == 0 and inv < self.n_shared:
                kv = None if cache is None else \
                    jax.tree.map(lambda a: a[inv], cache.kv)
                x, nkv = self._shared_apply(params, x, e0, inv, mode, kv,
                                            positions, paged)
                if nkv is not None:
                    new_kv.append(nkv)
                inv += 1
        if cache is None:
            return x, None
        stacked_m = jax.tree.map(lambda *a: jnp.stack(a), *new_mamba)
        stacked_kv = jax.tree.map(lambda *a: jnp.stack(a), *new_kv)
        return x, ZambaCache(stacked_m, stacked_kv)

    def forward(self, params, batch):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        e0 = x
        x, _ = self._iter_layers(params, x, e0, "train")
        return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), 0.0

    # ----------------------------------------------------------- serving
    def cache_axes(self):
        from repro.nn.mamba2 import MambaState
        return ZambaCache(
            mamba=MambaState(
                conv_x=("layers", "batch", None, "mlp"),
                conv_b=("layers", "batch", None, "state"),
                conv_c=("layers", "batch", None, "state"),
                ssm=("layers", "batch", "heads", None, None)),
            kv=KVCache(
                k=("layers", "batch", "cache_seq", None, "head_dim"),
                v=("layers", "batch", "cache_seq", None, "head_dim"),
                key_pos=("layers", "batch", "cache_seq")))

    def init_mamba_state(self, batch: int):
        """(L, batch, ...) stacked fresh recurrent state — the fixed-size
        half of the hybrid cache (paged serving splices this per slot
        while the attention KV lives in the shared page pool)."""
        cfg = self.cfg
        m = MambaState.init(batch, cfg, _dtype(cfg.compute_dtype))
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (cfg.num_layers,) + a.shape).copy(), m)

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dt = _dtype(cfg.compute_dtype)
        mamba = self.init_mamba_state(batch)
        scfg = self.shared_cfg()
        kv1 = KVCache.init(batch, max_len, scfg.num_kv_heads, scfg.head_dim,
                           dt)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (self.n_shared,) + a.shape).copy(), kv1)
        return ZambaCache(mamba, kv)

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int):
        """Hybrid paged cache (DESIGN.md §5): the mamba backbone keeps its
        FIXED per-slot recurrent state ((L, B, ...) — nothing to page),
        while the shared attention blocks' KV routes through a page pool
        stacked over the n_shared invocations."""
        cfg = self.cfg
        dt = _dtype(cfg.compute_dtype)
        mamba = self.init_mamba_state(batch)
        scfg = self.shared_cfg()
        kv1 = PagedKVCache.init(num_pages, page_size, scfg.num_kv_heads,
                                scfg.head_dim, dt)
        kv = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None],
                                       (self.n_shared,) + a.shape).copy(), kv1)
        return ZambaCache(mamba, kv)

    def prefill_paged(self, params, batch, cache, block_table, *,
                      start_pos, write_upto, last_pos,
                      whole_prompt: bool = True):
        """Whole-prompt prefill of ONE sequence through the paged pool:
        cache.mamba is the (L, 1, ...) recurrent state of this slot,
        cache.kv the SHARED page pool.  The engine never pads or chunks
        hybrid prompts (the mamba state is position-dependent), so the
        chunk is the exact prompt and `whole_prompt` stays True."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        paged = {"block_tables": block_table, "start_pos": start_pos,
                 "write_upto": write_upto, "backend": "auto"}
        x, cache = self._iter_layers(params, x, x, "prefill_paged", cache,
                                     paged=paged)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        x = jax.lax.dynamic_slice_in_dim(
            x, jnp.asarray(last_pos, jnp.int32), 1, axis=1)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, cache

    def decode_paged(self, params, tokens, cache, block_tables, positions,
                     backend: str = "auto"):
        cfg = self.cfg
        x = self._embed_in(params, {"tokens": tokens})
        paged = {"block_tables": block_tables, "backend": backend}
        x, cache = self._iter_layers(params, x, x, "decode_paged", cache,
                                     positions, paged=paged)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, cache

    def prefill(self, params, batch, cache, last_pos=None):
        cfg = self.cfg
        x = self._embed_in(params, batch)
        x, cache = self._iter_layers(params, x, x, "prefill", cache)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if last_pos is None:
            x = x[:, -1:, :]
        else:
            # API parity with LM.prefill; the serving engine never pads
            # hybrid models (mamba state is position-dependent), so
            # last_pos is S-1 here
            x = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(last_pos, jnp.int32), 1, axis=1)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, cache

    def decode(self, params, tokens, cache, positions):
        cfg = self.cfg
        x = self._embed_in(params, {"tokens": tokens})
        x, cache = self._iter_layers(params, x, x, "decode", cache, positions)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = x @ self._head_w(params).astype(x.dtype)
        return logits, cache
