"""Mamba-2 block (SSD: state-space duality, scalar per-head decay).

Recurrence per head (P = head dim, N = state dim):
    h_t = a_t h_{t-1} + dt_t * (B_t  x_t^T)        h: (N, P)
    y_t = C_t h_t + D * x_t
with a_t = exp(dt_t * A_h),  A_h < 0 learned scalar per head, dt_t > 0 from a
softplus-parameterized projection.  Chunked evaluation mirrors the Mamba-2
paper's SSD algorithm: intra-chunk "attention-like" term with decay-weighted
scores, cross-chunk scanned state.  A causal depthwise conv (width 4) runs on
the x / B / C streams; decode carries a (conv_width-1)-deep conv cache and
the (H, N, P) state.

TP note: the reference fuses x|B|C into one conv stream; we keep three
separate depthwise convs (mathematically identical) so the big x stream
shards over "model" while the small B/C streams stay replicated — no
cross-shard slicing.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.parallel.sharding import shard_logical


def mamba2_spec(cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.ssm_d_inner
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.ssm_conv
    return {
        "in_z": Spec((d, din), ("embed", "mlp")),
        "in_x": Spec((d, din), ("embed", "mlp")),
        "in_b": Spec((d, N), ("embed", "state")),
        "in_c": Spec((d, N), ("embed", "state")),
        "in_dt": Spec((d, H), ("embed", "heads"), init="small"),
        "conv_x_w": Spec((W, din), ("conv", "mlp"), init="fan_in"),
        "conv_x_b": Spec((din,), ("mlp",), init="zeros"),
        "conv_b_w": Spec((W, N), ("conv", "state"), init="fan_in"),
        "conv_b_b": Spec((N,), ("state",), init="zeros"),
        "conv_c_w": Spec((W, N), ("conv", "state"), init="fan_in"),
        "conv_c_b": Spec((N,), ("state",), init="zeros"),
        "a_log": Spec((H,), ("heads",), init="zeros"),
        "dt_bias": Spec((H,), ("heads",), init="zeros"),
        "d_skip": Spec((H,), ("heads",), init="ones"),
        "norm": Spec((din,), ("mlp",), init="ones"),
        "out": Spec((din, d), ("mlp", "embed")),
    }


class MambaState(NamedTuple):
    conv_x: jax.Array  # (B, W-1, din)
    conv_b: jax.Array  # (B, W-1, N)
    conv_c: jax.Array  # (B, W-1, N)
    ssm: jax.Array     # (B, H, N, P) fp32

    @staticmethod
    def init(batch: int, cfg: ModelConfig, dtype):
        W = cfg.ssm_conv
        return MambaState(
            conv_x=jnp.zeros((batch, W - 1, cfg.ssm_d_inner), dtype),
            conv_b=jnp.zeros((batch, W - 1, cfg.ssm_state), dtype),
            conv_c=jnp.zeros((batch, W - 1, cfg.ssm_state), dtype),
            ssm=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), jnp.float32),
        )


def state_nbytes(cfg, dtype) -> int:
    """Device bytes of ONE sequence's full-stack mamba state (all
    `num_layers` MambaStates at batch 1) — what the serving engine
    charges to the page pool as a state slab, computed from shapes
    without materializing arrays."""
    W = cfg.ssm_conv
    item = jnp.dtype(dtype).itemsize
    per_layer = (W - 1) * (cfg.ssm_d_inner + 2 * cfg.ssm_state) * item \
        + cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4
    return cfg.num_layers * per_layer


def _causal_conv(x, w, b, cache: Optional[jax.Array]):
    """Depthwise causal conv + silu.  x: (B, S, C); w: (W, C)."""
    B, S, C = x.shape
    W = w.shape[0]
    pad = jnp.zeros((B, W - 1, C), x.dtype) if cache is None \
        else cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, C)
    out = sum(xp[:, i:i + S, :] * w[i].astype(x.dtype) for i in range(W))
    new_cache = xp[:, S:, :]          # trailing W-1 inputs
    return jax.nn.silu(out + b.astype(x.dtype)), new_cache


def _chunked_ssd(x, B_, C_, la, dt, S0, chunk: int, unroll: bool = False):
    """Chunked SSD, batched formulation (Mamba-2 paper algorithm):
    the intra-chunk quadratic term is computed for ALL chunks at once
    (one set of einsums with the chunk index as a batch dim — MXU-friendly,
    tiny HLO), and the inter-chunk state recurrence
        S_k = a_k * S_{k-1} + b_k
    is an affine associative scan (log-depth, no while loop — which also
    makes `cost_analysis()` exact without unrolling; DESIGN.md §7).

    x: (B,T,H,P); B_/C_: (B,T,N); la/dt: (B,T,H); S0: (B,H,N,P).
    """
    del unroll  # batched form has no sequential loop to unroll
    Bb, T, H, P = x.shape
    if T % chunk != 0:
        chunk = T
    n, c = T // chunk, min(chunk, T)

    def ch(a):
        return a.reshape(Bb, n, c, *a.shape[2:])

    xc, Bc, Cc, lac, dtc = map(ch, (x, B_, C_, la, dt))
    mask = (jnp.arange(c)[:, None] >= jnp.arange(c)[None, :])

    ca = jnp.cumsum(lac, axis=2)                      # (B, n, c, H)
    dif = ca[:, :, :, None] - ca[:, :, None, :]       # (B, n, t, s, H)
    L = jnp.exp(jnp.minimum(dif, 0.0)) * mask[None, None, :, :, None]
    cb = jnp.einsum("bntk,bnsk->bnts", Cc, Bc)
    w = L * cb[..., None] * dtc[:, :, None, :, :]     # (B, n, t, s, H)
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", w, xc)

    # per-chunk state contribution and decay
    b_dec = (Bc[:, :, :, None, :]
             * jnp.exp(ca[:, :, -1:, :, None] - ca[..., None])
             * dtc[..., None])                        # (B, n, s, H, N)
    contrib = jnp.einsum("bnshk,bnshp->bnhkp", b_dec, xc)  # (B,n,H,N,P)
    a = jnp.exp(ca[:, :, -1])                         # (B, n, H)

    # affine associative scan over chunks, seeded with S0
    a_all = jnp.concatenate([jnp.ones((Bb, 1, H), a.dtype), a], axis=1)
    b_all = jnp.concatenate([S0[:, None], contrib], axis=1)  # (B,n+1,H,N,P)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2[..., None, None] * b1 + b2

    A, S_all = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    S_prev = S_all[:, :-1]                            # state BEFORE chunk k
    S_final = S_all[:, -1]

    c_dec = Cc[:, :, :, None, :] * jnp.exp(ca)[..., None]   # (B,n,t,H,N)
    y_cross = jnp.einsum("bnthk,bnhkp->bnthp", c_dec, S_prev)
    y = (y_intra + y_cross).reshape(Bb, T, H, P)
    return y, S_final


def mamba2(params, x, cfg: ModelConfig, state: Optional[MambaState] = None,
           chunk: int = 0, unroll: bool = False):
    """x: (B, S, d_model) -> (out, new_state)."""
    B, S, d = x.shape
    dt_ = x.dtype
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    din = cfg.ssm_d_inner

    z = shard_logical(x @ params["in_z"].astype(dt_), ("batch", "seq", "mlp"))
    xin = shard_logical(x @ params["in_x"].astype(dt_), ("batch", "seq", "mlp"))
    bin_ = x @ params["in_b"].astype(dt_)
    cin = x @ params["in_c"].astype(dt_)
    dt_raw = x @ params["in_dt"].astype(dt_)                   # (B, S, H)

    cx = state.conv_x if state is not None else None
    cb = state.conv_b if state is not None else None
    cc = state.conv_c if state is not None else None
    xin, ncx = _causal_conv(xin, params["conv_x_w"], params["conv_x_b"], cx)
    bin_, ncb = _causal_conv(bin_, params["conv_b_w"], params["conv_b_b"], cb)
    cin, ncc = _causal_conv(cin, params["conv_c_w"], params["conv_c_b"], cc)

    xs = xin.reshape(B, S, H, P)
    B_ = bin_.astype(jnp.float32)
    C_ = cin.astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))          # (H,) < 0
    la = dt * A[None, None, :]                                 # log decay < 0

    S0 = state.ssm if state is not None \
        else jnp.zeros((B, H, N, P), jnp.float32)
    y, S_new = _chunked_ssd(xs.astype(jnp.float32), B_, C_, la, dt,
                            S0, chunk or S, unroll)
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(B, S, din).astype(dt_)

    # gated RMSNorm (Mamba-2 norm before out proj)
    g = jax.nn.silu(z)
    y32 = (y * g).astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * params["norm"].astype(jnp.float32)).astype(dt_)
    out = y @ params["out"].astype(dt_)
    out = shard_logical(out, ("batch", "seq", "embed"))
    sd = state.conv_x.dtype if state is not None else dt_
    return out, MambaState(ncx.astype(sd), ncb.astype(sd), ncc.astype(sd),
                           S_new)
