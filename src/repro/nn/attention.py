"""Multi-head attention: MHA/GQA/MQA, qk-norm, QKV bias, sliding window,
RoPE, flash or naive computation, and a position-explicit KV cache that
uniformly supports full caches and SWA rolling buffers.

Sharding scheme (DESIGN.md §3/§4): Q projection is head-sharded over the
"model" axis (Megatron column-parallel); K/V projections are replicated over
heads (GQA kv-head counts rarely divide the TP degree — replicating the small
KV computation beats 4x pad-waste); the output projection is row-parallel
(one psum per block, inserted by XLA from the sharding constraints).  KV
*caches* are sequence-sharded over the model axis for decode (context
parallelism — softmax stats are the only cross-shard collective).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.nn import layers as L
from repro.nn.flash import NEG_INF, causal_bias, flash_attention, full_bias
from repro.parallel.sharding import shard_logical


def attention_spec(cfg: ModelConfig):
    """Projections are stored 2-D flat: (d, Hq*hd) shards evenly over the
    model axis even when the head COUNT does not divide TP (qwen2's 28
    heads on a 16-way axis); the per-head (B, S, H, hd) view only exists as
    an intermediate, where GSPMD tolerates uneven (padded) sharding."""
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": Spec((d, hq * hd), ("embed", "heads_flat")),
        "wk": Spec((d, hkv * hd), ("embed", None)),
        "wv": Spec((d, hkv * hd), ("embed", None)),
        "wo": Spec((hq * hd, d), ("heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = Spec((hq * hd,), ("heads_flat",), init="zeros")
        spec["bk"] = Spec((hkv * hd,), (None,), init="zeros")
        spec["bv"] = Spec((hkv * hd,), (None,), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = L.rmsnorm_spec(hd, axis="head_dim")
        spec["k_norm"] = L.rmsnorm_spec(hd, axis="head_dim")
    return spec


class KVCache(NamedTuple):
    """k/v: (B, S_max, H_kv, D).  key_pos: (B, S_max) int32, -1 = empty.

    For full attention, slot i holds position i.  For sliding-window
    attention the cache is a rolling buffer: position p lives in slot
    p % S_max, and `key_pos` disambiguates stale entries — one mask rule
    covers both layouts.
    """
    k: jax.Array
    v: jax.Array
    key_pos: jax.Array

    @staticmethod
    def init(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
        return KVCache(
            k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            key_pos=jnp.full((batch, max_len), -1, jnp.int32),
        )


def _qkv(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    dt = x.dtype
    hd = cfg.head_dim
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = shard_logical(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_logical(k, ("batch", "seq", None, "head_dim"))
    v = shard_logical(v, ("batch", "seq", None, "head_dim"))
    return q, k, v


def _expand_kv(k, n_heads):
    """(B, T, H_kv, D) -> (B, T, H, D) by repetition (GQA groups)."""
    reps = n_heads // k.shape[2]
    if reps == 1:
        return k
    return jnp.repeat(k, reps, axis=2)


def _naive_attention(q, k, v, bias, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attention(params, x, cfg: ModelConfig, positions: Optional[jax.Array] = None):
    """Self-attention over a full sequence (training / prefill).

    x: (B, S, d_model); positions: (S,) or None -> arange.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(params, x, cfg)
    cos, sin = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    k = _expand_kv(k, cfg.num_heads)
    v = _expand_kv(v, cfg.num_heads)
    k = shard_logical(k, ("batch", "seq", "heads", "head_dim"))
    v = shard_logical(v, ("batch", "seq", "heads", "head_dim"))
    scale = cfg.head_dim ** -0.5

    if cfg.causal:
        bias_fn = causal_bias(window=cfg.sliding_window)
    else:
        bias_fn = full_bias()

    if cfg.attn_chunk and S > cfg.attn_chunk:
        qc = min(cfg.attn_chunk, S)
        o = flash_attention(q, k, v, bias_fn, scale, qc, qc,
                            cfg.unroll_layers)
    else:
        bias = bias_fn(positions, positions)
        o = _naive_attention(q, k, v, bias, scale)
    o = shard_logical(o, ("batch", "seq", "heads", "head_dim"))
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = o @ params["wo"].astype(x.dtype)
    return shard_logical(out, ("batch", "seq", "embed"))


def attention_prefill(params, x, cfg: ModelConfig, cache: KVCache):
    """Prefill: same as attention() but also writes the KV cache.

    Assumes x fills positions [0, S) and S <= cache length (full attention)
    or writes the last `window` positions (SWA rolling buffer).
    """
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _qkv(params, x, cfg)
    cos, sin = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    smax = cache.k.shape[1]
    if S >= smax:  # rolling buffer: keep the trailing window
        start = S - smax
        new_k = k[:, start:]
        new_v = v[:, start:]
        new_pos = jnp.broadcast_to(positions[start:], (B, smax))
        # rotate so that slot = pos % smax
        slots = (positions[start:] % smax).argsort()
        new_k = new_k[:, slots]
        new_v = new_v[:, slots]
        new_pos = new_pos[:, slots]
        new_cache = KVCache(new_k.astype(cache.k.dtype),
                            new_v.astype(cache.v.dtype), new_pos)
    else:
        new_cache = KVCache(
            jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(
                cache.key_pos,
                jnp.broadcast_to(positions, (B, S)).astype(jnp.int32),
                (0, 0)),
        )
    new_cache = KVCache(
        shard_logical(new_cache.k, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_cache.v, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_cache.key_pos, ("batch", "cache_seq")),
    )

    ke = shard_logical(_expand_kv(k, cfg.num_heads),
                       ("batch", "seq", "heads", "head_dim"))
    ve = shard_logical(_expand_kv(v, cfg.num_heads),
                       ("batch", "seq", "heads", "head_dim"))
    scale = cfg.head_dim ** -0.5
    bias_fn = causal_bias(window=cfg.sliding_window)
    if cfg.attn_chunk and S > cfg.attn_chunk:
        qc = min(cfg.attn_chunk, S)
        o = flash_attention(q, ke, ve, bias_fn, scale, qc, qc,
                            cfg.unroll_layers)
    else:
        o = _naive_attention(q, ke, ve, bias_fn(positions, positions), scale)
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = o @ params["wo"].astype(x.dtype)
    return shard_logical(out, ("batch", "seq", "embed")), new_cache


def attention_decode(params, x, cfg: ModelConfig, cache: KVCache,
                     positions: jax.Array):
    """One-token decode step.  x: (B, 1, d); positions: (B,) int32.

    Writes (k, v) into slot `pos % S_max` (identity for full caches sized to
    the max sequence) and attends over every cached key with
    key_pos in (pos - window, pos].
    """
    B = x.shape[0]
    smax = cache.k.shape[1]
    q, k, v = _qkv(params, x, cfg)          # (B, 1, h, d)
    cos, sin = L.rope_angles(positions[:, None], cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    slots = positions % smax                # (B,)
    barange = jnp.arange(B)
    new_k = cache.k.at[barange, slots].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[barange, slots].set(v[:, 0].astype(cache.v.dtype))
    new_pos = cache.key_pos.at[barange, slots].set(positions.astype(jnp.int32))
    new_cache = KVCache(
        shard_logical(new_k, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_v, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_pos, ("batch", "cache_seq")),
    )

    # Grouped attention read: no GQA expansion of the cache — decode is
    # memory-bound, so the cache is read once at its native kv-head width.
    hkv = cfg.num_kv_heads
    g = cfg.num_heads // hkv
    qg = q.reshape(B, 1, hkv, g, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    kp = new_cache.key_pos                  # (B, smax)
    ok = (kp >= 0) & (kp <= positions[:, None])
    if cfg.sliding_window is not None:
        ok &= kp > (positions[:, None] - cfg.sliding_window)
    bias = jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]  # (B,1,1,1,T)

    kc = new_cache.k.astype(x.dtype)
    vc = new_cache.v.astype(x.dtype)
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    out = o @ params["wo"].astype(x.dtype)
    return shard_logical(out, ("batch", "seq", "embed")), new_cache
