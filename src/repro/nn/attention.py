"""Multi-head attention: MHA/GQA/MQA, qk-norm, QKV bias, sliding window,
RoPE, flash or naive computation, and a position-explicit KV cache that
uniformly supports full caches and SWA rolling buffers.

Sharding scheme (DESIGN.md §3/§4): Q projection is head-sharded over the
"model" axis (Megatron column-parallel); K/V projections are replicated over
heads (GQA kv-head counts rarely divide the TP degree — replicating the small
KV computation beats 4x pad-waste); the output projection is row-parallel
(one psum per block, inserted by XLA from the sharding constraints).  KV
*caches* are sequence-sharded over the model axis for decode (context
parallelism — softmax stats are the only cross-shard collective).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.nn import layers as L
from repro.nn.flash import NEG_INF, causal_bias, flash_attention, full_bias
from repro.parallel.sharding import shard_logical


def attention_spec(cfg: ModelConfig):
    """Projections are stored 2-D flat: (d, Hq*hd) shards evenly over the
    model axis even when the head COUNT does not divide TP (qwen2's 28
    heads on a 16-way axis); the per-head (B, S, H, hd) view only exists as
    an intermediate, where GSPMD tolerates uneven (padded) sharding."""
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    spec = {
        "wq": Spec((d, hq * hd), ("embed", "heads_flat")),
        "wk": Spec((d, hkv * hd), ("embed", None)),
        "wv": Spec((d, hkv * hd), ("embed", None)),
        "wo": Spec((hq * hd, d), ("heads_flat", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = Spec((hq * hd,), ("heads_flat",), init="zeros")
        spec["bk"] = Spec((hkv * hd,), (None,), init="zeros")
        spec["bv"] = Spec((hkv * hd,), (None,), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = L.rmsnorm_spec(hd, axis="head_dim")
        spec["k_norm"] = L.rmsnorm_spec(hd, axis="head_dim")
    return spec


class KVCache(NamedTuple):
    """k/v: (B, S_max, H_kv, D).  key_pos: (B, S_max) int32, -1 = empty.

    For full attention, slot i holds position i.  For sliding-window
    attention the cache is a rolling buffer: position p lives in slot
    p % S_max, and `key_pos` disambiguates stale entries — one mask rule
    covers both layouts.
    """
    k: jax.Array
    v: jax.Array
    key_pos: jax.Array

    @staticmethod
    def init(batch: int, max_len: int, n_kv: int, head_dim: int, dtype):
        return KVCache(
            k=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            v=jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
            key_pos=jnp.full((batch, max_len), -1, jnp.int32),
        )


class PagedKVCache(NamedTuple):
    """Block-paged KV pool (DESIGN.md §5): k/v split into fixed-size
    pages shared by EVERY sequence; a per-request block table maps
    logical page j of the sequence to a physical page.

    k/v: (P, page_size, H_kv, D).  Logical token t of a sequence lives in
    slot t % page_size of physical page block_table[t // page_size]; the
    attention mask is purely positional (kpos <= query position), so no
    per-slot key_pos bookkeeping is needed — unwritten or stale slots are
    never inside the mask.

    Physical page 0 is reserved as the TRASH page: writes from inactive
    batch slots and masked-off padding land there and nothing ever reads
    it back (the allocator never hands page 0 to a request).
    """
    k: jax.Array
    v: jax.Array

    @staticmethod
    def init(num_pages: int, page_size: int, n_kv: int, head_dim: int,
             dtype):
        return PagedKVCache(
            k=jnp.zeros((num_pages, page_size, n_kv, head_dim), dtype),
            v=jnp.zeros((num_pages, page_size, n_kv, head_dim), dtype),
        )


def ring_shape(cfg: ModelConfig, page_size: int) -> int:
    """Ring length R for a sliding-window sequence: enough pages that the
    last W positions are always live — ceil(W / ps) full pages plus one
    page being overwritten.  (R - 1) * ps >= W guarantees the cell a new
    token lands in never still holds a key inside the window."""
    return -(-cfg.sliding_window // page_size) + 1


def ring_positions(positions, page_size: int, ring: int):
    """Map absolute positions to VIRTUAL positions inside the ring so the
    ordinary `paged_write` scatter lands in ring cell
    (pos // ps) % ring, slot pos % ps — block tables of ring sequences
    are indexed by RING index, and writes wrap in place."""
    return ((positions // page_size) % ring) * page_size \
        + positions % page_size


def paged_write(pages: PagedKVCache, k, v, block_tables, positions,
                write_mask=None) -> PagedKVCache:
    """Scatter one K/V vector per row into the page pool.

    k/v: (R, H_kv, D) — R rows, each a (token, sequence) pair;
    block_tables: (R, nmax) int32; positions: (R,) int32 the token's
    logical position; write_mask: (R,) bool or None — masked-off rows
    (padding, positions past the cache capacity) are redirected to slot 0
    of the trash page instead of corrupting a live page."""
    ps = pages.k.shape[1]
    nmax = block_tables.shape[1]
    lp = jnp.clip(positions // ps, 0, nmax - 1)
    phys = jnp.take_along_axis(block_tables, lp[:, None], axis=1)[:, 0]
    slot = positions % ps
    ok = positions < nmax * ps
    if write_mask is not None:
        ok = ok & write_mask
    phys = jnp.where(ok, phys, 0)
    slot = jnp.where(ok, slot, 0)
    return PagedKVCache(
        k=pages.k.at[phys, slot].set(k.astype(pages.k.dtype)),
        v=pages.v.at[phys, slot].set(v.astype(pages.v.dtype)),
    )


def _qkv(params, x, cfg: ModelConfig, ov=None, ov_backend: str = "lax"):
    """ov: optional per-slot adapter overlay {name: {"idx", "val"}} for
    merge-free serving (DESIGN.md §5) — each batch slot's sparse delta is
    composed into the projection dot by `ops.overlay_matmul`; ov None
    compiles the identical program as before."""
    from repro.kernels.ops import overlay_matmul, weight_operand
    B, S, _ = x.shape
    dt = x.dtype
    hd = cfg.head_dim
    ov = ov or {}
    q = overlay_matmul(x, weight_operand(params["wq"], dt), ov.get("wq"),
                       backend=ov_backend)
    k = overlay_matmul(x, weight_operand(params["wk"], dt), ov.get("wk"),
                       backend=ov_backend)
    v = overlay_matmul(x, weight_operand(params["wv"], dt), ov.get("wv"),
                       backend=ov_backend)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = shard_logical(q, ("batch", "seq", "heads", "head_dim"))
    k = shard_logical(k, ("batch", "seq", None, "head_dim"))
    v = shard_logical(v, ("batch", "seq", None, "head_dim"))
    return q, k, v


def _expand_kv(k, n_heads):
    """(B, T, H_kv, D) -> (B, T, H, D) by repetition (GQA groups)."""
    reps = n_heads // k.shape[2]
    if reps == 1:
        return k
    return jnp.repeat(k, reps, axis=2)


def _naive_attention(q, k, v, bias, scale):
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attention(params, x, cfg: ModelConfig, positions: Optional[jax.Array] = None):
    """Self-attention over a full sequence (training / prefill).

    x: (B, S, d_model); positions: (S,) or None -> arange.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(params, x, cfg)
    cos, sin = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    k = _expand_kv(k, cfg.num_heads)
    v = _expand_kv(v, cfg.num_heads)
    k = shard_logical(k, ("batch", "seq", "heads", "head_dim"))
    v = shard_logical(v, ("batch", "seq", "heads", "head_dim"))
    scale = cfg.head_dim ** -0.5

    if cfg.causal:
        bias_fn = causal_bias(window=cfg.sliding_window)
    else:
        bias_fn = full_bias()

    if cfg.attn_chunk and S > cfg.attn_chunk:
        qc = min(cfg.attn_chunk, S)
        o = flash_attention(q, k, v, bias_fn, scale, qc, qc,
                            cfg.unroll_layers)
    else:
        bias = bias_fn(positions, positions)
        o = _naive_attention(q, k, v, bias, scale)
    o = shard_logical(o, ("batch", "seq", "heads", "head_dim"))
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    from repro.kernels import ops as kops
    out = kops.overlay_matmul(o, kops.weight_operand(params["wo"], x.dtype),
                              None)
    return shard_logical(out, ("batch", "seq", "embed"))


def attention_prefill(params, x, cfg: ModelConfig, cache: KVCache):
    """Prefill: same as attention() but also writes the KV cache.

    Assumes x fills positions [0, S) and S <= cache length (full attention)
    or writes the last `window` positions (SWA rolling buffer).
    """
    B, S, _ = x.shape
    positions = jnp.arange(S)
    q, k, v = _qkv(params, x, cfg)
    cos, sin = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    smax = cache.k.shape[1]
    if S >= smax:  # rolling buffer: keep the trailing window
        start = S - smax
        new_k = k[:, start:]
        new_v = v[:, start:]
        new_pos = jnp.broadcast_to(positions[start:], (B, smax))
        # rotate so that slot = pos % smax
        slots = (positions[start:] % smax).argsort()
        new_k = new_k[:, slots]
        new_v = new_v[:, slots]
        new_pos = new_pos[:, slots]
        new_cache = KVCache(new_k.astype(cache.k.dtype),
                            new_v.astype(cache.v.dtype), new_pos)
    else:
        new_cache = KVCache(
            jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)),
            jax.lax.dynamic_update_slice(
                cache.key_pos,
                jnp.broadcast_to(positions, (B, S)).astype(jnp.int32),
                (0, 0)),
        )
    new_cache = KVCache(
        shard_logical(new_cache.k, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_cache.v, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_cache.key_pos, ("batch", "cache_seq")),
    )

    ke = shard_logical(_expand_kv(k, cfg.num_heads),
                       ("batch", "seq", "heads", "head_dim"))
    ve = shard_logical(_expand_kv(v, cfg.num_heads),
                       ("batch", "seq", "heads", "head_dim"))
    scale = cfg.head_dim ** -0.5
    bias_fn = causal_bias(window=cfg.sliding_window)
    if cfg.attn_chunk and S > cfg.attn_chunk:
        qc = min(cfg.attn_chunk, S)
        o = flash_attention(q, ke, ve, bias_fn, scale, qc, qc,
                            cfg.unroll_layers)
    else:
        o = _naive_attention(q, ke, ve, bias_fn(positions, positions), scale)
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    from repro.kernels import ops as kops
    out = kops.overlay_matmul(o, kops.weight_operand(params["wo"], x.dtype),
                              None)
    return shard_logical(out, ("batch", "seq", "embed")), new_cache


def attention_prefill_paged(params, x, cfg: ModelConfig,
                            pages: PagedKVCache, block_table, *,
                            start_pos, write_upto, whole_prompt: bool,
                            ov=None, ov_backend: str = "lax"):
    """Prefill one CHUNK of one sequence through the paged KV pool.

    x: (1, C, d) — chunk tokens at absolute positions
    [start_pos, start_pos + C); block_table: (1, nmax) int32 the
    sequence's block table; `write_upto` (traced int32) caps K/V writes —
    padding rows at positions >= write_upto go to the trash page, so a
    right-padded final chunk never corrupts slots that later decode
    tokens will own.

    `whole_prompt` (STATIC) selects the attention read:
      * True  — the chunk IS the whole prompt ([0, C) covers every real
        token): queries attend only within the chunk, with literally the
        same einsum/flash code as `attention_prefill` — the paged
        monolithic prefill is bitwise-identical to the dense-cache one.
      * False — mid-stream chunk: queries attend the full logical token
        stream gathered from the pages (prefix written by earlier chunks
        or shared prefix pages + this chunk), masked causally on absolute
        positions.
    """
    from repro.kernels import ops as kops
    B, C, _ = x.shape
    assert B == 1, "chunked prefill runs one sequence at a time"
    positions = jnp.asarray(start_pos, jnp.int32) + jnp.arange(C)
    q, k, v = _qkv(params, x, cfg, ov, ov_backend)
    cos, sin = L.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    bt = jnp.broadcast_to(block_table.reshape(1, -1), (C,
                                                       block_table.size))
    ps = pages.k.shape[1]
    if cfg.sliding_window is not None:
        # ring write: scatter through virtual in-ring positions; rows
        # more than R - 1 full pages behind the last written token would
        # alias a LIVE ring cell from the right, so they are masked off
        # (they are outside the window of every later query anyway)
        R = ring_shape(cfg, ps)
        floor = jnp.maximum(
            0, ((write_upto - 1) // ps - (R - 1)) * ps)
        mask = (positions < write_upto) & (positions >= floor)
        new_pages = paged_write(pages, k[0], v[0], bt,
                                ring_positions(positions, ps, R),
                                write_mask=mask)
    else:
        new_pages = paged_write(pages, k[0], v[0], bt, positions,
                                write_mask=positions < write_upto)

    scale = cfg.head_dim ** -0.5
    if whole_prompt:
        # same read as attention_prefill: intra-chunk causal attention
        # (windowed when the config slides — identical bias math)
        ke = _expand_kv(k, cfg.num_heads)
        ve = _expand_kv(v, cfg.num_heads)
        bias_fn = causal_bias(window=cfg.sliding_window)
        if cfg.attn_chunk and C > cfg.attn_chunk:
            qc = min(cfg.attn_chunk, C)
            o = flash_attention(q, ke, ve, bias_fn, scale, qc, qc,
                                cfg.unroll_layers)
        else:
            o = _naive_attention(q, ke, ve,
                                 bias_fn(jnp.arange(C), jnp.arange(C)),
                                 scale)
    else:
        # mid-stream chunk: grouped read over the gathered logical stream
        assert cfg.sliding_window is None, \
            "chunked prefill reads a linear block table — ring sequences" \
            " prefill monolithically"
        hkv = cfg.num_kv_heads
        g = cfg.num_heads // hkv
        nmax = block_table.size
        ps = new_pages.k.shape[1]
        T = nmax * ps
        kc = new_pages.k[block_table.reshape(-1)].reshape(1, T, hkv,
                                                          cfg.head_dim)
        vc = new_pages.v[block_table.reshape(-1)].reshape(1, T, hkv,
                                                          cfg.head_dim)
        kc = kc.astype(x.dtype)
        vc = vc.astype(x.dtype)
        kp = jnp.arange(T)
        ok = kp[None, :] <= positions[:, None]              # (C, T)
        bias = jnp.where(ok, 0.0, NEG_INF)[None, None, None, :, :]
        qg = q.reshape(1, C, hkv, g, cfg.head_dim)
        s = jnp.einsum("bqhgd,bthd->bhgqt", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        s = s + bias
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        o = o.reshape(1, C, cfg.num_heads, cfg.head_dim)
    o = o.reshape(1, C, cfg.num_heads * cfg.head_dim)
    out = kops.overlay_matmul(o, kops.weight_operand(params["wo"], x.dtype),
                              (ov or {}).get("wo"), backend=ov_backend)
    return shard_logical(out, ("batch", "seq", "embed")), new_pages


def attention_decode_paged(params, x, cfg: ModelConfig,
                           pages: PagedKVCache, block_tables, positions,
                           backend: str = "auto", ov=None,
                           ov_backend: str = "lax"):
    """One-token decode through the paged KV pool.

    x: (B, 1, d); block_tables: (B, nmax) int32; positions: (B,) int32.
    Writes this token's K/V into page block_tables[b, pos // ps] slot
    pos % ps (inactive slots carry an all-zero block table and position 0,
    so their writes land in the trash page), then reads with the paged
    kernel or its lax fallback (`ops.paged_attention_decode` — the lax
    read is the grouped einsum `attention_decode` uses, bitwise-comparable
    to the dense cache)."""
    from repro.kernels import ops as kops
    B = x.shape[0]
    q, k, v = _qkv(params, x, cfg, ov, ov_backend)   # (B, 1, h, d)
    cos, sin = L.rope_angles(positions[:, None], cfg.head_dim,
                             cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    if cfg.sliding_window is not None:
        ps = pages.k.shape[1]
        R = ring_shape(cfg, ps)
        new_pages = paged_write(pages, k[:, 0], v[:, 0], block_tables,
                                ring_positions(positions, ps, R))
    else:
        R = None
        new_pages = paged_write(pages, k[:, 0], v[:, 0], block_tables,
                                positions)
    hkv = cfg.num_kv_heads
    g = cfg.num_heads // hkv
    qg = q.reshape(B, hkv, g, cfg.head_dim)
    o = kops.paged_attention_decode(qg, new_pages.k, new_pages.v,
                                    block_tables, positions,
                                    backend=backend,
                                    window=cfg.sliding_window, ring=R)
    o = o.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    out = kops.overlay_matmul(o, kops.weight_operand(params["wo"], x.dtype),
                              (ov or {}).get("wo"), backend=ov_backend)
    return shard_logical(out, ("batch", "seq", "embed")), new_pages


def attention_verify_paged(params, x, cfg: ModelConfig,
                           pages: PagedKVCache, block_tables, positions,
                           backend: str = "auto", ov=None,
                           ov_backend: str = "lax"):
    """Speculative verify through the paged KV pool: n_q consecutive
    decode tokens per sequence in ONE dispatch.

    x: (B, n_q, d) — token i of row b sits at logical position
    positions[b] + i (the current token plus the drafted tokens);
    block_tables: (B, nmax) int32; positions: (B,) int32.

    Every token's K/V is written first (same trash-page redirect as the
    one-token write — inactive slots carry an all-zero table, rows past
    the table capacity are masked), then all n_q queries read through
    `ops.paged_attention_verify` with the per-row `kpos <= pos + i`
    mask.  Writes precede reads inside the dispatch, so rejected-draft
    K/V left in the pages by an earlier verify step is always
    overwritten before any query's mask can reach it — the stale-KV
    invariant DESIGN.md §5 documents."""
    from repro.kernels import ops as kops
    B, nq, _ = x.shape
    q, k, v = _qkv(params, x, cfg, ov, ov_backend)   # (B, nq, h, d)
    posm = positions[:, None] + jnp.arange(nq, dtype=jnp.int32)[None, :]
    cos, sin = L.rope_angles(posm, cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    btr = jnp.repeat(block_tables, nq, axis=0)            # (B*nq, nmax)
    new_pages = paged_write(pages, k.reshape(B * nq, hkv, hd),
                            v.reshape(B * nq, hkv, hd), btr,
                            posm.reshape(B * nq))
    g = cfg.num_heads // hkv
    qg = q.reshape(B, nq, hkv, g, hd)
    o = kops.paged_attention_verify(qg, new_pages.k, new_pages.v,
                                    block_tables, positions,
                                    backend=backend)
    o = o.reshape(B, nq, cfg.num_heads * hd)
    out = kops.overlay_matmul(o, kops.weight_operand(params["wo"], x.dtype),
                              (ov or {}).get("wo"), backend=ov_backend)
    return shard_logical(out, ("batch", "seq", "embed")), new_pages


def attention_decode(params, x, cfg: ModelConfig, cache: KVCache,
                     positions: jax.Array):
    """One-token decode step.  x: (B, 1, d); positions: (B,) int32.

    Writes (k, v) into slot `pos % S_max` (identity for full caches sized to
    the max sequence) and attends over every cached key with
    key_pos in (pos - window, pos].
    """
    B = x.shape[0]
    smax = cache.k.shape[1]
    q, k, v = _qkv(params, x, cfg)          # (B, 1, h, d)
    cos, sin = L.rope_angles(positions[:, None], cfg.head_dim, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    slots = positions % smax                # (B,)
    barange = jnp.arange(B)
    new_k = cache.k.at[barange, slots].set(k[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[barange, slots].set(v[:, 0].astype(cache.v.dtype))
    new_pos = cache.key_pos.at[barange, slots].set(positions.astype(jnp.int32))
    new_cache = KVCache(
        shard_logical(new_k, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_v, ("batch", "cache_seq", None, "head_dim")),
        shard_logical(new_pos, ("batch", "cache_seq")),
    )

    # Grouped attention read: no GQA expansion of the cache — decode is
    # memory-bound, so the cache is read once at its native kv-head width.
    hkv = cfg.num_kv_heads
    g = cfg.num_heads // hkv
    qg = q.reshape(B, 1, hkv, g, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    kp = new_cache.key_pos                  # (B, smax)
    ok = (kp >= 0) & (kp <= positions[:, None])
    if cfg.sliding_window is not None:
        ok &= kp > (positions[:, None] - cfg.sliding_window)
    bias = jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]  # (B,1,1,1,T)

    kc = new_cache.k.astype(x.dtype)
    vc = new_cache.v.astype(x.dtype)
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(B, 1, cfg.num_heads * cfg.head_dim)
    from repro.kernels import ops as kops
    out = kops.overlay_matmul(o, kops.weight_operand(params["wo"], x.dtype),
                              None)
    return shard_logical(out, ("batch", "seq", "embed")), new_cache
