"""Minimal functional NN substrate (no flax dependency).

Modules are plain functions over *param trees* (nested dicts of jax arrays).
Each module declares a *spec tree*: nested dicts whose leaves are `Spec`s —
(shape, logical axes, initializer).  Generic helpers turn a spec tree into an
initialized param tree, an axes tree (for sharding rules) or a
ShapeDtypeStruct tree (for dry-runs that must never allocate).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple, Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple
    axes: tuple  # logical axis names, same length as shape
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Any = None  # None -> use the model-wide param dtype
    # where the (rows | cols) boundary sits among the non-stack dims when the
    # leaf is viewed as a matrix (LIFT / PEFT operate on this 2-D view)
    matrix_split: int = 1

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _init_leaf(key: jax.Array, spec: Spec, dtype) -> jax.Array:
    dt = spec.dtype or dtype
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dt)
    if spec.init == "ones":
        return jnp.ones(shape, dt)
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, shape)).astype(dt)
    if spec.init == "embed":
        return (jax.random.normal(key, shape)).astype(dt)
    if spec.init == "small":
        return (0.02 * spec.scale * jax.random.normal(key, shape)).astype(dt)
    if spec.init == "fan_in":
        # weight matrices: last axis is the output dim by convention; fan-in is
        # the product of all other dims that participate in the contraction.
        fan_in = max(1, math.prod(shape[:-1]))
        std = spec.scale / math.sqrt(fan_in)
        return (std * jax.random.normal(key, shape)).astype(dt)
    raise ValueError(f"unknown init {spec.init}")


def init_params(key: jax.Array, spec_tree, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def axes_tree(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def shape_tree(spec_tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacking dim (for scan-over-layers) to every Spec."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale,
                       s.dtype, s.matrix_split),
        spec_tree, is_leaf=is_spec)


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(int(math.prod(x.shape)) for x in leaves)


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(int(math.prod(x.shape)) * x.dtype.itemsize for x in leaves)


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
