"""Pure-JAX flash attention (online softmax, custom VJP, O(S) memory).

This is the TPU-idiomatic streaming attention the framework uses whenever the
naive (B, H, S, T) score tensor would not fit (32k prefill / 4k train shapes).
Forward saves only (q, k, v, o, lse); backward recomputes scores per KV chunk
— FlashAttention-2 dataflow expressed with lax.scan so XLA keeps the working
set in VMEM-sized tiles.

`unroll=True` replaces the scans with python loops: used by the dry-run cost
lowering so `cost_analysis()` sees every block (scan bodies are counted once
regardless of trip count — DESIGN.md §7).

Inputs are already GQA-expanded: q (B, S, H, D), k/v (B, T, H, D).
`bias_fn(qpos, kpos)` returns an additive mask block for the given position
blocks — causality / sliding windows / padding are all expressed through it.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk(x, axis, size):
    n = x.shape[axis] // size
    new_shape = x.shape[:axis] + (n, size) + x.shape[axis + 1:]
    return jnp.moveaxis(x.reshape(new_shape), axis, 0)


def _scan(f, init, xs, unroll):
    if not unroll:
        return jax.lax.scan(f, init, xs)
    carry = init
    ys = []
    n = jax.tree.leaves(xs)[0].shape[0]
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _map(f, xs, unroll):
    if not unroll:
        return jax.lax.map(f, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = [f(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
    return jax.tree.map(lambda *a: jnp.stack(a), *outs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, bias_fn, scale, q_chunk, kv_chunk, unroll=False):
    o, _ = _flash_fwd_impl(q, k, v, bias_fn, scale, q_chunk, kv_chunk, unroll)
    return o


def _flash_fwd_impl(q, k, v, bias_fn, scale, q_chunk, kv_chunk, unroll):
    B, S, H, D = q.shape
    T = k.shape[1]
    nq, nk = S // q_chunk, T // kv_chunk
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, T, q_chunk, kv_chunk)

    qc = _chunk(q, 1, q_chunk)          # (nq, B, qc, H, D)
    kc = _chunk(k, 1, kv_chunk)         # (nk, B, kc, H, D)
    vc = _chunk(v, 1, kv_chunk)

    def one_q_chunk(qi_and_q):
        qi, qb = qi_and_q                # qb: (B, qc, H, D)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj_and_kv):
            m, l, acc = carry
            kj, kb, vb = kj_and_kv
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = s + bias_fn(qpos, kpos)  # (.., q_chunk, kv_chunk) additive
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        (m, l, acc), _ = _scan(kv_step, (m0, l0, a0),
                               (jnp.arange(nk), kc, vc), unroll)
        l = jnp.maximum(l, 1e-37)
        o = acc / l.transpose(0, 2, 1)[..., None]
        lse = m + jnp.log(l)             # (B, H, qc)
        return o.astype(q.dtype), lse

    o_c, lse_c = _map(one_q_chunk, (jnp.arange(nq), qc), unroll)
    o = jnp.moveaxis(o_c, 0, 1).reshape(B, S, H, D)
    lse = jnp.moveaxis(lse_c, 0, 2).reshape(B, H, S)
    return o, lse


def _flash_fwd(q, k, v, bias_fn, scale, q_chunk, kv_chunk, unroll):
    o, lse = _flash_fwd_impl(q, k, v, bias_fn, scale, q_chunk, kv_chunk, unroll)
    return o, (q, k, v, o, lse)


def _flash_bwd(bias_fn, scale, q_chunk, kv_chunk, unroll, res, do):
    q, k, v, o, lse = res
    B, S, H, D = q.shape
    T = k.shape[1]
    nq, nk = S // q_chunk, T // kv_chunk

    qc = _chunk(q, 1, q_chunk)
    doc = _chunk(do, 1, q_chunk)
    oc = _chunk(o, 1, q_chunk)
    lsec = _chunk(lse, 2, q_chunk)      # (nq, B, H, qc)
    kc = _chunk(k, 1, kv_chunk)
    vc = _chunk(v, 1, kv_chunk)

    # delta_i = sum_d o_i * do_i  (rowwise)
    delta_c = jnp.einsum("nbqhd,nbqhd->nbhq", oc.astype(jnp.float32),
                         doc.astype(jnp.float32))

    def one_q_chunk(carry, args):
        dk_acc, dv_acc = carry          # (B, T, H, D) fp32 accumulators
        qi, qb, dob, lseb, deltab = args
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(dq_acc, kj_and_kv):
            kj, kb, vb = kj_and_kv
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = s + bias_fn(qpos, kpos)
            p = jnp.exp(s - lseb[..., None])                       # (B,H,q,k)
            dp = jnp.einsum("bqhd,bkhd->bhqk", dob, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - deltab[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd",
                                         ds.astype(kb.dtype), kb,
                                         preferred_element_type=jnp.float32)
            dk = jnp.einsum("bhqk,bqhd->bkhd", ds.astype(qb.dtype), qb,
                            preferred_element_type=jnp.float32)
            dv = jnp.einsum("bhqk,bqhd->bkhd", p.astype(dob.dtype), dob,
                            preferred_element_type=jnp.float32)
            return dq_acc, (dk, dv)

        dq0 = jnp.zeros((B, q_chunk, H, D), jnp.float32)
        dq, (dk_c, dv_c) = _scan(kv_step, dq0, (jnp.arange(nk), kc, vc),
                                 unroll)
        # accumulate into (B, T, H, D) — stacking (nq, nk, B, kc, H, D)
        # would blow activation memory up by nq (EXPERIMENTS.md §Perf)
        dk_acc = dk_acc + jnp.moveaxis(dk_c, 0, 1).reshape(B, T, H, D)
        dv_acc = dv_acc + jnp.moveaxis(dv_c, 0, 1).reshape(B, T, H, D)
        return (dk_acc, dv_acc), dq

    zkv = jnp.zeros((B, T, H, D), jnp.float32)
    (dk, dv), dq_c = _scan(one_q_chunk, (zkv, zkv),
                           (jnp.arange(nq), qc, doc, lsec, delta_c), unroll)
    dq = jnp.moveaxis(dq_c, 0, 1).reshape(B, S, H, D).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ------------------------------------------------------------- bias builders
def causal_bias(q_offset: int = 0, window: Optional[int] = None) -> Callable:
    def bias_fn(qpos, kpos):
        qp = (qpos + q_offset)[:, None]
        kp = kpos[None, :]
        ok = kp <= qp
        if window is not None:
            ok &= kp > qp - window
        return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)

    return bias_fn


def full_bias() -> Callable:
    def bias_fn(qpos, kpos):
        return jnp.zeros((qpos.shape[0], kpos.shape[0]), jnp.float32)
    return bias_fn
