from repro.nn.core import (  # noqa: F401
    Spec, axes_tree, cast_tree, init_params, param_bytes, param_count,
    shape_tree, stack_specs,
)
