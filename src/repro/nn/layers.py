"""Basic layers: norms, embeddings, RoPE, chunked cross-entropy."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.core import Spec
from repro.parallel.sharding import shard_logical


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_spec(dim: int, axis: str = "embed"):
    return {"scale": Spec((dim,), (axis,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_spec(dim: int, axis: str = "embed"):
    return {"scale": Spec((dim,), (axis,), init="ones"),
            "bias": Spec((dim,), (axis,), init="zeros")}


def layernorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- Embedding
def embedding_spec(vocab: int, dim: int):
    return {"table": Spec((vocab, dim), ("vocab", "embed"), init="small")}


def embed(params, tokens, scale: Optional[float] = None, compute_dtype=None):
    table = params["table"]
    if compute_dtype is not None:
        table = table.astype(compute_dtype)
    x = jnp.take(table, tokens, axis=0)
    if scale is not None:
        x = x * jnp.asarray(scale, x.dtype)
    return shard_logical(x, ("batch", "seq", "embed"))


def lm_head_spec(dim: int, vocab: int):
    return {"w": Spec((dim, vocab), ("embed", "vocab"), init="fan_in")}


# ---------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions: (..., S) int -> (cos, sin) with shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) or (S, D//2). LLaMA half-split."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos_ = cos[None, :, None, :]
        sin_ = sin[None, :, None, :]
    else:  # (B, S, half)
        cos_ = cos[:, :, None, :]
        sin_ = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos_ - x2 * sin_, x2 * cos_ + x1 * sin_], axis=-1)
    return out.astype(dt)


# ------------------------------------------------- chunked cross-entropy
def _ce_of_logits(logits, labels, weights):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * weights
    return jnp.sum(nll), jnp.sum(weights)


def cross_entropy(h, w_head, labels, weights=None, chunk: int = 0,
                  unroll: bool = False):
    """Mean CE of h @ w_head vs labels.

    h: (B, S, D); w_head: (D, V); labels: (B, S) int32;
    weights: (B, S) loss mask (defaults to all-ones).
    chunk > 0 streams the sequence dim so the full (B, S, V) logits tensor is
    never materialized (crucial for 150k-vocab models at 4k sequence).
    unroll=True replaces the scan with a python loop (dry-run cost mode).
    """
    B, S, D = h.shape
    if weights is None:
        weights = jnp.ones((B, S), jnp.float32)
    weights = weights.astype(jnp.float32)
    if chunk <= 0 or S <= chunk:
        logits = (h @ w_head.astype(h.dtype))
        logits = shard_logical(logits, ("batch", "seq", "vocab"))
        total, denom = _ce_of_logits(logits, labels, weights)
        return total / jnp.maximum(denom, 1.0)

    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    h_c = h.reshape(B, n, chunk, D).swapaxes(0, 1)
    l_c = labels.reshape(B, n, chunk).swapaxes(0, 1)
    w_c = weights.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        hc, lc, wc = xs
        logits = hc @ w_head.astype(hc.dtype)
        logits = shard_logical(logits, ("batch", "seq", "vocab"))
        t, d = _ce_of_logits(logits, lc, wc)
        return (carry[0] + t, carry[1] + d), None

    if unroll:
        carry = (0.0, 0.0)
        for i in range(n):
            carry, _ = body(carry, (h_c[i], l_c[i], w_c[i]))
        total, denom = carry
    else:
        (total, denom), _ = jax.lax.scan(body, (0.0, 0.0), (h_c, l_c, w_c))
    return total / jnp.maximum(denom, 1.0)
