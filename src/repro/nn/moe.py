"""Mixture-of-Experts block: top-k router, sort-based capacity dispatch,
expert-parallel over the "model" mesh axis, GROUPED dispatch over the
"data" axis.

Dispatch is the sort/compaction formulation (no one-hot matmuls).  The
token set is split into G groups that align with the data-parallel shards
(cfg.moe_groups == mesh data size in production, 1 on a laptop).  Each group
sorts ITS tokens and fills per-(group, expert) capacity buffers — so the
sort, capacity logic and gathers are shard-LOCAL, matching how real MoE
systems give every data shard its own capacity.  The (G, E, C, d) dispatch
buffer shards as (data, model, -, -); the only cross-shard traffic is the
combine reduction over the sharded expert dim (one activation-sized psum
per layer).  Without the grouping the capacity dim replicates across the
data axis — a silent DPx expert-FLOP blowup (EXPERIMENTS.md §Perf cell C).

The Switch-style auxiliary load-balancing loss is returned so train_step
can add `router_aux_coef * aux`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.nn.mlp import _ACTS
from repro.parallel.sharding import shard_logical


def moe_spec(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": Spec((d, e), ("embed", None)),
        "gate": Spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "up": Spec((e, d, f), ("experts", "embed", "expert_mlp")),
        "down": Spec((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.num_experts_per_tok
            / cfg.num_experts)
    return max(8, ((c + 127) // 128) * 128 if c > 128 else c)


def moe(params, x, cfg: ModelConfig):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    act = _ACTS[cfg.mlp_act]
    dt = x.dtype
    T = B * S

    # dispatch groups: align with the data shards; degrade gracefully
    G = max(1, min(cfg.moe_groups, T))
    while T % G:
        G //= 2
    Tg = T // G

    xt = x.reshape(G, Tg, d)
    xt = shard_logical(xt, ("capacity", None, "embed"))

    logits = (xt @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # (G, Tg, E)
    top_p, top_e = jax.lax.top_k(probs, K)                  # (G, Tg, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss (global)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- per-group sort-based dispatch -----------------------------------
    C = _capacity(cfg, Tg)
    flat_e = top_e.reshape(G, Tg * K)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), K)[None], (G, Tg * K))
    flat_p = top_p.reshape(G, Tg * K)

    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sp = jnp.take_along_axis(flat_p, order, axis=1)

    ar = jnp.arange(Tg * K)[None]
    start_of_expert = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(se)
    pos_in_e = ar - jnp.take_along_axis(start_of_expert, se, axis=1)
    keep = pos_in_e < C

    # scatter pairs into per-group buffers (dropped pairs go out of range)
    goff = (jnp.arange(G) * (E * C))[:, None]
    slot = jnp.where(keep, se * C + pos_in_e, G * E * C) + goff
    slot = jnp.where(keep, slot, G * E * C)
    buf_tok = jnp.zeros((G * E * C,), jnp.int32).at[slot.reshape(-1)].set(
        st.reshape(-1).astype(jnp.int32), mode="drop")
    buf_w = jnp.zeros((G * E * C,), jnp.float32).at[slot.reshape(-1)].set(
        sp.reshape(-1), mode="drop")
    buf_tok = buf_tok.reshape(G, E, C)
    buf_w = buf_w.reshape(G, E, C)

    xe = jnp.take_along_axis(
        xt, buf_tok.reshape(G, E * C)[..., None], axis=1).reshape(G, E, C, d)
    xe = xe * (buf_w[..., None] > 0)
    xe = shard_logical(xe, ("capacity", "experts", None, "embed"))

    # ---- expert computation (E over "model", G over "data") -------------
    g = jnp.einsum("gecd,edf->gecf", xe, params["gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xe, params["up"].astype(dt))
    h = act(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, params["down"].astype(dt))
    ye = shard_logical(ye, ("capacity", "experts", None, "embed"))

    # ---- combine: scatter-add back per group (psum over the sharded E) --
    ye_w = ye * buf_w[..., None].astype(dt)
    out = jnp.zeros((G, Tg, d), dt).at[
        jnp.arange(G)[:, None], buf_tok.reshape(G, E * C)].add(
        ye_w.reshape(G, E * C, d), mode="drop")
    out = shard_logical(out, ("capacity", None, "embed"))
    out = out.reshape(B, S, d)
    return shard_logical(out, ("batch", "seq", "embed")), aux
