"""Feed-forward blocks: SwiGLU / GeGLU / plain, Megatron col+row parallel."""
from __future__ import annotations

import jax

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.parallel.sharding import shard_logical

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_spec(cfg: ModelConfig, d_ff: int = 0):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    spec = {
        "up": Spec((d, f), ("embed", "mlp")),
        "down": Spec((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_glu:
        spec["gate"] = Spec((d, f), ("embed", "mlp"))
    return spec


def mlp(params, x, cfg: ModelConfig, ov=None, ov_backend: str = "lax"):
    """ov: optional per-slot adapter overlay {name: {"idx", "val"}} for
    merge-free serving (DESIGN.md §5) — `overlay_matmul` composes each
    batch slot's sparse delta into the dot; ov None compiles the
    identical program as before.  Params leaves may be quantized-operand
    dicts (int8 base + principal overlay, DESIGN.md §12) — `weight_operand`
    passes them through and `overlay_matmul` fuses dequant + overlays."""
    from repro.kernels.ops import overlay_matmul, weight_operand
    dt = x.dtype
    act = _ACTS[cfg.mlp_act]
    ov = ov or {}
    up = overlay_matmul(x, weight_operand(params["up"], dt), ov.get("up"),
                        backend=ov_backend)
    up = shard_logical(up, ("batch", "seq", "mlp"))
    if cfg.mlp_glu:
        gate = overlay_matmul(x, weight_operand(params["gate"], dt),
                              ov.get("gate"), backend=ov_backend)
        gate = shard_logical(gate, ("batch", "seq", "mlp"))
        h = act(gate) * up
    else:
        h = act(up)
    out = overlay_matmul(h, weight_operand(params["down"], dt),
                         ov.get("down"), backend=ov_backend)
    return shard_logical(out, ("batch", "seq", "embed"))
