"""Feed-forward blocks: SwiGLU / GeGLU / plain, Megatron col+row parallel."""
from __future__ import annotations

import jax

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.parallel.sharding import shard_logical

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def mlp_spec(cfg: ModelConfig, d_ff: int = 0):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    spec = {
        "up": Spec((d, f), ("embed", "mlp")),
        "down": Spec((f, d), ("mlp", "embed")),
    }
    if cfg.mlp_glu:
        spec["gate"] = Spec((d, f), ("embed", "mlp"))
    return spec


def mlp(params, x, cfg: ModelConfig):
    dt = x.dtype
    act = _ACTS[cfg.mlp_act]
    up = x @ params["up"].astype(dt)
    up = shard_logical(up, ("batch", "seq", "mlp"))
    if cfg.mlp_glu:
        gate = x @ params["gate"].astype(dt)
        gate = shard_logical(gate, ("batch", "seq", "mlp"))
        h = act(gate) * up
    else:
        h = act(up)
    out = h @ params["down"].astype(dt)
    return shard_logical(out, ("batch", "seq", "embed"))
