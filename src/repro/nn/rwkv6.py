"""RWKV-6 "Finch" block: data-dependent decay linear attention (attention-
free), implemented in the numerically-safe chunked form.

Recurrence per head (K = V = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: K x V state)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with per-channel decay w_t in (0,1) produced from the token-shifted input via
a low-rank "decay LoRA" (the data-dependent part that distinguishes v6 from
v5).  Chunked evaluation factors exp-sums of log-decays so every exponent is
<= 0; intra-chunk uses a pairwise log-decay difference tensor, cross-chunk a
scanned (B, H, K, V) state.

Token shift (RWKV's 1-step conv) makes decode need a (B, d) "last hidden"
cache per mixer in addition to the wkv state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.nn.core import Spec
from repro.parallel.sharding import shard_logical

_STREAMS = 5  # r, k, v, w, g


def time_mix_spec(cfg: ModelConfig):
    d = cfg.d_model
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    dl, ml = cfg.rwkv_decay_lora, cfg.rwkv_mix_lora
    return {
        "mu": Spec((_STREAMS, d), (None, "embed"), init="zeros"),
        "mix_a": Spec((d, _STREAMS * ml), ("embed", None), init="small"),
        "mix_b": Spec((_STREAMS, ml, d), (None, None, "embed"), init="small"),
        "wr": Spec((d, H, K), ("embed", "heads", "head_dim")),
        "wk": Spec((d, H, K), ("embed", "heads", "head_dim")),
        "wv": Spec((d, H, K), ("embed", "heads", "head_dim")),
        "wg": Spec((d, H, K), ("embed", "heads", "head_dim")),
        "wo": Spec((H, K, d), ("heads", "head_dim", "embed"), matrix_split=2),
        "decay_a": Spec((d, dl), ("embed", None), init="small"),
        "decay_b": Spec((dl, d), (None, "embed"), init="small"),
        "decay_base": Spec((d,), ("embed",), init="zeros"),
        "bonus_u": Spec((H, K), ("heads", "head_dim"), init="zeros"),
        "ln_x": Spec((d,), ("embed",), init="ones"),
    }


def channel_mix_spec(cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": Spec((2, d), (None, "embed"), init="zeros"),  # k, r streams
        "wk": Spec((d, f), ("embed", "mlp")),
        "wv": Spec((f, d), ("mlp", "embed")),
        "wr": Spec((d, d), ("embed", "embed")),
    }


class RwkvState(NamedTuple):
    """Per-layer decode state."""
    tm_last: jax.Array   # (B, d)  last input to time-mix (token shift)
    cm_last: jax.Array   # (B, d)  last input to channel-mix
    wkv: jax.Array       # (B, H, K, V) linear-attention state (fp32)

    @staticmethod
    def init(batch: int, cfg: ModelConfig, dtype):
        H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
        return RwkvState(
            tm_last=jnp.zeros((batch, cfg.d_model), dtype),
            cm_last=jnp.zeros((batch, cfg.d_model), dtype),
            wkv=jnp.zeros((batch, H, K, K), jnp.float32),
        )


def state_nbytes(cfg: ModelConfig, dtype) -> int:
    """Device bytes of ONE sequence's full-stack recurrent state (all
    `num_layers` RwkvStates at batch 1) — what the serving engine
    charges to the page pool as a state slab, computed from shapes
    without materializing arrays."""
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    item = jnp.dtype(dtype).itemsize
    per_layer = 2 * cfg.d_model * item + H * K * K * 4   # wkv is fp32
    return cfg.num_layers * per_layer


def _ddlerp(params, x, prev):
    """Data-dependent lerp between x and prev -> the 5 streams (5, B, S, d)."""
    dt = x.dtype
    delta = prev - x
    base = x[None] + delta[None] * params["mu"].astype(dt)[:, None, None, :]
    ml = params["mix_b"].shape[1]
    lora = jnp.tanh(x @ params["mix_a"].astype(dt))               # (B,S,5*ml)
    lora = lora.reshape(*lora.shape[:-1], _STREAMS, ml)           # (B,S,5,ml)
    extra = jnp.einsum("bsnm,nmd->nbsd", lora, params["mix_b"].astype(dt))
    return base + extra * delta[None]


def _decay(params, xw):
    """Per-channel log-decay, guaranteed < 0.  xw: (B, S, d) -> fp32."""
    dt = jnp.float32
    lora = jnp.tanh(xw.astype(dt) @ params["decay_a"].astype(dt)) \
        @ params["decay_b"].astype(dt)
    raw = params["decay_base"].astype(dt) + lora
    return -jax.nn.softplus(-(raw - 0.5)) - 1e-3


def _chunked_wkv(r, k, v, lw, u, S0, chunk: int, unroll: bool = False):
    """Chunked WKV, batched formulation: the intra-chunk quadratic term is
    evaluated for ALL chunks at once (chunk index = batch dim) and the
    inter-chunk state recurrence S_k = diag(a_k) S_{k-1} + b_k is an affine
    associative scan — no while loops, exact `cost_analysis()` accounting
    (DESIGN.md §7).

    r,k,v,lw: (B, T, H, K) fp32 (lw = log-decay < 0); u: (H, K).
    S0: (B, H, K, V) initial state.  Returns (o (B,T,H,K) fp32, S_final)."""
    del unroll
    B, T, H, K = r.shape
    if T % chunk != 0:
        chunk = T  # fall back to a single chunk
    n, c = T // chunk, min(chunk, T)

    def ch(a):
        return a.reshape(B, n, c, H, K)

    rc, kc, vc, lwc = map(ch, (r, k, v, lw))
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])

    cw = jnp.cumsum(lwc, axis=2)                     # (B, n, c, H, K)
    # A[t, s, k] = exp(cw[t-1, k] - cw[s, k]) for s < t  (exponent <= 0)
    dif = cw[:, :, :, None] - lwc[:, :, :, None] - cw[:, :, None, :]
    A = jnp.exp(jnp.minimum(dif, 0.0)) \
        * mask[None, None, :, :, None, None]
    scores = jnp.einsum("bnthk,bntshk,bnshk->bnhts", rc, A, kc)
    o_intra = jnp.einsum("bnhts,bnshv->bnthv", scores, vc)
    diag = jnp.einsum("bnthk,hk,bnthk->bnth", rc, u, kc)
    o_diag = diag[..., None] * vc

    # per-chunk state contribution and decay
    k_dec = kc * jnp.exp(cw[:, :, -1:] - cw)         # k_s * exp(cw[-1]-cw[s])
    contrib = jnp.einsum("bnshk,bnshv->bnhkv", k_dec, vc)
    a = jnp.exp(cw[:, :, -1])                        # (B, n, H, K)

    a_all = jnp.concatenate([jnp.ones((B, 1, H, K), a.dtype), a], axis=1)
    b_all = jnp.concatenate([S0[:, None], contrib], axis=1)  # (B,n+1,H,K,V)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2[..., None] * b1 + b2

    _, S_all = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    S_prev = S_all[:, :-1]
    S_final = S_all[:, -1]

    r_dec = rc * jnp.exp(cw - lwc)                   # r_t * exp(cw[t-1])
    o_cross = jnp.einsum("bnthk,bnhkv->bnthv", r_dec, S_prev)
    o = (o_intra + o_diag + o_cross).reshape(B, T, H, K)
    return o, S_final


def _group_norm(o, scale, eps):
    """Per-head normalization (RWKV ln_x).  o: (B, T, H, K)."""
    mu = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mu) * jax.lax.rsqrt(var + eps)
    B, T, H, K = o.shape
    return o.reshape(B, T, H * K) * scale.astype(o.dtype)


def time_mix(params, x, cfg: ModelConfig, last=None, state=None,
             chunk: int = 0, unroll: bool = False):
    """x: (B, S, d).  last/state: decode caches (None during training).

    Returns (out (B, S, d), new_last (B, d), new_state)."""
    B, S, d = x.shape
    dt = x.dtype
    H, K = cfg.rwkv_heads, cfg.rwkv_head_dim
    if last is None:
        last = jnp.zeros((B, d), dt)
    prev = jnp.concatenate([last[:, None, :].astype(dt), x[:, :-1, :]], axis=1)
    xr, xk, xv, xw, xg = _ddlerp(params, x, prev)

    r = jnp.einsum("bsd,dhk->bshk", xr, params["wr"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xk, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xv, params["wv"].astype(dt))
    g = jnp.einsum("bsd,dhk->bshk", xg, params["wg"].astype(dt))
    r = shard_logical(r, ("batch", "seq", "heads", "head_dim"))
    k = shard_logical(k, ("batch", "seq", "heads", "head_dim"))
    v = shard_logical(v, ("batch", "seq", "heads", "head_dim"))
    lw = _decay(params, xw).reshape(B, S, H, K)       # fp32, < 0

    S0 = state if state is not None \
        else jnp.zeros((B, H, K, K), jnp.float32)
    o, S_new = _chunked_wkv(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), lw,
                            params["bonus_u"].astype(jnp.float32),
                            S0, chunk or S, unroll)
    o = _group_norm(o, params["ln_x"], cfg.norm_eps).astype(dt)
    o = o.reshape(B, S, H, K) * jax.nn.silu(g)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
    out = shard_logical(out, ("batch", "seq", "embed"))
    return out, x[:, -1, :], S_new


def channel_mix(params, x, cfg: ModelConfig, last=None):
    """RWKV channel mix (square-ReLU MLP).  Returns (out, new_last)."""
    B, S, d = x.shape
    dt = x.dtype
    if last is None:
        last = jnp.zeros((B, d), dt)
    prev = jnp.concatenate([last[:, None, :].astype(dt), x[:, :-1, :]], axis=1)
    delta = prev - x
    mu = params["mu"].astype(dt)
    xk = x + delta * mu[0]
    xr = x + delta * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(dt)))
    kk = shard_logical(kk, ("batch", "seq", "mlp"))
    vv = kk @ params["wv"].astype(dt)
    rr = jax.nn.sigmoid(xr @ params["wr"].astype(dt))
    out = shard_logical(rr * vv, ("batch", "seq", "embed"))
    return out, x[:, -1, :]
