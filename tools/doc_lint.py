#!/usr/bin/env python
"""Doc lint: dead intra-repo references in the repo's markdown (docs/CI.md).

Three checks, all conservative (a reference is only flagged when it
POSITIVELY looks intra-repo and provably resolves to nothing):

1. Backtick path references — `` `src/repro/deltas/format.py` ``,
   `` `kvpool/pool.py` ``, `` `core/selection.py::SelectionEngine` ``.
   A candidate is checked only when its first path segment is a
   directory that actually exists in the tree (or the whole token is a
   tracked root-level file); it resolves if some tracked path ends with
   it.  Everything else — external paths, module dotted names, flags,
   globs, generated `BENCH_*.json` artifacts — is skipped, never
   guessed at.
2. `DESIGN.md §N` citations (and bare `§N` inside DESIGN.md itself)
   must point at a section number DESIGN.md defines (`## §N` headings).
3. Markdown links `[text](target)` with a relative target must point at
   an existing file/directory, and a `#fragment` on a markdown target
   must match a heading anchor in that file (GitHub slugging).  http(s)
   links are never fetched.

Exit 0 = clean; exit 1 prints `file:line: message` per dead reference.
Driver-owned retrieval docs (PAPER/PAPERS/SNIPPETS/ISSUE) quote other
repos' paths by design and are excluded, as is `.claude/`.

Usage: python tools/doc_lint.py [--root DIR] [FILES...]
Runs in CI's lint job (blocking) and in tier 1 via
tests/test_doc_lint.py.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

EXCLUDE = {"PAPER.md", "PAPERS.md", "SNIPPETS.md", "ISSUE.md"}
EXCLUDE_DIRS = (".claude/", ".git/")

BACKTICK = re.compile(r"`([^`\s]+)`")
SECTION_CITE = re.compile(r"DESIGN\.md §(\d+)")
BARE_SECTION = re.compile(r"§(\d+)")
SECTION_DEF = re.compile(r"^## §(\d+)\b", re.M)
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.M)

# characters that mark a token as a pattern/placeholder, not a path
NON_PATH = set("<>{}*$|\\\"'")


def tracked_files(root: str) -> list[str]:
    """Tracked + untracked-unignored files, '/'-separated, repo-relative."""
    try:
        out = subprocess.run(
            ["git", "ls-files", "--cached", "--others", "--exclude-standard"],
            cwd=root, capture_output=True, text=True, check=True).stdout
        files = [l for l in out.splitlines() if l]
    except (OSError, subprocess.CalledProcessError):
        files = []
        for dirpath, dirnames, filenames in os.walk(root):
            rel = os.path.relpath(dirpath, root).replace(os.sep, "/")
            rel = "" if rel == "." else rel + "/"
            dirnames[:] = [d for d in dirnames if d != ".git"]
            files.extend(rel + f for f in filenames)
    return [f for f in files if not f.startswith(EXCLUDE_DIRS)]


def _strip(token: str) -> str:
    """Drop `::member` / `:line` / `:func` suffixes and punctuation."""
    token = token.split(":")[0]
    return token.rstrip(".,;:!?)")


class Repo:
    def __init__(self, root: str, files: list[str]):
        self.root = root
        self.files = files
        self.file_set = set(files)
        # every directory name appearing anywhere in the tree: the
        # "looks intra-repo" signal for multi-segment candidates
        self.dir_names: set[str] = set()
        self.dirs: set[str] = set()
        for f in files:
            parts = f.split("/")[:-1]
            self.dir_names.update(parts)
            for i in range(1, len(parts) + 1):
                self.dirs.add("/".join(parts[:i]))
        self.root_files = {f for f in files if "/" not in f}

    def resolves(self, cand: str) -> bool:
        if cand.endswith("/"):
            d = cand.rstrip("/")
            return any(p == d or p.endswith("/" + d) for p in self.dirs)
        if cand in self.file_set:
            return True
        suffix = "/" + cand
        if any(p.endswith(suffix) for p in self.files):
            return True
        # `benchmarks/common.write_bench_json`-style module members:
        # peel trailing `.attr` pieces and retry with a `.py` suffix
        while "." in cand.rsplit("/", 1)[-1]:
            cand = cand.rsplit(".", 1)[0]
            for probe in (cand, cand + ".py"):
                if probe in self.file_set or any(
                        p.endswith("/" + probe) for p in self.files):
                    return True
        return False

    def check_token(self, token: str):
        """Error string for a dead intra-repo path, else None."""
        cand = _strip(token)
        if (not cand or NON_PATH & set(cand) or cand.startswith(("/", "-"))
                or "//" in cand or cand.startswith(("http:", "https:"))):
            return None
        if "/" not in cand:
            # single segment: only root-level docs are checkable; a
            # bare name that isn't one could be anything — skip
            if cand in self.root_files:
                return None
            if re.fullmatch(r"[A-Z]+[A-Z_]*\.md", cand) and \
                    cand not in EXCLUDE:
                return f"dead root doc reference `{cand}`"
            return None
        first = cand.split("/")[0]
        if first not in self.dir_names and first not in self.dirs:
            return None  # not a directory this repo has — external
        if not self.resolves(cand):
            return f"dead intra-repo path `{cand}`"
        return None


def _anchor(heading: str) -> str:
    """GitHub-style heading slug."""
    h = re.sub(r"`([^`]*)`", r"\1", heading).strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(text: str) -> set[str]:
    return {_anchor(m.group(1)) for m in HEADING.finditer(text)}


def lint_file(repo: Repo, path: str, text: str,
              sections: set[str]) -> list[str]:
    errs = []
    lines = text.splitlines()
    is_design = os.path.basename(path) == "DESIGN.md"
    own_anchors = _anchors(text)
    for ln, line in enumerate(lines, 1):
        for m in BACKTICK.finditer(line):
            err = repo.check_token(m.group(1))
            if err:
                errs.append(f"{path}:{ln}: {err}")
        cite = SECTION_CITE if not is_design else BARE_SECTION
        for m in cite.finditer(line):
            if m.group(1) not in sections:
                errs.append(f"{path}:{ln}: citation §{m.group(1)} — "
                            f"DESIGN.md defines no such section "
                            f"(have §{', §'.join(sorted(sections, key=int))})")
        for m in MD_LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http:", "https:", "mailto:")):
                continue
            base, _, frag = target.partition("#")
            if base:
                rel = os.path.normpath(os.path.join(
                    os.path.dirname(path), base)).replace(os.sep, "/")
                if rel not in repo.file_set and rel not in repo.dirs:
                    errs.append(f"{path}:{ln}: broken link target "
                                f"`{target}` ({rel} does not exist)")
                    continue
            if frag:
                if base:
                    if not base.endswith(".md"):
                        continue
                    with open(os.path.join(repo.root, rel)) as f:
                        anchors = _anchors(f.read())
                else:
                    anchors = own_anchors
                if frag.lower() not in anchors:
                    errs.append(f"{path}:{ln}: broken anchor "
                                f"`#{frag}` in link `{target}`")
    return errs


def lint_repo(root: str, only: list[str] | None = None) -> list[str]:
    files = tracked_files(root)
    repo = Repo(root, files)
    design = os.path.join(root, "DESIGN.md")
    sections: set[str] = set()
    if os.path.exists(design):
        with open(design) as f:
            sections = set(SECTION_DEF.findall(f.read()))
    targets = only if only is not None else [
        f for f in files
        if f.endswith(".md") and os.path.basename(f) not in EXCLUDE]
    errs = []
    for f in sorted(targets):
        with open(os.path.join(root, f)) as fh:
            errs.extend(lint_file(repo, f, fh.read(), sections))
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on dead intra-repo paths and broken §/anchor "
                    "references in the repo's markdown")
    ap.add_argument("files", nargs="*",
                    help="specific .md files (default: every tracked one)")
    ap.add_argument("--root", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))) or ".")
    args = ap.parse_args(argv)
    errs = lint_repo(args.root, args.files or None)
    for e in errs:
        print(e, file=sys.stderr)
    if not errs:
        print(f"doc-lint: OK")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
