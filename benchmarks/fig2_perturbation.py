"""Fig. 2 analog: perturb weights selected by LIFT vs magnitude vs random
with N(0, 0.01^2..0.05^2) noise; Principal Weights should be by far the
most fragile.  derived = loss(perturbed) - loss(clean) per selection."""
import jax
import jax.numpy as jnp

from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.core.analysis import perturb_at_indices
from repro.core.lift import LiftConfig, compute_indices, make_plan
from repro.data.synthetic import generate


def run():
    out = train_method(SMALL, make_method("full"), task="lm", steps=60,
                       eval_n=0)
    model, params = out["model"], out["params"]
    data = generate("lm", 64, 48, seed=5)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    base = float(model.loss(params, batch)[0])

    rows = []
    for sel in ["lift", "magnitude", "random"]:
        lcfg = LiftConfig(rank=8, match_rank=2, method="exact",
                          selection=sel, min_dim=16)
        plan = make_plan(model.spec(), lcfg)
        idx = compute_indices(params, plan, lcfg, jax.random.PRNGKey(3))
        deltas = []
        for scale in (0.01, 0.03, 0.05):
            pert = perturb_at_indices(params, idx, plan, scale,
                                      jax.random.PRNGKey(7))
            deltas.append(float(model.loss(pert, batch)[0]) - base)
        rows.append({
            "name": f"fig2/perturb-{sel}",
            "us_per_call": 0.0,
            "derived": "dloss@.01/.03/.05=" + "/".join(
                f"{d:.3f}" for d in deltas),
        })
    return rows


if __name__ == "__main__":
    csv_rows(run())
