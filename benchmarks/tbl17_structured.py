"""App. G.7 analog: structured 4x4-block LIFT vs unstructured LIFT vs
top-k magnitude at equal budget.  derived = eval accuracy."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method


def run():
    rows = []
    cases = [("lift", dict()), ("lift-4x4", dict(block_size=4)),
             ("magnitude", dict())]
    for tag, extra in cases:
        kind = "magnitude" if tag == "magnitude" else "lift"
        out = train_method(SMALL, make_method(kind, **extra), task="arith",
                           steps=120, refresh_every=25, seed=3)
        rows.append({"name": f"tbl17/{tag}",
                     "us_per_call": out["us_per_step"],
                     "derived": f"acc={out['eval_acc']:.3f}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
