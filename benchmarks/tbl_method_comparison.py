"""Tables 1 & 2 analog: LIFT vs Full FT / LoRA / PiSSA / DoRA / magnitude
sparse-FT on the synthetic reasoning SFT task (reduced scale).
derived = eval accuracy (paper's finding: LIFT >= Full FT > adapters)."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method

METHODS = ["full", "lift", "lora", "pissa", "dora", "magnitude"]


def run():
    rows = []
    for kind in METHODS:
        out = train_method(SMALL, make_method(kind), task="arith",
                           steps=150, refresh_every=25)
        rows.append({
            "name": f"tbl12/{kind}",
            "us_per_call": out["us_per_step"],
            "derived": f"acc={out['eval_acc']:.3f};"
                       f"loss={out['train_loss']:.3f}",
        })
    return rows


if __name__ == "__main__":
    csv_rows(run())
