"""PagedKV serving benchmarks (DESIGN.md §5) — BENCH_paged_decode.json.

A mixed-prompt-length request stream (the workload paging exists for:
short and long prompts sharing one batch) served three ways — the
dense-cache engine, the paged engine with monolithic prefill, and the
paged engine with chunked prefill interleaving — with:

  * a MEASURED token-identity bit per paged run (`matches_dense`): the
    paged engine must reproduce the dense engine's token streams exactly
    (greedy) — the CI-gated invariant;
  * decode throughput (tokens/s) for each engine (interpret-mode wall
    time: regression tracking only, never gated) and the paged/dense
    speedup at the measured concurrency;
  * the KV-memory story (`kvbytes/` rows, CI-gated): peak resident paged
    KV bytes vs the dense engine's slots x max_len allocation
    (`kv_bytes_ratio` < 1) and vs the live-token bound
    (`within_live_bound` — pool bytes track live tokens plus page
    rounding, never the worst case).

Machine-readable output: `python -m benchmarks.paged_decode --json
BENCH_paged_decode.json` (schema: benchmarks/bench_schema.py).
"""
import argparse
import time

import numpy as np

import jax

from benchmarks.common import SMALL, csv_rows, write_bench_json
from repro.models import build_model
from repro.serving.engine import Engine, EngineConfig, Request
from repro.serving.kvpool import PagedEngine, PagedEngineConfig

SLOTS = 8
REQUESTS = 12
MAX_LEN = 128
MAX_NEW = 16
PAGE_SIZE = 16
NUM_PAGES = 48


def _prompts(n, seed=7, lo=4, hi=60):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve(eng, prompts):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = {r.uid: tuple(r.out_tokens) for r in done}
    return toks, sum(len(t) for t in toks.values()), dt


def run():
    model = build_model(SMALL)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(REQUESTS)

    def dense():
        return Engine(model, params, EngineConfig(
            batch_slots=SLOTS, max_len=MAX_LEN, eos_id=2))

    def paged(chunked):
        return PagedEngine(model, params, PagedEngineConfig(
            batch_slots=SLOTS, max_len=MAX_LEN, eos_id=2,
            page_size=PAGE_SIZE, num_pages=NUM_PAGES,
            chunked_prefill=chunked))

    # serve each engine twice: the first pass takes the compiles (jit
    # caches live per engine instance), the second is the measured wall
    eng_d = dense()
    _serve(eng_d, prompts)
    want, n_dense, dt_dense = _serve(eng_d, prompts)
    eng_p = paged(False)
    _serve(eng_p, prompts)
    got_p, n_paged, dt_paged = _serve(eng_p, prompts)
    eng_c = paged(True)
    _serve(eng_c, prompts)
    eng_c.prefill_chunks = 0            # count the measured pass only
    got_c, n_chunk, dt_chunk = _serve(eng_c, prompts)

    name = f"mixed-{SLOTS}req"
    tok_s_dense = n_dense / max(dt_dense, 1e-9)
    tok_s_paged = n_paged / max(dt_paged, 1e-9)
    tok_s_chunk = n_chunk / max(dt_chunk, 1e-9)
    st = eng_p.kv_stats()
    rows = [
        {"name": f"decode/{name}-paged",
         "us_per_call": dt_paged * 1e6,
         "derived": f"matches_dense={want == got_p};"
                    f"tok_s={tok_s_paged:.1f};"
                    f"tok_s_dense={tok_s_dense:.1f}",
         "metrics": {"matches_dense": bool(want == got_p),
                     "tok_s": tok_s_paged, "tok_s_dense": tok_s_dense,
                     "speedup_vs_dense": tok_s_paged / tok_s_dense,
                     "concurrency": SLOTS, "requests": REQUESTS}},
        {"name": f"decode/{name}-chunked",
         "us_per_call": dt_chunk * 1e6,
         "derived": f"matches_dense={want == got_c};"
                    f"tok_s={tok_s_chunk:.1f};"
                    f"chunks={eng_c.prefill_chunks}",
         "metrics": {"matches_dense": bool(want == got_c),
                     "tok_s": tok_s_chunk,
                     "speedup_vs_dense": tok_s_chunk / tok_s_dense,
                     "prefill_chunks": eng_c.prefill_chunks,
                     "prefill_compilations": eng_c.prefill_compilations,
                     "concurrency": SLOTS, "requests": REQUESTS}},
        {"name": f"kvbytes/{name}",
         "us_per_call": 0.0,
         "derived": f"kv_bytes_ratio={st['kv_bytes_ratio']:.4f};"
                    f"peak_pages={st['peak_pages_in_use']};"
                    f"within_live_bound={st['within_live_bound']}",
         "metrics": {"kv_bytes_ratio": float(st["kv_bytes_ratio"]),
                     "peak_kv_bytes": int(st["peak_kv_bytes"]),
                     "dense_kv_bytes": int(st["dense_kv_bytes"]),
                     "peak_live_tokens": int(st["peak_live_tokens"]),
                     "within_live_bound": bool(st["within_live_bound"]),
                     "page_size": PAGE_SIZE, "num_pages": NUM_PAGES,
                     "preemptions": int(st["preemptions"])}},
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_paged_decode.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="paged_decode")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
