"""PagedKV serving benchmarks (DESIGN.md §5) — BENCH_paged_decode.json.

A mixed-prompt-length request stream (the workload paging exists for:
short and long prompts sharing one batch) served three ways — the
dense-cache engine, the paged engine with monolithic prefill, and the
paged engine with chunked prefill interleaving — with:

  * a MEASURED token-identity bit per paged run (`matches_dense`): the
    paged engine must reproduce the dense engine's token streams exactly
    (greedy) — the CI-gated invariant;
  * decode throughput (tokens/s) for each engine (interpret-mode wall
    time: regression tracking only, never gated) and the paged/dense
    speedup at the measured concurrency;
  * the KV-memory story (`kvbytes/` rows, CI-gated): peak resident paged
    KV bytes vs the dense engine's slots x max_len allocation
    (`kv_bytes_ratio` < 1) and vs the live-token bound
    (`within_live_bound` — pool bytes track live tokens plus page
    rounding, never the worst case);
  * speculative multi-token decode (`speculative/` rows, CI-gated): the
    paged engine drafting `draft_len` tokens per dispatch (n-gram
    prompt-lookup drafter) must STILL match the dense streams bitwise
    (`matches_dense`), advance more than one token per sequence-dispatch
    (`effective_tokens_per_step` > 1 — accept_rate x draft_len paying
    off), and compile exactly ONE decode program
    (`decode_compilations` == 1); `tok_s_ratio` vs the one-token paged
    engine is reported (and baseline-tracked) but not schema-gated —
    interpret-mode wall time is noise;
  * a memory-bound roofline row (`roofline/`): attainable tok/s from
    `repro.launch.roofline.paged_decode_roofline` at the measured
    accept rate and page size, next to the measured tok/s — plus a
    report-only `roofline/*-int8` variant modeling the int8 base +
    principal-overlay weight stream (DESIGN.md §12; never gated
    against a measurement);
  * an observability-overhead row (`obs/`, CI-gated): the same paged
    config served fully instrumented (span tracing + compile
    fingerprinting on, docs/OBSERVABILITY.md) vs fully disabled
    (`ObsContext.disabled()` — `instrument_jit` returns the raw jitted
    callable); the instrumented arm must keep `obs_tok_s_ratio` >= 0.97
    and stay token-identical to the dense streams.

Machine-readable output: `python -m benchmarks.paged_decode --json
BENCH_paged_decode.json` (schema: benchmarks/bench_schema.py).
"""
import argparse
import time

import numpy as np

import jax

from benchmarks.common import SMALL, csv_rows, write_bench_json
from repro import obs as obs_lib
from repro.models import build_model
from repro.serving import Request, ServingConfig, make_engine
from repro.serving.oracle import DenseOracle

SLOTS = 8
REQUESTS = 12
MAX_LEN = 128
MAX_NEW = 32         # long enough decode for drafting to amortize
PAGE_SIZE = 16
NUM_PAGES = 56
DRAFT_LEN = 2        # short drafts win at this mix: per-draft acceptance
                     # falls with depth while verify width cost grows
REPS = 3             # interleaved measured passes; tok/s is the median
OBS_REPS = 5         # obs-overhead passes: step-locked A/B gives
                     # ~hundreds of per-step pairs for the gated median


def _prompts(n, seed=7, lo=4, hi=60):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve(eng, prompts):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = {r.uid: tuple(r.out_tokens) for r in done}
    return toks, sum(len(t) for t in toks.values()), dt


def run():
    model = build_model(SMALL)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(REQUESTS)

    def dense():
        return DenseOracle(model, params, ServingConfig(
            batch_slots=SLOTS, max_len=MAX_LEN, eos_id=2))

    def paged(chunked, speculate=0, obs=None):
        return make_engine(model, params, ServingConfig(
            batch_slots=SLOTS, max_len=MAX_LEN, eos_id=2,
            page_size=PAGE_SIZE, num_pages=NUM_PAGES,
            chunked_prefill=chunked, speculate=speculate,
            draft_source="ngram"), obs=obs)

    # serve each engine once to take the compiles (jit caches live per
    # engine instance), then REPS interleaved measured passes — round-
    # robin across engines so CPU-frequency/contention drift is shared,
    # with the per-engine tok/s taken as the median pass
    eng_d, eng_p = dense(), paged(False)
    eng_c, eng_s = paged(True), paged(False, speculate=DRAFT_LEN)
    for eng in (eng_d, eng_p, eng_c, eng_s):
        _serve(eng, prompts)
    # count the measured passes only (decode_compilations stays
    # cumulative: the speculative path compiles exactly ONE program EVER)
    eng_c.prefill_chunks = 0
    eng_s.spec_drafted = eng_s.spec_accepted = 0
    eng_s.spec_emitted = eng_s.spec_slot_steps = 0
    eng_s.decode_steps = 0
    runs = {id(eng): [] for eng in (eng_d, eng_p, eng_c, eng_s)}
    for _ in range(REPS):
        for eng in (eng_d, eng_p, eng_c, eng_s):
            runs[id(eng)].append(_serve(eng, prompts))
    want, n_dense, dt_dense = runs[id(eng_d)][0]
    got_p, n_paged, dt_paged = runs[id(eng_p)][0]
    got_c, n_chunk, dt_chunk = runs[id(eng_c)][0]
    got_s, n_spec, dt_spec = runs[id(eng_s)][0]
    sp = eng_s.spec_stats()

    def _tok_s(eng):
        return float(np.median([n / max(dt, 1e-9)
                                for _, n, dt in runs[id(eng)]]))

    name = f"mixed-{SLOTS}req"
    tok_s_dense = _tok_s(eng_d)
    tok_s_paged = _tok_s(eng_p)
    tok_s_chunk = _tok_s(eng_c)
    tok_s_spec = _tok_s(eng_s)
    st = eng_p.kv_stats()

    # token identity must hold on EVERY measured pass, not just one
    def _matches(eng):
        return all(got == want for got, _, _ in runs[id(eng)])

    # observability overhead (docs/OBSERVABILITY.md): the same paged
    # config with everything on (span tracing + compile fingerprinting)
    # vs ObsContext.disabled() (instrument_jit hands back the raw jitted
    # callable) — interleaved passes, median tok/s each, gated ratio
    obs_on = obs_lib.ObsContext.fresh(trace=True)
    eng_i = paged(False, obs=obs_on)
    eng_u = paged(False, obs=obs_lib.ObsContext.disabled())
    got_i, n_tok_i, dt_instr = _serve(eng_i, prompts)   # compile pass
    got_u, _, _ = _serve(eng_u, prompts)
    # step-LOCKED measured passes: both arms run the same deterministic
    # schedule, so step k is the same work in each — alternating single
    # steps pairs them ~1ms apart and the median per-step-pair ratio
    # cancels the CPU-drift/GC/OS hiccups that swamp whole-pass wall
    # time (a 1-2% per-step effect is unmeasurable at +-10% pass noise)
    pc = time.perf_counter
    ti, tu = [], []
    flip = False
    for _ in range(OBS_REPS):
        for i, p in enumerate(prompts):
            eng_i.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
            eng_u.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW))
        while eng_i.sched.has_work() or eng_u.sched.has_work():
            # alternate which arm steps first: going second in a pair is
            # measurably cheaper (warmed caches), so a fixed order would
            # bias the ratio by more than the effect being gated
            order = (((eng_u, tu), (eng_i, ti)) if flip
                     else ((eng_i, ti), (eng_u, tu)))
            flip = not flip
            for eng, acc in order:
                if eng.sched.has_work():
                    t0 = pc()
                    eng.step()
                    acc.append(pc() - t0)
    n_steps = min(len(ti), len(tu))
    obs_ratio = float(np.median([u / i for i, u
                                 in zip(ti[:n_steps], tu[:n_steps])]))
    tok_s_instr = n_tok_i * OBS_REPS / max(sum(ti), 1e-9)
    tok_s_plain = n_tok_i * OBS_REPS / max(sum(tu), 1e-9)
    obs_matches = got_i == want and got_u == want and \
        {r.uid: tuple(r.out_tokens) for r in eng_i.done} == want
    n_spans = len(obs_on.tracer.spans)

    from repro.launch.roofline import paged_decode_roofline
    live = float(np.mean([len(p) for p in prompts])) + MAX_NEW / 2
    roof = paged_decode_roofline(
        SMALL, batch=SLOTS, live_tokens_per_seq=live,
        page_size=PAGE_SIZE, draft_len=DRAFT_LEN,
        accept_rate=sp["accept_rate"])
    # report-only: same roofline with the int8 base + principal overlay
    # weight-stream term (DESIGN.md §12) — the modeled headroom a
    # quantized base buys in the memory-bound decode regime; never gated
    # against a measurement (this bench serves the fp32 base)
    roof_q = paged_decode_roofline(
        SMALL, batch=SLOTS, live_tokens_per_seq=live,
        page_size=PAGE_SIZE, draft_len=DRAFT_LEN,
        accept_rate=sp["accept_rate"], quantize_base=True,
        overlay_density=0.05)
    rows = [
        {"name": f"decode/{name}-paged",
         "us_per_call": dt_paged * 1e6,
         "derived": f"matches_dense={_matches(eng_p)};"
                    f"tok_s={tok_s_paged:.1f};"
                    f"tok_s_dense={tok_s_dense:.1f}",
         "metrics": {"matches_dense": bool(_matches(eng_p)),
                     "tok_s": tok_s_paged, "tok_s_dense": tok_s_dense,
                     "speedup_vs_dense": tok_s_paged / tok_s_dense,
                     "concurrency": SLOTS, "requests": REQUESTS}},
        {"name": f"decode/{name}-chunked",
         "us_per_call": dt_chunk * 1e6,
         "derived": f"matches_dense={_matches(eng_c)};"
                    f"tok_s={tok_s_chunk:.1f};"
                    f"chunks={eng_c.prefill_chunks // REPS}",
         "metrics": {"matches_dense": bool(_matches(eng_c)),
                     "tok_s": tok_s_chunk,
                     "speedup_vs_dense": tok_s_chunk / tok_s_dense,
                     "prefill_chunks": eng_c.prefill_chunks // REPS,
                     "prefill_compilations": eng_c.prefill_compilations,
                     "concurrency": SLOTS, "requests": REQUESTS}},
        {"name": f"kvbytes/{name}",
         "us_per_call": 0.0,
         "derived": f"kv_bytes_ratio={st['kv_bytes_ratio']:.4f};"
                    f"peak_pages={st['peak_pages_in_use']};"
                    f"within_live_bound={st['within_live_bound']}",
         "metrics": {"kv_bytes_ratio": float(st["kv_bytes_ratio"]),
                     "peak_kv_bytes": int(st["peak_kv_bytes"]),
                     "dense_kv_bytes": int(st["dense_kv_bytes"]),
                     "peak_live_tokens": int(st["peak_live_tokens"]),
                     "within_live_bound": bool(st["within_live_bound"]),
                     "page_size": PAGE_SIZE, "num_pages": NUM_PAGES,
                     "preemptions": int(st["preemptions"])}},
        {"name": f"speculative/{name}-ngram",
         "us_per_call": dt_spec * 1e6,
         "derived": f"matches_dense={_matches(eng_s)};"
                    f"accept_rate={sp['accept_rate']:.3f};"
                    f"eff_tok_step={sp['effective_tokens_per_step']:.2f};"
                    f"tok_s_ratio={tok_s_spec / tok_s_paged:.2f}",
         "metrics": {"matches_dense": bool(_matches(eng_s)),
                     "accept_rate": float(sp["accept_rate"]),
                     "effective_tokens_per_step":
                         float(sp["effective_tokens_per_step"]),
                     "tok_s": tok_s_spec,
                     "tok_s_ratio": tok_s_spec / tok_s_paged,
                     "decode_steps": int(sp["decode_steps"]) // REPS,
                     "decode_compilations":
                         int(sp["decode_compilations"]),
                     "draft_len": DRAFT_LEN, "draft_source": "ngram",
                     "drafted": int(sp["drafted"]),
                     "accepted": int(sp["accepted"]),
                     "concurrency": SLOTS, "requests": REQUESTS}},
        {"name": f"roofline/{name}-spec",
         "us_per_call": 0.0,
         "derived": f"attainable_tok_s={roof['attainable_tok_s']:.0f};"
                    f"measured_tok_s={tok_s_spec:.1f};"
                    f"eff_tok_step={roof['effective_tokens_per_step']:.2f}",
         "metrics": {"attainable_tok_s": float(roof["attainable_tok_s"]),
                     "measured_tok_s": tok_s_spec,
                     "effective_tokens_per_step":
                         float(roof["effective_tokens_per_step"]),
                     "step_bytes": float(roof["step_bytes"]),
                     "accept_rate": float(roof["accept_rate"]),
                     "draft_len": DRAFT_LEN, "page_size": PAGE_SIZE,
                     "live_tokens_per_seq": live}},
        {"name": f"roofline/{name}-spec-int8",
         "us_per_call": 0.0,
         "derived": f"attainable_tok_s={roof_q['attainable_tok_s']:.0f};"
                    f"vs_fp32_attainable="
                    f"{roof_q['attainable_tok_s'] / roof['attainable_tok_s']:.2f};"
                    f"param_bytes={roof_q['param_bytes']:.0f}",
         "metrics": {"attainable_tok_s": float(roof_q["attainable_tok_s"]),
                     "measured_tok_s": 0.0,
                     "vs_fp32_attainable":
                         float(roof_q["attainable_tok_s"]
                               / roof["attainable_tok_s"]),
                     "param_bytes": float(roof_q["param_bytes"]),
                     "param_bytes_dense": float(roof["param_bytes"]),
                     "overlay_density": 0.05,
                     "quantize_base": True,
                     "draft_len": DRAFT_LEN, "page_size": PAGE_SIZE}},
        {"name": f"obs/{name}-overhead",
         "us_per_call": dt_instr * 1e6,
         "derived": f"obs_tok_s_ratio={obs_ratio:.3f};"
                    f"tok_s_instr={tok_s_instr:.1f};"
                    f"tok_s_plain={tok_s_plain:.1f};"
                    f"spans={n_spans}",
         "metrics": {"obs_tok_s_ratio": obs_ratio,
                     "tok_s_instrumented": tok_s_instr,
                     "tok_s_uninstrumented": tok_s_plain,
                     "matches_dense": bool(obs_matches),
                     "spans": n_spans,
                     "concurrency": SLOTS, "requests": REQUESTS}},
    ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_paged_decode.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="paged_decode")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
