"""DeltaHub merge benchmarks (DESIGN.md §4) — BENCH_delta_merge.json.

Per density: the Pallas scatter-merge latency vs the dense jnp reference
(interpret-mode wall time, regression tracking only) with a MEASURED
bitwise-parity bit, and the artifact-size story — on-disk bytes of the
saved `(indices, values)` artifact vs the dense planned-tensor bytes.
The `ratio/` rows carry the CI-gated invariant (bench_schema.py): at the
paper's operating density (<= 5 %) the artifact must stay within 12 % of
the dense checkpoint — the O(k) distribution-unit claim that makes
many-adapters-per-base serving viable.

The `pool/` rows carry the merge-free SERVING half of that claim
(DESIGN.md §5, docs/SERVING.md):

  * `pool/resident-*` (CI-gated): >= 32 adapters held device-resident
    CONCURRENTLY in one paged adapter pool, each costing
    `adapter_bytes_ratio` <= 5 % of one dense merged copy — the
    "a million adapters" scaling unit (an AdapterStore entry costs 1.0x
    per adapter; the pool costs ~2x density plus page slack);
  * `pool/footprint-*` (report-only): the same ratio at the paper's 5 %
    operating density, where ~2x density lands above the 5 % gate —
    tracked so the density -> resident-bytes tradeoff stays visible;
  * `pool/identity-*` (CI-gated): a decode batch MIXING >= 2 adapters
    per step through the pool must be token-identical to merge-on-load
    AdapterStore serving (the reference path), at temperature 0 AND
    sampled temperatures — `matches_ref` with `adapters_mixed` >= 2.

Machine-readable output: `python -m benchmarks.delta_merge --json
BENCH_delta_merge.json` (schema: benchmarks/bench_schema.py).
"""
import argparse
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import SMALL, csv_rows, timer, write_bench_json
from repro.deltas.format import (DeltaArtifact, make_manifest, num_stack,
                                 tree_hash)
from repro.kernels import ops, ref

CASES = [
    # (ns, rows, cols, density)
    (4, 256, 512, 0.01),
    (4, 256, 512, 0.05),
    (4, 256, 512, 0.10),
]

# pool rows: SMALL-model serving geometry
POOL_ADAPTERS = 32           # concurrent-residency target (CI-gated)
POOL_ENTRIES = 512           # adapter-pool entries per page
POOL_SLOTS = 4
POOL_REQUESTS = 6
POOL_MAX_LEN = 128
POOL_MAX_NEW = 16
POOL_PAGE_SIZE = 16
POOL_KV_PAGES = 48


def _artifact(ns, rows, cols, k, seed=0, value_dtype=None):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(ns, rows * cols)).astype(np.float32)
    idx = np.sort(np.stack([rng.choice(rows * cols, k, replace=False)
                            for _ in range(ns)]), -1).astype(np.int32)
    val = rng.normal(size=(ns, k)).astype(np.float32)
    meta = {"t": {"shape": [ns, rows, cols], "stack": [ns], "rows": rows,
                  "cols": cols, "k": k, "dtype": "float32"}}
    if value_dtype == "int8":
        scale = (float(np.max(np.abs(val))) / 127.0) or 1.0
        val = np.clip(np.rint(val / scale), -127, 127).astype(np.int8)
        meta["t"]["value_dtype"] = "int8"
        meta["t"]["value_scale"] = scale
    elif value_dtype is not None:
        val = val.astype(np.dtype(value_dtype))
        meta["t"]["value_dtype"] = value_dtype
    art = DeltaArtifact(
        manifest=make_manifest(mode="replace", base_hash="bench",
                               selection=None, tensors_meta=meta, step=0),
        tensors={"t": {"idx": idx, "val": val}})
    return base, idx, val, art


def _disk_bytes(art: DeltaArtifact, base: np.ndarray):
    """On-disk artifact bytes vs the dense npz the checkpoint would ship."""
    with tempfile.TemporaryDirectory() as d:
        art.save(os.path.join(d, "delta"))
        art_bytes = sum(
            os.path.getsize(os.path.join(d, "delta", f))
            for f in os.listdir(os.path.join(d, "delta")))
        np.savez(os.path.join(d, "dense.npz"), t=base)
        dense_bytes = os.path.getsize(os.path.join(d, "dense.npz"))
    return art_bytes, dense_bytes


# ------------------------------------------------- merge-free pool rows
def _plan_meta(model, density):
    """Default-plan tensors_meta for the model at `density` (the 7
    per-layer block projections — exactly what adapter-pool serving
    composes in-matmul)."""
    from repro.core.lift import LiftConfig, make_plan
    plan = make_plan(model.spec(), LiftConfig(density=density, min_dim=16))
    return {p: {"shape": list(t.shape), "stack": list(t.stack),
                "rows": t.rows, "cols": t.cols, "k": t.k,
                "dtype": "float32"} for p, t in sorted(plan.items())}


def _synthetic_adapter(base_params, base_hash, meta, seed):
    """A mode="replace" artifact perturbing the base at random planned
    indices — the geometry of a real LIFT extract without the training."""
    from repro.core.lift import get_by_path
    rng = np.random.default_rng(seed)
    tensors = {}
    for path, m in meta.items():
        ns, k = num_stack(m), m["k"]
        size = m["rows"] * m["cols"]
        idx = np.stack([np.sort(rng.choice(size, k, replace=False))
                        for _ in range(ns)]).astype(np.int32)
        base = np.asarray(get_by_path(base_params, path),
                          np.float32).reshape(ns, size)
        val = (np.take_along_axis(base, idx, 1)
               + rng.normal(scale=0.05, size=(ns, k))).astype(np.float32)
        tensors[path] = {"idx": idx, "val": val}
    return DeltaArtifact(
        manifest=make_manifest(mode="replace", base_hash=base_hash,
                               selection=None, tensors_meta=meta, step=0),
        tensors=tensors)


def _serve_mixed(eng, prompts, adapter_ids):
    """Serve the request mix, tracking the PEAK number of distinct
    adapters decoding in one batch step.  Temperatures alternate greedy /
    sampled — identity must hold bitwise at any temperature."""
    from repro.serving import Request
    for i, (p, a) in enumerate(zip(prompts, adapter_ids)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=POOL_MAX_NEW,
                           temperature=0.0 if i % 2 == 0 else 0.8,
                           adapter_id=a))
    mixed, steps = 0, 0
    t0 = time.perf_counter()
    while eng.sched.has_work() and steps < 100_000:
        eng.step()
        steps += 1
        live = {s.req.adapter_id for s in eng.sched.seqs
                if s is not None and s.phase == "decode"
                and s.req.adapter_id is not None}
        mixed = max(mixed, len(live))
    dt = time.perf_counter() - t0
    return {r.uid: tuple(r.out_tokens) for r in eng.done}, mixed, dt


def pool_rows():
    from repro.models import build_model
    from repro.serving import AdapterStore, ServingConfig, make_engine
    from repro.serving.kvpool import AdapterPool
    model = build_model(SMALL)
    params = model.init(jax.random.PRNGKey(0))
    base_hash = tree_hash(params)
    rows = []

    # residency: POOL_ADAPTERS adapters at 1% density, ALL pinned at
    # once in a pool sized exactly adapters x pages_per_adapter (+trash)
    meta01 = _plan_meta(model, 0.01)
    from repro.deltas.pool_layout import PoolLayout
    lay01 = PoolLayout(meta01, entries_per_page=POOL_ENTRIES)
    apool = AdapterPool(
        params, num_pages=1 + POOL_ADAPTERS * lay01.pages_per_adapter,
        entries_per_page=POOL_ENTRIES)
    for i in range(POOL_ADAPTERS):
        apool.register(f"ad{i}", _synthetic_adapter(params, base_hash,
                                                    meta01, seed=100 + i))
    t0 = time.perf_counter()
    held = [apool.acquire(f"ad{i}") for i in range(POOL_ADAPTERS)]
    dt = time.perf_counter() - t0
    st = apool.stats()
    for pages in held:
        apool.release(pages)
    rows.append({
        "name": f"pool/resident-{POOL_ADAPTERS}ad-d0.01",
        "us_per_call": dt / POOL_ADAPTERS * 1e6,
        "derived": f"resident_adapters={st['resident_adapters']};"
                   f"adapter_bytes_ratio={st['adapter_bytes_ratio']:.4f};"
                   f"pages_per_adapter={st['pages_per_adapter']}",
        "metrics": {"resident_adapters": int(st["resident_adapters"]),
                    "adapter_bytes_ratio":
                        float(st["adapter_bytes_ratio"]),
                    "pages_per_adapter": int(st["pages_per_adapter"]),
                    "entries_per_page": POOL_ENTRIES,
                    "uploads": int(st["uploads"]),
                    "evictions": int(st["evictions"]),
                    "density": 0.01}})

    # identity: >= 2 adapters + the base mixed per decode step through
    # the pool vs merge-on-load AdapterStore serving (reference path)
    meta05 = _plan_meta(model, 0.05)
    arts = {aid: _synthetic_adapter(params, base_hash, meta05, seed)
            for aid, seed in (("a", 1), ("b", 2))}
    ipool = AdapterPool(params, num_pages=24,
                        entries_per_page=POOL_ENTRIES)
    for aid, art in arts.items():
        ipool.register(aid, art)
    cfg = dict(batch_slots=POOL_SLOTS, max_len=POOL_MAX_LEN, eos_id=2,
               page_size=POOL_PAGE_SIZE, num_pages=POOL_KV_PAGES)
    eng_pool = make_engine(model, params, ServingConfig(**cfg),
                           adapter_pool=ipool)
    store = AdapterStore(params)
    for aid, art in arts.items():
        store.load(aid, art)
    eng_ref = make_engine(model, params, ServingConfig(**cfg),
                          adapters=store)

    rng = np.random.default_rng(7)
    prompts = [rng.integers(3, 90, size=int(s)).astype(np.int32)
               for s in rng.integers(4, 60, size=POOL_REQUESTS)]
    aids = [("a", "b", None)[i % 3] for i in range(POOL_REQUESTS)]
    got, mixed, dt_pool = _serve_mixed(eng_pool, prompts, aids)
    want, _, _ = _serve_mixed(eng_ref, prompts, aids)
    matches = bool(got == want)
    ist = eng_pool.pool_stats()
    rows.append({
        "name": "pool/identity-mixed-d0.05",
        "us_per_call": dt_pool * 1e6,
        "derived": f"matches_ref={matches};adapters_mixed={mixed};"
                   f"requests={POOL_REQUESTS}",
        "metrics": {"matches_ref": matches,
                    "adapters_mixed": int(mixed),
                    "requests": POOL_REQUESTS,
                    "concurrency": POOL_SLOTS,
                    "uploads": int(ist["uploads"]),
                    "density": 0.05}})

    # footprint at the paper's operating density (report-only: ~2x
    # density puts 5% density above the residency gate by design)
    rows.append({
        "name": "pool/footprint-d0.05",
        "us_per_call": 0.0,
        "derived": f"adapter_bytes_ratio="
                   f"{ist['adapter_bytes_ratio']:.4f};"
                   f"dense_copy_ratio=1.0",
        "metrics": {"adapter_bytes_ratio":
                        float(ist["adapter_bytes_ratio"]),
                    "pages_per_adapter": int(ist["pages_per_adapter"]),
                    "entries_per_page": POOL_ENTRIES,
                    "density": 0.05}})
    return rows


def run():
    rows = []
    for ns, m, n, density in CASES:
        k = max(128, int(density * m * n) // 128 * 128)
        base_np, idx_np, val_np, art = _artifact(ns, m, n, k)
        base = jnp.asarray(base_np)
        idx = jnp.asarray(idx_np)
        val = jnp.asarray(val_np)
        name = f"{ns}x{m}x{n}-d{density}"

        kern = jax.jit(lambda b, i, v: ops.sparse_scatter_merge(b, i, v))
        dense = jax.jit(lambda b, i, v: ref.sparse_scatter_merge(b, i, v))
        us_k, out_k = timer(kern, base, idx, val)
        us_d, out_d = timer(dense, base, idx, val)
        matches = bool(np.array_equal(np.asarray(out_k), np.asarray(out_d)))

        art_bytes, dense_bytes = _disk_bytes(art, base_np)
        ratio = art_bytes / dense_bytes
        rows.append({
            "name": f"merge/{name}-kernel", "us_per_call": us_k,
            "derived": f"matches_ref={matches};k={k}",
            "metrics": {"matches_ref": matches, "k": k,
                        "density": density}})
        rows.append({
            "name": f"merge/{name}-ref", "us_per_call": us_d,
            "derived": f"k={k}",
            "metrics": {"k": k, "density": density}})
        rows.append({
            "name": f"ratio/{name}", "us_per_call": 0.0,
            "derived": f"artifact_bytes={art_bytes};"
                       f"dense_bytes={dense_bytes};"
                       f"bytes_ratio={ratio:.4f}",
            "metrics": {"artifact_bytes": int(art_bytes),
                        "dense_bytes": int(dense_bytes),
                        "bytes_ratio": float(ratio),
                        "density": density}})

        # fp16-value artifact (format v2): the value half of the payload
        # shrinks 2x for fp32 tensors; merging upcasts (DESIGN.md §4)
        _, _, _, art16 = _artifact(ns, m, n, k, value_dtype="float16")
        art16_bytes, dense16 = _disk_bytes(art16, base_np)
        ratio16 = art16_bytes / dense16
        rows.append({
            "name": f"ratio/{name}-fp16", "us_per_call": 0.0,
            "derived": f"artifact_bytes={art16_bytes};"
                       f"dense_bytes={dense16};"
                       f"bytes_ratio={ratio16:.4f};"
                       f"vs_fp32={art16_bytes / art_bytes:.3f}",
            "metrics": {"artifact_bytes": int(art16_bytes),
                        "dense_bytes": int(dense16),
                        "bytes_ratio": float(ratio16),
                        "vs_fp32_artifact": float(art16_bytes / art_bytes),
                        "value_dtype": "float16",
                        "density": density}})

        # int8-value artifact (format v3): values shrink 4x with one
        # per-tensor value_scale — ~2x total artifact shrink vs fp32
        # (the int32 index half dominates); merging dequantizes
        _, _, _, art8 = _artifact(ns, m, n, k, value_dtype="int8")
        art8_bytes, dense8 = _disk_bytes(art8, base_np)
        ratio8 = art8_bytes / dense8
        rows.append({
            "name": f"ratio/{name}-int8", "us_per_call": 0.0,
            "derived": f"artifact_bytes={art8_bytes};"
                       f"dense_bytes={dense8};"
                       f"bytes_ratio={ratio8:.4f};"
                       f"vs_fp32={art8_bytes / art_bytes:.3f}",
            "metrics": {"artifact_bytes": int(art8_bytes),
                        "dense_bytes": int(dense8),
                        "bytes_ratio": float(ratio8),
                        "vs_fp32_artifact": float(art8_bytes / art_bytes),
                        "value_dtype": "int8",
                        "density": density}})
    rows.extend(pool_rows())
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_delta_merge.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="delta_merge")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
