"""DeltaHub merge benchmarks (DESIGN.md §4) — BENCH_delta_merge.json.

Per density: the Pallas scatter-merge latency vs the dense jnp reference
(interpret-mode wall time, regression tracking only) with a MEASURED
bitwise-parity bit, and the artifact-size story — on-disk bytes of the
saved `(indices, values)` artifact vs the dense planned-tensor bytes.
The `ratio/` rows carry the CI-gated invariant (bench_schema.py): at the
paper's operating density (<= 5 %) the artifact must stay within 12 % of
the dense checkpoint — the O(k) distribution-unit claim that makes
many-adapters-per-base serving viable.

Machine-readable output: `python -m benchmarks.delta_merge --json
BENCH_delta_merge.json` (schema: benchmarks/bench_schema.py).
"""
import argparse
import os
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_rows, timer, write_bench_json
from repro.deltas.format import DeltaArtifact, make_manifest
from repro.kernels import ops, ref

CASES = [
    # (ns, rows, cols, density)
    (4, 256, 512, 0.01),
    (4, 256, 512, 0.05),
    (4, 256, 512, 0.10),
]


def _artifact(ns, rows, cols, k, seed=0, value_dtype=None):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(ns, rows * cols)).astype(np.float32)
    idx = np.sort(np.stack([rng.choice(rows * cols, k, replace=False)
                            for _ in range(ns)]), -1).astype(np.int32)
    val = rng.normal(size=(ns, k)).astype(np.float32)
    meta = {"t": {"shape": [ns, rows, cols], "stack": [ns], "rows": rows,
                  "cols": cols, "k": k, "dtype": "float32"}}
    if value_dtype is not None:
        val = val.astype(np.dtype(value_dtype))
        meta["t"]["value_dtype"] = value_dtype
    art = DeltaArtifact(
        manifest=make_manifest(mode="replace", base_hash="bench",
                               selection=None, tensors_meta=meta, step=0),
        tensors={"t": {"idx": idx, "val": val}})
    return base, idx, val, art


def _disk_bytes(art: DeltaArtifact, base: np.ndarray):
    """On-disk artifact bytes vs the dense npz the checkpoint would ship."""
    with tempfile.TemporaryDirectory() as d:
        art.save(os.path.join(d, "delta"))
        art_bytes = sum(
            os.path.getsize(os.path.join(d, "delta", f))
            for f in os.listdir(os.path.join(d, "delta")))
        np.savez(os.path.join(d, "dense.npz"), t=base)
        dense_bytes = os.path.getsize(os.path.join(d, "dense.npz"))
    return art_bytes, dense_bytes


def run():
    rows = []
    for ns, m, n, density in CASES:
        k = max(128, int(density * m * n) // 128 * 128)
        base_np, idx_np, val_np, art = _artifact(ns, m, n, k)
        base = jnp.asarray(base_np)
        idx = jnp.asarray(idx_np)
        val = jnp.asarray(val_np)
        name = f"{ns}x{m}x{n}-d{density}"

        kern = jax.jit(lambda b, i, v: ops.sparse_scatter_merge(b, i, v))
        dense = jax.jit(lambda b, i, v: ref.sparse_scatter_merge(b, i, v))
        us_k, out_k = timer(kern, base, idx, val)
        us_d, out_d = timer(dense, base, idx, val)
        matches = bool(np.array_equal(np.asarray(out_k), np.asarray(out_d)))

        art_bytes, dense_bytes = _disk_bytes(art, base_np)
        ratio = art_bytes / dense_bytes
        rows.append({
            "name": f"merge/{name}-kernel", "us_per_call": us_k,
            "derived": f"matches_ref={matches};k={k}",
            "metrics": {"matches_ref": matches, "k": k,
                        "density": density}})
        rows.append({
            "name": f"merge/{name}-ref", "us_per_call": us_d,
            "derived": f"k={k}",
            "metrics": {"k": k, "density": density}})
        rows.append({
            "name": f"ratio/{name}", "us_per_call": 0.0,
            "derived": f"artifact_bytes={art_bytes};"
                       f"dense_bytes={dense_bytes};"
                       f"bytes_ratio={ratio:.4f}",
            "metrics": {"artifact_bytes": int(art_bytes),
                        "dense_bytes": int(dense_bytes),
                        "bytes_ratio": float(ratio),
                        "density": density}})

        # fp16-value artifact (format v2): the value half of the payload
        # shrinks 2x for fp32 tensors; merging upcasts (DESIGN.md §4)
        _, _, _, art16 = _artifact(ns, m, n, k, value_dtype="float16")
        art16_bytes, dense16 = _disk_bytes(art16, base_np)
        ratio16 = art16_bytes / dense16
        rows.append({
            "name": f"ratio/{name}-fp16", "us_per_call": 0.0,
            "derived": f"artifact_bytes={art16_bytes};"
                       f"dense_bytes={dense16};"
                       f"bytes_ratio={ratio16:.4f};"
                       f"vs_fp32={art16_bytes / art_bytes:.3f}",
            "metrics": {"artifact_bytes": int(art16_bytes),
                        "dense_bytes": int(dense16),
                        "bytes_ratio": float(ratio16),
                        "vs_fp32_artifact": float(art16_bytes / art_bytes),
                        "value_dtype": "float16",
                        "density": density}})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_delta_merge.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="delta_merge")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
