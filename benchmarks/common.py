"""Shared benchmark scaffolding.

Every benchmark module exposes `run() -> list[dict]` with keys
  name, us_per_call, derived
where `us_per_call` is the wall time of the measured unit and `derived` is
the paper-relevant quantity (accuracy, ppl ratio, bytes, rank...).

Paper-scale models cannot train on this CPU container, so the comparisons
(LIFT vs Full FT vs LoRA vs selection baselines) run at reduced scale on the
synthetic reasoning corpus — the *orderings* are the reproduction target,
not absolute numbers (DESIGN.md §9).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.core.peft import PeftConfig
from repro.data.loader import ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, eval_accuracy, generate
from repro.models import ModelConfig, build_model
from repro.training import trainer as T

SMALL = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                    num_kv_heads=2, head_dim=16, d_ff=128,
                    vocab_size=max(VOCAB_SIZE, 97))


def timer(fn, *args, reps: int = 3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def make_method(kind: str, rank: int = 8, **lift_kw) -> T.MethodConfig:
    lift_defaults = dict(rank=rank, match_rank=max(1, rank // 4),
                         method="exact", min_dim=16,
                         update_interval=25)
    lift_defaults.update(lift_kw)
    sel = lift_kw.get("selection", "lift")
    kind_map = {"magnitude": "sparse", "gradient": "sparse",
                "movement": "sparse", "random": "sparse"}
    if kind in kind_map:
        lift_defaults["selection"] = kind
        kind = "sparse"
    return T.MethodConfig(kind=kind, lift=LiftConfig(**lift_defaults),
                          peft=PeftConfig(rank=rank))


def train_method(cfg: ModelConfig, method: T.MethodConfig, *,
                 task: str = "arith", steps: int = 60, batch: int = 8,
                 seq: int = 48, lr: float = 0.0, seed: int = 0,
                 n_data: int = 512, refresh_every: Optional[int] = None,
                 eval_n: int = 32):
    """Train, return dict(train_loss, eval_acc, us_per_step, params...).

    lr == 0 picks the paper-style per-method default (the paper searches LR
    per method, App. D.2; these are the best-of-search values at this
    scale): Full FT 1e-3, adapters 3e-3, sparse-FT 1e-2."""
    if lr == 0.0:
        lr = {"full": 1e-3, "lift": 1e-2, "sparse": 1e-2}.get(
            method.kind, 3e-3)
    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(seed))
    data = generate(task, n_data, seq, seed=seed)
    loader = ShardedLoader(data, batch_size=batch, seed=seed)

    sample_grads = None
    if method.kind == "sparse" and method.lift.selection in ("gradient",
                                                             "movement"):
        b0 = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        sample_grads = jax.grad(lambda p: model.loss(p, b0)[0])(params0)

    engine = T.selection_engine(model, method)  # ONE engine: init+refresh
    params, state = T.init_train_state(model, params0, method,
                                       jax.random.PRNGKey(seed + 1),
                                       sample_grads=sample_grads,
                                       engine=engine)
    step_fn = jax.jit(T.make_train_step(model, method,
                                        sa.AdamConfig(lr=lr),
                                        T.constant_lr(lr)))
    refresh = None
    if method.kind in ("lift", "sparse") and refresh_every:
        refresh = T.make_refresh_step(model, method, engine=engine)

    t0 = time.perf_counter()
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        params, state, metrics = step_fn(params, state, b)
        losses.append(float(metrics["loss"]))
        if refresh is not None and (i + 1) % refresh_every == 0:
            state = refresh(params, state, jax.random.PRNGKey(100 + i))
    dt = (time.perf_counter() - t0) / steps * 1e6

    eff = T.effective_params(model, params, state, method)
    acc = eval_accuracy(model, eff, task if task != "lm" else "arith",
                        n=eval_n, seq_len=seq, seed=9999) if eval_n else 0.0
    return {"model": model, "params0": params0, "params": eff,
            "state": state, "train_loss": float(np.mean(losses[-10:])),
            "eval_acc": acc, "us_per_step": dt}


def csv_rows(rows):
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


# -------------------------------------------------- JSON bench artifacts
def _parse_derived(derived: str) -> dict:
    """Best-effort "k1=v1;k2=v2" -> scalar dict for legacy rows that don't
    carry an explicit `metrics` payload."""
    out = {}
    for part in str(derived).split(";"):
        if "=" not in part:
            continue
        key, val = part.split("=", 1)
        try:
            out[key.strip()] = int(val)
        except ValueError:
            try:
                out[key.strip()] = float(val)
            except ValueError:
                out[key.strip()] = val.strip()
    return out


def bench_doc(rows, suite: str) -> dict:
    """rows -> the machine-readable artifact document CI uploads
    (schema: benchmarks/bench_schema.py, docs/CI.md)."""
    from benchmarks.bench_schema import SCHEMA_VERSION
    doc_rows = []
    for r in rows:
        metrics = dict(r.get("metrics") or _parse_derived(r.get("derived",
                                                                "")))
        doc_rows.append({"name": r["name"],
                         "us_per_call": float(r["us_per_call"]),
                         "derived": str(r.get("derived", "")),
                         "metrics": metrics})
    return {"schema_version": SCHEMA_VERSION, "suite": suite,
            "rows": doc_rows}


def write_bench_json(path: str, rows, suite: str) -> None:
    """Write BENCH_<suite>.json, refusing to emit a schema-invalid doc."""
    import json

    from benchmarks.bench_schema import validate
    doc = bench_doc(rows, suite)
    errs = validate(doc)
    if errs:
        raise ValueError(f"benchmark rows violate the artifact schema: "
                         f"{'; '.join(errs)}")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
