"""Bench-baseline regression gate (docs/CI.md).

Compares freshly produced `BENCH_<suite>.json` artifacts against the
committed baselines in `benchmarks/baselines/` and fails on regression.
Only SEMANTIC metrics and relative ratios are compared — never absolute
wall time: `us_per_call` and every timing-derived metric (`*_us`,
`tok_s`, `speedup_*`) are noise on shared CI runners, so they are
tracked through the uploaded artifacts but never gated here
(`bench_schema.py` owns the per-row invariants; this gate owns the
trajectory vs the last accepted baseline).

A run FAILS when, for any row present in the baseline:

  * the row disappeared from the current artifact (coverage regression —
    a benchmark silently stopped measuring something);
  * a guarded boolean metric that was true in the baseline is no longer
    true (e.g. `matches_dense`, `within_bound`);
  * a guarded numeric metric moved beyond its tolerance in the guarded
    direction (e.g. modeled streaming HBM bytes grew > 10 %, dense/
    streaming index agreement dropped by > 0.002, the delta-artifact
    bytes ratio grew > 5 %).

New rows in the current artifact are fine (they join the baseline at the
next re-baseline); unguarded metrics are ignored.

Re-baselining — when a change INTENTIONALLY moves a guarded metric
(bigger modeled buffer for a new feature, new row set), regenerate the
baseline artifact in place and commit it with the PR:

    PYTHONPATH=src:. python -m benchmarks.kernels_micro \
        --json benchmarks/baselines/BENCH_kernels_micro.json
    PYTHONPATH=src:. python -m benchmarks.delta_merge \
        --json benchmarks/baselines/BENCH_delta_merge.json
    PYTHONPATH=src:. python -m benchmarks.paged_decode \
        --json benchmarks/baselines/BENCH_paged_decode.json
    PYTHONPATH=src:. python -m benchmarks.quant \
        --json benchmarks/baselines/BENCH_quant.json
    PYTHONPATH=src:. python -m benchmarks.serving_scenarios \
        --json benchmarks/baselines/BENCH_serving_scenarios.json

The baseline diff then documents the accepted trajectory change in
review, which is the point of committing baselines at all.

Usage: python -m benchmarks.compare [--baseline-dir benchmarks/baselines]
           BENCH_kernels_micro.json [BENCH_*.json ...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# guarded booleans: once true in the baseline, must stay true
BOOL_GUARDS = ("matches_dense", "matches_ref", "within_bound",
               "within_live_bound", "deterministic", "restart_matches")

# guarded numerics: {metric: (direction, rel_tol, abs_tol)} — "max" means
# the current value must not EXCEED baseline * (1 + rel_tol) + abs_tol,
# "min" means it must not FALL BELOW baseline * (1 - rel_tol) - abs_tol.
# Everything here is deterministic arithmetic or a measured agreement
# ratio — never wall time.
NUM_GUARDS = {
    "agree":                    ("min", 0.0, 0.002),
    "hbm_bytes_modeled":        ("max", 0.10, 0.0),
    "dense_bytes_modeled":      ("max", 0.0, 0.0),
    "hbm_saved_bytes":          ("min", 0.10, 0.0),
    "state_saved_bytes":        ("min", 0.10, 0.0),
    "buffer_slots_per_device":  ("max", 0.10, 0.0),
    "bound_slots_per_device":   ("max", 0.10, 0.0),
    "bytes_ratio":              ("max", 0.05, 0.0),
    "kv_bytes_ratio":           ("max", 0.10, 0.0),
    # merge-free adapter-pool serving (deterministic layout arithmetic /
    # counted residency — never wall time)
    "adapter_bytes_ratio":      ("max", 0.05, 0.0),
    "resident_adapters":        ("min", 0.0, 0.0),
    "adapters_mixed":           ("min", 0.0, 0.0),
    # speculative decode (fixed-seed greedy: drafting and acceptance are
    # deterministic, but generous headroom absorbs jax-version stream
    # shifts; tok_s_ratio is wall time and stays unguarded)
    "accept_rate":              ("min", 0.25, 0.0),
    "effective_tokens_per_step": ("min", 0.10, 0.0),
    "decode_compilations":      ("max", 0.0, 0.0),
    # observability overhead: instrumented/uninstrumented decode tok/s
    # (both arms are wall time, but their RATIO is what must not drift —
    # a host sync sneaking into a hot path shows up here)
    "obs_tok_s_ratio":          ("min", 0.03, 0.0),
    # quantized-base serving (DESIGN.md §12): residency is deterministic
    # byte arithmetic; logit divergence is fixed-seed deterministic with
    # headroom for jax-version numeric shifts; the committed bound itself
    # must NEVER loosen (zero tolerance)
    "hbm_bytes_ratio":          ("max", 0.05, 0.0),
    "max_logit_divergence":     ("max", 0.25, 0.0),
    "bound":                    ("max", 0.0, 0.0),
    # serving scenario harness (benchmarks/serving_scenarios.py):
    # deterministic scheduler arithmetic on seeded workloads — a storm
    # that stops preempting or a prefix cache that stops hitting is a
    # behavior regression, never wall time (latency/tok_s stay
    # unguarded); occupancy must not creep past the live working set
    "preemption_rate":          ("min", 0.5, 0.0),
    "page_hit_rate":            ("min", 0.5, 0.0),
    "peak_pool_occupancy":      ("max", 0.25, 0.05),
    # measured by XLA, stable under pinned jaxlib but version-sensitive:
    # generous headroom so only order-of-magnitude regressions (a score
    # matrix sneaking back into temps) trip the gate
    "temp_bytes_measured":      ("max", 0.50, 0.0),
}


def _is_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def compare_docs(current: dict, baseline: dict, where: str = "") -> list:
    """Regression errors of `current` vs `baseline` (empty = no
    regression).  Rows pair by exact name; baseline rows missing from
    current are coverage regressions."""
    errs = []
    if current.get("suite") != baseline.get("suite"):
        errs.append(f"{where}: suite changed: baseline "
                    f"{baseline.get('suite')!r} vs current "
                    f"{current.get('suite')!r}")
    cur_rows = {r.get("name"): r.get("metrics") or {}
                for r in current.get("rows", [])}
    for row in baseline.get("rows", []):
        name = row.get("name")
        base_m = row.get("metrics") or {}
        if name not in cur_rows:
            errs.append(f"{where}: baseline row {name!r} missing from the "
                        f"current artifact — a benchmark stopped "
                        f"measuring it (coverage regression); re-baseline "
                        f"if intentional")
            continue
        cur_m = cur_rows[name]
        for key, want in base_m.items():
            if key in BOOL_GUARDS and want is True:
                if cur_m.get(key) is not True:
                    errs.append(f"{where}: {name}: {key} regressed from "
                                f"true to {cur_m.get(key)!r}")
                continue
            guard = NUM_GUARDS.get(key)
            if guard is None or not _is_number(want):
                continue
            got = cur_m.get(key)
            if not _is_number(got):
                errs.append(f"{where}: {name}: guarded metric {key!r} "
                            f"disappeared (baseline {want!r}, current "
                            f"{got!r})")
                continue
            direction, rel, abs_ = guard
            if direction == "max":
                limit = want * (1 + rel) + abs_
                if got > limit:
                    errs.append(
                        f"{where}: {name}: {key} regressed: {got} > "
                        f"baseline {want} (+{rel:.0%}/{abs_} tolerance)")
            else:
                limit = want * (1 - rel) - abs_
                if got < limit:
                    errs.append(
                        f"{where}: {name}: {key} regressed: {got} < "
                        f"baseline {want} (-{rel:.0%}/{abs_} tolerance)")
    return errs


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compare BENCH_*.json artifacts against committed "
                    "baselines (relative/semantic metrics only, never "
                    "wall time)")
    ap.add_argument("current", nargs="+",
                    help="freshly produced BENCH_<suite>.json files")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines",
                    help="directory holding the committed baseline "
                         "artifacts (matched by file name)")
    args = ap.parse_args(argv)

    bad = 0
    for path in args.current:
        base_path = os.path.join(args.baseline_dir, os.path.basename(path))
        try:
            current = _load(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}", file=sys.stderr)
            bad += 1
            continue
        try:
            baseline = _load(base_path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: no usable baseline at {base_path} ({e}) — "
                  f"generate and commit one (see module docstring)",
                  file=sys.stderr)
            bad += 1
            continue
        errs = compare_docs(current, baseline, where=os.path.basename(path))
        if errs:
            bad += 1
            for e in errs:
                print(e, file=sys.stderr)
        else:
            n = len(baseline.get("rows", []))
            print(f"{path}: OK vs {base_path} ({n} baseline rows held)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
