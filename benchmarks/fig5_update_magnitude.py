"""Fig. 5 analog: weight-update magnitude distribution.  LIFT's delta-W has
far LARGER per-entry magnitude than Full FT / LoRA while touching only ~5 %
of entries.  derived = (frac changed, max |dW|, ||dW||)."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.core.analysis import tree_update_stats


def run():
    rows = []
    for kind in ["full", "lift", "lora"]:
        out = train_method(SMALL, make_method(kind), task="arith",
                           steps=60, eval_n=0)
        stats = tree_update_stats(out["params0"], out["params"])
        rows.append({
            "name": f"fig5/update-{kind}",
            "us_per_call": out["us_per_step"],
            "derived": f"frac={stats['frac_changed']:.4f};"
                       f"max={stats['max']:.4f};l2={stats['l2']:.3f}",
        })
    return rows


if __name__ == "__main__":
    csv_rows(run())
