"""App. C analog: spectral-norm change when noise hits LIFT-selected vs
magnitude/random-selected entries of (a) random matrices, (b) trained-LM
weights.  LIFT selections move the spectral norm far more.
derived = delta spectral norm per selection."""
import jax
import jax.numpy as jnp

from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.core.lift import LiftConfig, scores_for, topk_indices
from repro.core.lowrank import spectral_norm


def _delta_sn(w, sel, key, scale=0.1, density=0.05):
    lcfg = LiftConfig(rank=8, method="exact", selection=sel)
    k = int(density * w.size)
    s = scores_for(w, lcfg, sel, key)
    idx = topk_indices(s, k)
    noise = scale * jax.random.normal(key, (k,))
    flat = w.reshape(-1)
    w2 = flat.at[idx].add(noise).reshape(w.shape)
    return float(spectral_norm(w2) - spectral_norm(w))


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for n in (128, 512):
        w = jax.random.normal(key, (n, n)) / jnp.sqrt(n)
        d = {s: _delta_sn(w, s, jax.random.PRNGKey(1))
             for s in ("lift", "magnitude", "random")}
        rows.append({"name": f"appc/random-{n}x{n}", "us_per_call": 0.0,
                     "derived": ";".join(f"{k}={v:+.4f}"
                                         for k, v in d.items())})
    out = train_method(SMALL, make_method("full"), task="lm", steps=40,
                       eval_n=0)
    w = out["params"]["blocks"]["mlp"]["up"][0]
    d = {s: _delta_sn(w, s, jax.random.PRNGKey(2))
         for s in ("lift", "magnitude", "random")}
    rows.append({"name": "appc/trained-mlp-up", "us_per_call": 0.0,
                 "derived": ";".join(f"{k}={v:+.4f}" for k, v in d.items())})
    return rows


if __name__ == "__main__":
    csv_rows(run())
