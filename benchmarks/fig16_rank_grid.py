"""Fig. 16 analog: LRA rank x selected-rank grid.  Paper: best accuracy
sits near LRA-rank ~ selected-rank, not at max LRA rank.
derived = eval accuracy per (lra_rank, sel_rank)."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method


def run():
    rows = []
    for lra in [4, 8, 16]:
        for sel in [1, 2, 4]:
            out = train_method(
                SMALL, make_method("lift", rank=lra, match_rank=sel),
                task="arith", steps=100, refresh_every=25, seed=4,
                eval_n=24)
            rows.append({"name": f"fig16/lra{lra}-sel{sel}",
                         "us_per_call": out["us_per_step"],
                         "derived": f"acc={out['eval_acc']:.3f}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
