"""Machine-readable benchmark artifact schema (docs/CI.md).

`BENCH_<suite>.json` documents look like:

    {
      "schema_version": 1,
      "suite": "kernels_micro",
      "rows": [
        {"name": "sel/512x512-d0.05-streaming",
         "us_per_call": 123.4,
         "derived": "hbm_bytes_modeled=...;agree=0.99987",
         "metrics": {"hbm_bytes_modeled": 274432, "agree": 0.99987}}
      ]
    }

`metrics` carries the machine-readable values (numbers / bools / short
strings); `derived` keeps the human CSV string.  CI validates the schema
and the SEMANTIC invariants below and fails on violations — it never
fails on absolute timings (interpret-mode wall time is noise; the
trajectory lives in the uploaded artifacts, DESIGN.md §9).

Semantic invariants for suite "kernels_micro":
  * every `sel/*-streaming` row reports `agree` in [0, 1] and
    agree >= 0.99 (streaming selection may differ from dense top-k only
    in final-histogram-bin ties);
  * every `selstruct/*-streaming` row (structured LIFT, block_size > 1)
    additionally reports `matches_dense` == true — on the benchmark's
    fixed-seed cases the streaming block-sum pipeline must be
    bitwise-identical to the dense block top-k (DESIGN.md §3);
  * every `shardsel/*` row reports `within_bound` == true — the modeled
    per-device candidate buffer of sharded streaming selection must stay
    within its O(compact_factor * k / n_shards) bound.

Semantic invariants for suite "delta_merge" (DESIGN.md §4):
  * every `merge/*-kernel` row reports `matches_ref` == true — the Pallas
    scatter-merge must stay bitwise-identical to the dense reference;
  * every `ratio/*` row reports `bytes_ratio`, and rows at the paper's
    operating density (metric density <= 0.05) must keep the on-disk
    delta artifact within 12 % of the dense checkpoint bytes;
  * every `pool/resident*` row (merge-free adapter-pool serving,
    DESIGN.md §5) reports `resident_adapters` >= 32 held concurrently
    AND `adapter_bytes_ratio` <= 0.05 — one pool-resident adapter costs
    at most 5 % of the dense merged copy an AdapterStore entry holds;
  * every `pool/identity*` row reports `matches_ref` == true (a decode
    batch mixing adapters per slot through the pool is token-identical
    to merge-on-load AdapterStore serving) and `adapters_mixed` >= 2
    (the batch actually mixed >= 2 adapters in one decode step).

Semantic invariants for suite "paged_decode" (DESIGN.md §5):
  * every `decode/*` row reports `matches_dense` == true — the paged
    engine must reproduce the dense-cache engine's token streams exactly
    on the mixed-length request stream (greedy);
  * every `kvbytes/*` row reports numeric `kv_bytes_ratio` < 1 (resident
    paged KV at its peak stays below the dense slots x max_len cache on
    mixed lengths) and `within_live_bound` == true (pool bytes track the
    LIVE tokens plus page-rounding slack, never the worst case);
  * every `speculative/*` row reports `matches_dense` == true (drafting
    and multi-token verification must not move a single token at any
    temperature), `accept_rate` in [0, 1],
    `effective_tokens_per_step` > 1 (speculation pays for itself in
    tokens advanced per sequence-dispatch — one-token decode is exactly
    1.0), and `decode_compilations` == 1 (the speculative path compiles
    exactly ONE decode program; every dispatch reuses it).
    `tok_s_ratio` must be present (baseline-tracked) but is NOT gated —
    interpret-mode wall time is noise;
  * every `roofline/*` row reports numeric `attainable_tok_s` > 0 and
    `measured_tok_s` >= 0 (the memory-bound attainable bound next to
    the measured throughput; never gated against each other — the bound
    models TPU HBM, the measurement is interpret-mode CPU);
  * every `obs/*` row reports `obs_tok_s_ratio` >= 0.97 (fully
    instrumented decode — span tracing plus compile fingerprinting,
    docs/OBSERVABILITY.md — stays within 3 % of the
    `ObsContext.disabled()` arm's throughput: telemetry must never add
    a host sync to a hot path) and `matches_dense` == true
    (instrumentation must not move a single token).

Semantic invariants for suite "quant" (DESIGN.md §12):
  * every `residency/*` row reports numeric `hbm_bytes_ratio` <= 0.55 —
    int8 base + fp32 principal overlay must cost at most 55 % of the
    dense fp32 residency for the quantized projection set;
  * every `parity/*` row reports `matches_ref` == true — the fused
    dequant-scatter-matmul kernel and the lax fallback must both stay
    bitwise-identical to the `kernels.ref` oracle;
  * every `divergence/*` row reports numeric `max_logit_divergence` >= 0
    AND `within_bound` == true (per-position max |logit - fp32 logit|
    stays under the row's committed `bound`);
  * every `identity/*` row reports `matches_ref` == true — greedy decode
    over the quantized base reproduces the fp32 reference token streams
    exactly, including the mixed-adapter pool row (vs fp32
    merge-on-load), which additionally reports `adapters_mixed` >= 2.

Semantic invariants for suite "serving_scenarios" (docs/CI.md; the
unified-engine fleet scenario harness, benchmarks/serving_scenarios.py):
  * every row reports `deterministic` == true — rerunning the seeded
    scenario must reproduce every token stream exactly;
  * every row reports `preemption_rate` in [0, 1],
    `peak_pool_occupancy` in (0, 1] and `page_hit_rate` in [0, 1]
    (ratio metrics — the gated trajectory; latency percentiles and
    tok/s ride along unguarded, wall time is never gated);
  * every `storm/*` row reports `preemption_rate` > 0 (the storm must
    actually preempt) and `matches_ref` == true (streams bitwise-equal
    to the roomy-pool reference despite the churn);
  * every `chat/*` row reports `page_hit_rate` > 0 (the shared prefix
    must actually hit the refcounted prefix cache);
  * every `elastic/*` row reports `restart_matches` == true (the union
    of pre-crash and post-restart streams equals the uninterrupted
    reference).

Usage: python -m benchmarks.bench_schema BENCH_kernels_micro.json [...]
"""
from __future__ import annotations

import json
import sys

SCHEMA_VERSION = 1


def validate(doc) -> list:
    """Returns a list of human-readable schema violations (empty = valid)."""
    errs = []
    if not isinstance(doc, dict):
        return [f"document must be a JSON object, got {type(doc).__name__}"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version must be {SCHEMA_VERSION}, "
                    f"got {doc.get('schema_version')!r}")
    suite = doc.get("suite")
    if not isinstance(suite, str) or not suite:
        errs.append(f"suite must be a non-empty string, got {suite!r}")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        return errs + ["rows must be a non-empty list"]
    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            errs.append(f"{where} must be an object")
            continue
        name = row.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"{where}.name must be a non-empty string")
            name = f"<row {i}>"
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or isinstance(us, bool) \
                or us < 0:
            errs.append(f"{where} ({name}): us_per_call must be a "
                        f"number >= 0, got {us!r}")
        metrics = row.get("metrics", {})
        if not isinstance(metrics, dict):
            errs.append(f"{where} ({name}): metrics must be an object")
            continue
        for mk, mv in metrics.items():
            if not isinstance(mv, (int, float, str, bool)):
                errs.append(f"{where} ({name}): metric {mk!r} must be a "
                            f"scalar, got {type(mv).__name__}")
        if suite == "kernels_micro":
            errs.extend(_kernels_micro_row(name, metrics))
        if suite == "delta_merge":
            errs.extend(_delta_merge_row(name, metrics))
        if suite == "paged_decode":
            errs.extend(_paged_decode_row(name, metrics))
        if suite == "quant":
            errs.extend(_quant_row(name, metrics))
        if suite == "serving_scenarios":
            errs.extend(_serving_scenarios_row(name, metrics))
    return errs


def _kernels_micro_row(name: str, metrics: dict) -> list:
    errs = []
    if name.startswith(("sel/", "selstruct/")) and \
            name.endswith("-streaming"):
        agree = metrics.get("agree")
        if not isinstance(agree, (int, float)) or not 0.0 <= agree <= 1.0:
            errs.append(f"{name}: streaming row needs metric agree in "
                        f"[0, 1], got {agree!r}")
        elif agree < 0.99:
            errs.append(f"{name}: streaming/dense index agreement {agree} "
                        f"< 0.99 — beyond final-bin ties, selection broke")
    if name.startswith("selstruct/") and name.endswith("-streaming"):
        if metrics.get("matches_dense") is not True:
            errs.append(
                f"{name}: matches_dense must be true — structured "
                f"streaming selection diverged from the dense block-sum "
                f"top-k on a fixed-seed case")
    if name.startswith("shardsel/"):
        if metrics.get("within_bound") is not True:
            errs.append(
                f"{name}: within_bound must be true — per-device candidate "
                f"buffer exceeded its O(compact_factor * k / n_shards) "
                f"bound ({metrics.get('buffer_slots_per_device')} slots vs "
                f"bound {metrics.get('bound_slots_per_device')})")
    return errs


def _delta_merge_row(name: str, metrics: dict) -> list:
    errs = []
    if name.startswith("merge/") and name.endswith("-kernel"):
        if metrics.get("matches_ref") is not True:
            errs.append(f"{name}: matches_ref must be true — the Pallas "
                        f"scatter-merge diverged from the dense reference")
    if name.startswith("ratio/"):
        ratio = metrics.get("bytes_ratio")
        if not isinstance(ratio, (int, float)) or isinstance(ratio, bool):
            errs.append(f"{name}: ratio row needs numeric metric "
                        f"bytes_ratio, got {ratio!r}")
        else:
            density = metrics.get("density")
            if isinstance(density, (int, float)) and density <= 0.05 \
                    and ratio > 0.12:
                errs.append(
                    f"{name}: delta artifact is {ratio:.3f}x the dense "
                    f"checkpoint at density {density} — exceeds the 12% "
                    f"O(k)-artifact bound (DESIGN.md §4)")
    if name.startswith("pool/resident"):
        res = metrics.get("resident_adapters")
        if not isinstance(res, int) or isinstance(res, bool):
            errs.append(f"{name}: residency row needs integer metric "
                        f"resident_adapters, got {res!r}")
        elif res < 32:
            errs.append(
                f"{name}: only {res} adapters concurrently device-"
                f"resident — the merge-free pool must hold >= 32 "
                f"(DESIGN.md §5)")
        abr = metrics.get("adapter_bytes_ratio")
        if not isinstance(abr, (int, float)) or isinstance(abr, bool):
            errs.append(f"{name}: residency row needs numeric metric "
                        f"adapter_bytes_ratio, got {abr!r}")
        elif abr > 0.05:
            errs.append(
                f"{name}: one pool-resident adapter costs {abr:.3f}x a "
                f"dense merged copy — exceeds the 5% merge-free "
                f"residency bound (DESIGN.md §5)")
    if name.startswith("pool/identity"):
        if metrics.get("matches_ref") is not True:
            errs.append(
                f"{name}: matches_ref must be true — adapter-pool "
                f"serving diverged from merge-on-load AdapterStore "
                f"token streams (DESIGN.md §5)")
        mixed = metrics.get("adapters_mixed")
        if not isinstance(mixed, int) or isinstance(mixed, bool) \
                or mixed < 2:
            errs.append(
                f"{name}: adapters_mixed must be an integer >= 2 — the "
                f"identity run must actually mix adapters in one decode "
                f"batch, got {mixed!r}")
    return errs


def _paged_decode_row(name: str, metrics: dict) -> list:
    errs = []
    if name.startswith("decode/"):
        if metrics.get("matches_dense") is not True:
            errs.append(f"{name}: matches_dense must be true — the paged "
                        f"engine diverged from the dense-cache engine's "
                        f"token streams")
    if name.startswith("kvbytes/"):
        ratio = metrics.get("kv_bytes_ratio")
        if not isinstance(ratio, (int, float)) or isinstance(ratio, bool):
            errs.append(f"{name}: kvbytes row needs numeric metric "
                        f"kv_bytes_ratio, got {ratio!r}")
        elif ratio >= 1.0:
            errs.append(
                f"{name}: peak paged KV is {ratio:.3f}x the dense "
                f"slots x max_len cache — paging must be bounded by the "
                f"live working set on mixed lengths (DESIGN.md §5)")
        if metrics.get("within_live_bound") is not True:
            errs.append(
                f"{name}: within_live_bound must be true — the pool "
                f"exceeded live tokens + page-rounding slack "
                f"({metrics.get('peak_kv_bytes')} bytes at "
                f"{metrics.get('peak_live_tokens')} live tokens)")
    if name.startswith("speculative/"):
        if metrics.get("matches_dense") is not True:
            errs.append(f"{name}: matches_dense must be true — "
                        f"speculative decode moved a token vs the dense "
                        f"engine's streams (DESIGN.md §5)")
        ar = metrics.get("accept_rate")
        if not isinstance(ar, (int, float)) or isinstance(ar, bool) \
                or not 0.0 <= ar <= 1.0:
            errs.append(f"{name}: speculative row needs accept_rate in "
                        f"[0, 1], got {ar!r}")
        eff = metrics.get("effective_tokens_per_step")
        if not isinstance(eff, (int, float)) or isinstance(eff, bool):
            errs.append(f"{name}: speculative row needs numeric "
                        f"effective_tokens_per_step, got {eff!r}")
        elif eff <= 1.0:
            errs.append(
                f"{name}: effective_tokens_per_step {eff:.3f} <= 1 — "
                f"accept_rate x draft_len is not paying for the wider "
                f"verify dispatch (one-token decode is exactly 1.0)")
        if metrics.get("decode_compilations") != 1:
            errs.append(
                f"{name}: decode_compilations must be 1 — the "
                f"speculative path compiles exactly one decode program, "
                f"got {metrics.get('decode_compilations')!r}")
        if not isinstance(metrics.get("tok_s_ratio"), (int, float)) \
                or isinstance(metrics.get("tok_s_ratio"), bool):
            errs.append(f"{name}: speculative row needs numeric "
                        f"tok_s_ratio (vs the one-token paged engine), "
                        f"got {metrics.get('tok_s_ratio')!r}")
    if name.startswith("obs/"):
        ratio = metrics.get("obs_tok_s_ratio")
        if not isinstance(ratio, (int, float)) or isinstance(ratio, bool):
            errs.append(f"{name}: obs row needs numeric metric "
                        f"obs_tok_s_ratio, got {ratio!r}")
        elif ratio < 0.97:
            errs.append(
                f"{name}: instrumented decode at {ratio:.3f}x the "
                f"uninstrumented throughput — telemetry overhead "
                f"exceeds the 3% budget (a host sync crept into a hot "
                f"path? docs/OBSERVABILITY.md)")
        if metrics.get("matches_dense") is not True:
            errs.append(
                f"{name}: matches_dense must be true — instrumentation "
                f"moved a token vs the dense engine's streams")
    if name.startswith("roofline/"):
        att = metrics.get("attainable_tok_s")
        if not isinstance(att, (int, float)) or isinstance(att, bool) \
                or att <= 0:
            errs.append(f"{name}: roofline row needs numeric "
                        f"attainable_tok_s > 0, got {att!r}")
        meas = metrics.get("measured_tok_s")
        if not isinstance(meas, (int, float)) or isinstance(meas, bool) \
                or meas < 0:
            errs.append(f"{name}: roofline row needs numeric "
                        f"measured_tok_s >= 0, got {meas!r}")
    return errs


def _quant_row(name: str, metrics: dict) -> list:
    errs = []
    if name.startswith("residency/"):
        ratio = metrics.get("hbm_bytes_ratio")
        if not isinstance(ratio, (int, float)) or isinstance(ratio, bool):
            errs.append(f"{name}: residency row needs numeric metric "
                        f"hbm_bytes_ratio, got {ratio!r}")
        elif ratio > 0.55:
            errs.append(
                f"{name}: quantized residency is {ratio:.3f}x the dense "
                f"fp32 bytes — exceeds the 55% int8+overlay bound "
                f"(DESIGN.md §12)")
    if name.startswith("parity/"):
        if metrics.get("matches_ref") is not True:
            errs.append(
                f"{name}: matches_ref must be true — the fused "
                f"dequant-scatter-matmul diverged from the kernels.ref "
                f"oracle (the contract is bitwise, DESIGN.md §12)")
    if name.startswith("divergence/"):
        div = metrics.get("max_logit_divergence")
        if not isinstance(div, (int, float)) or isinstance(div, bool) \
                or div < 0:
            errs.append(f"{name}: divergence row needs numeric "
                        f"max_logit_divergence >= 0, got {div!r}")
        if metrics.get("within_bound") is not True:
            errs.append(
                f"{name}: within_bound must be true — per-position logit "
                f"divergence vs the fp32 reference exceeded the committed "
                f"bound ({metrics.get('max_logit_divergence')!r} vs "
                f"{metrics.get('bound')!r})")
    if name.startswith("identity/"):
        if metrics.get("matches_ref") is not True:
            errs.append(
                f"{name}: matches_ref must be true — greedy decode over "
                f"the quantized base moved a token vs the fp32 reference "
                f"streams (DESIGN.md §12)")
        if "adapters_mixed" in metrics:
            mixed = metrics.get("adapters_mixed")
            if not isinstance(mixed, int) or isinstance(mixed, bool) \
                    or mixed < 2:
                errs.append(
                    f"{name}: adapters_mixed must be an integer >= 2 — "
                    f"the pool row must actually mix adapters over the "
                    f"int8 base, got {mixed!r}")
    return errs


def _serving_scenarios_row(name: str, metrics: dict) -> list:
    errs = []
    if metrics.get("deterministic") is not True:
        errs.append(f"{name}: deterministic must be true — rerunning the "
                    f"seeded scenario moved a token")
    for key, lo_open in (("preemption_rate", False),
                         ("page_hit_rate", False),
                         ("peak_pool_occupancy", True)):
        v = metrics.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not 0.0 <= v <= 1.0 or (lo_open and v == 0.0):
            errs.append(f"{name}: needs metric {key} in "
                        f"{'(0, 1]' if lo_open else '[0, 1]'}, got {v!r}")
    for key in ("p50_latency_s", "p99_latency_s", "tok_s"):
        v = metrics.get(key)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
            errs.append(f"{name}: needs numeric metric {key} >= 0, "
                        f"got {v!r}")
    if name.startswith("storm/"):
        pr = metrics.get("preemption_rate")
        if isinstance(pr, (int, float)) and pr <= 0:
            errs.append(f"{name}: preemption_rate must be > 0 — the "
                        f"storm scenario never actually preempted")
        if metrics.get("matches_ref") is not True:
            errs.append(f"{name}: matches_ref must be true — preemption "
                        f"churn moved a token vs the roomy-pool reference")
    if name.startswith("chat/"):
        hr = metrics.get("page_hit_rate")
        if isinstance(hr, (int, float)) and hr <= 0:
            errs.append(f"{name}: page_hit_rate must be > 0 — the shared "
                        f"prefix never hit the prefix cache")
    if name.startswith("elastic/"):
        if metrics.get("restart_matches") is not True:
            errs.append(f"{name}: restart_matches must be true — the "
                        f"restarted engine's streams diverged from the "
                        f"uninterrupted reference")
    return errs


def main(argv) -> int:
    if not argv:
        print("usage: python -m benchmarks.bench_schema BENCH_*.json",
              file=sys.stderr)
        return 2
    bad = 0
    for path in argv:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}", file=sys.stderr)
            bad += 1
            continue
        errs = validate(doc)
        if errs:
            bad += 1
            for e in errs:
                print(f"{path}: {e}", file=sys.stderr)
        else:
            print(f"{path}: OK ({len(doc['rows'])} rows, "
                  f"suite {doc['suite']})")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
