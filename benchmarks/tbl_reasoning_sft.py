"""Table 4 analog (s1K-style): low-data reasoning SFT — LIFT vs Full FT.
128 examples x multiple epochs; Full FT overfits, LIFT generalizes.
derived = held-out accuracy."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method


def run():
    rows = []
    for kind in ["full", "lift"]:
        out = train_method(SMALL, make_method(kind), task="arith",
                           steps=150, n_data=128, refresh_every=25)
        rows.append({
            "name": f"tbl4/{kind}-lowdata",
            "us_per_call": out["us_per_step"],
            "derived": f"acc={out['eval_acc']:.3f};"
                       f"loss={out['train_loss']:.3f}",
        })
    return rows


if __name__ == "__main__":
    csv_rows(run())
