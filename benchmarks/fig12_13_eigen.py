"""Figs. 12/13 analog: eigenspace alignment score (App. H.1) and
update-matrix rank (App. G.3) per layer type, for LIFT vs Full FT vs LoRA.
Paper: LIFT rotates the top eigenspace of Up/Down/O far more than LoRA and
its update rank is near-full (LoRA's is capped at r).
derived = alignment score + update rank for the mlp/up matrix."""

from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.core.analysis import alignment_score, update_rank


def run():
    rows = []
    for kind in ["full", "lift", "lora"]:
        out = train_method(SMALL, make_method(kind), task="arith",
                           steps=80, eval_n=0, refresh_every=25)
        b = out["params0"]["blocks"]["mlp"]["up"][0]
        a = out["params"]["blocks"]["mlp"]["up"][0]
        score = float(alignment_score(b, a, top_n=32))
        rk = int(update_rank(a - b))
        rows.append({"name": f"fig12_13/{kind}",
                     "us_per_call": out["us_per_step"],
                     "derived": f"align={score:.4f};update_rank={rk}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
