"""Fig. 4 / App. G.1 analog: learning vs forgetting.  Fine-tune on the
arithmetic target domain, measure accuracy on BOTH domains.  Paper: LIFT
learns the target at least as well as Full FT while forgetting far less of
the source domain (commonsense).  derived = (target acc, source acc)."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.data.synthetic import eval_accuracy


def run():
    rows = []
    # "pre-train" on the source domain first, then fine-tune on target
    for kind in ["full", "lift", "lora"]:
        src = train_method(SMALL, make_method("full"), task="common",
                           steps=60, eval_n=0, seed=6)
        model, params = src["model"], src["params"]
        # fine-tune the source-trained model on arithmetic
        import jax
        from benchmarks import common as C
        from repro.data.loader import ShardedLoader
        from repro.data.synthetic import generate
        from repro.training import trainer as T
        from repro.core import sparse_adam as sa
        import jax.numpy as jnp

        method = C.make_method(kind)
        params, state = T.init_train_state(model, params, method,
                                           jax.random.PRNGKey(11))
        step = jax.jit(T.make_train_step(model, method,
                                         sa.AdamConfig(lr=1e-3),
                                         T.constant_lr(1e-3)))
        loader = ShardedLoader(generate("arith", 256, 48, seed=8),
                               batch_size=8, seed=8)
        for i in range(60):
            b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
            params, state, _ = step(params, state, b)
        eff = T.effective_params(model, params, state, method)
        tgt = eval_accuracy(model, eff, "arith", n=24, seq_len=48)
        srcacc = eval_accuracy(model, eff, "common", n=24, seq_len=48)
        rows.append({"name": f"fig4/{kind}",
                     "us_per_call": 0.0,
                     "derived": f"target={tgt:.3f};source={srcacc:.3f}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
