"""Benchmark harness — one module per paper table/figure (DESIGN.md §10).
Prints ``name,us_per_call,derived`` CSV.  ``--only <prefix>`` filters;
``--json-dir DIR`` additionally writes one machine-readable
``BENCH_<module>.json`` per module (schema: benchmarks/bench_schema.py,
uploaded by CI as the perf-trajectory artifacts — docs/CI.md)."""
import argparse
import importlib
import os
import sys
import time
import traceback

MODULES = [
    "benchmarks.tbl_method_comparison",   # Tables 1 & 2
    "benchmarks.tbl_reasoning_sft",       # Table 4
    "benchmarks.fig2_perturbation",       # Figure 2
    "benchmarks.fig3_selection_metrics",  # Figure 3
    "benchmarks.fig4_generalization",     # Figure 4 / App G.1
    "benchmarks.fig5_update_magnitude",   # Figure 5
    "benchmarks.fig6_memory",             # Figure 6
    "benchmarks.fig7_ablations",          # Figure 7a/7b
    "benchmarks.appc_spectral_norm",      # App C
    "benchmarks.fig12_13_eigen",          # Figures 12/13
    "benchmarks.toy_model",               # App G.5
    "benchmarks.tbl17_structured",        # App G.7 / Table 17
    "benchmarks.fig16_rank_grid",         # Figure 16
    "benchmarks.fig17_selection_overlap", # Figure 17 / App G.9
    "benchmarks.fig_super_weights",       # outliers survive rank reduction
    "benchmarks.kernels_micro",           # kernel hot-spots
    "benchmarks.delta_merge",             # DeltaHub scatter-merge + bytes
    "benchmarks.paged_decode",            # PagedKV serving identity + bytes
    "benchmarks.quant",                   # int8 base + overlay serving
    "benchmarks.serving_scenarios",       # fleet scenarios, one engine
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json-dir", default="",
                    help="write BENCH_<module>.json per module here")
    args = ap.parse_args()
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(modname)
            rows = list(mod.run())
            for r in rows:
                print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}",
                      flush=True)
            if args.json_dir:
                from benchmarks.common import write_bench_json
                suite = modname.rsplit(".", 1)[-1]
                write_bench_json(
                    os.path.join(args.json_dir, f"BENCH_{suite}.json"),
                    rows, suite=suite)
        except Exception as e:
            failures += 1
            print(f"{modname},0,ERROR:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
        print(f"# {modname} took {time.time() - t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
