"""Kernel microbenchmarks.  On this CPU container the Pallas kernels run
through the interpreter, so wall time is NOT indicative of TPU speed; the
`derived` column therefore reports the MODELED TPU HBM traffic each fused
kernel saves vs the materializing baseline (the §Perf-relevant quantity),
alongside the interpret-mode us_per_call for regression tracking.

The `sel/` rows compare the two SelectionEngine backends end-to-end
(dense |A B^T| -> top_k -> sort vs streaming threshold + compaction):

  * dense peak memory is MEASURED via XLA `memory_analysis()` temp bytes
    (the score matrix really lands in memory);
  * streaming HBM is MODELED as the kernel's actual HBM outputs
    (candidate buffer + counts + histograms) — on CPU the interpreter
    spills the kernel's VMEM-resident intermediates into XLA temps, so
    measured temps would overstate the TPU number by orders of magnitude;
  * index agreement between the two backends is MEASURED per row.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_rows, timer
from repro.kernels import ops, ref


def _selection_rows():
    """Dense top-k vs streaming selection across densities and sizes."""
    rows = []
    cases = [(512, 512, 16, 0.01), (512, 512, 16, 0.05),
             (256, 384, 16, 0.2)]
    for m, n, r, density in cases:
        k = int(density * m * n)
        a = jax.random.normal(jax.random.PRNGKey(0), (m, r))
        b = jax.random.normal(jax.random.PRNGKey(1), (n, r))

        dense_fn = jax.jit(lambda a, b: jnp.sort(
            jax.lax.top_k(jnp.abs(a @ b.T).reshape(-1), k)[1]))
        stream_fn = jax.jit(lambda a, b: ops.lift_indices(a, b, k)[0])

        us_dense, idx_dense = timer(
            lambda: jax.block_until_ready(dense_fn(a, b)), reps=3)
        us_stream, idx_stream = timer(
            lambda: jax.block_until_ready(stream_fn(a, b)), reps=1)
        agree = len(np.intersect1d(np.asarray(idx_dense),
                                   np.asarray(idx_stream))) / k

        dense_temp = dense_fn.lower(a, b).compile() \
                             .memory_analysis().temp_size_in_bytes
        bm, bn = ops.pick_block(m), ops.pick_block(n)
        cap = ops.compact_capacity(m, n, k, bm, bn)
        tiles = (m // bm) * (n // bn)
        # streaming HBM outputs: candidate idx buffer + per-tile counts
        # + (passes x) histograms + absmax partials (hist passes = 3x512)
        stream_bytes = tiles * cap * 4 + tiles * 4 \
            + 3 * tiles * 512 * 4 + tiles * 4
        name = f"sel/{m}x{n}-d{density}"
        rows.append({
            "name": name + "-dense_topk", "us_per_call": us_dense,
            "derived": f"temp_bytes_measured={dense_temp};k={k}"})
        rows.append({
            "name": name + "-streaming", "us_per_call": us_stream,
            "derived": f"hbm_bytes_modeled={stream_bytes};"
                       f"dense_bytes_modeled={m * n * 4 * 2};"
                       f"agree={agree:.5f}"})
    return rows


def run():
    rows = []
    m, n, r = 1024, 1024, 136
    a = jax.random.normal(jax.random.PRNGKey(0), (m, r))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, r))
    k = int(0.05 * m * n)

    us_ref, _ = timer(lambda: jax.block_until_ready(
        ref.lowrank_abs(a, b)), reps=3)
    us_mask, _ = timer(lambda: jax.block_until_ready(
        ops.lift_mask(a, b, k, bm=256, bn=256)[0]), reps=1)
    # modeled HBM traffic: baseline materializes m*n f32 scores (write+read
    # for the top-k) + mask; fused path writes only the bool mask
    base_bytes = m * n * 4 * 2 + m * n
    fused_bytes = m * n  # bool mask only (3 streaming passes stay in VMEM)
    rows.append({"name": "kern/lift_mask-1024x1024",
                 "us_per_call": us_mask,
                 "derived": f"hbm_saved={(base_bytes - fused_bytes)/2**20:.1f}"
                            f"MiB;ref_abs_us={us_ref:.0f}"})

    N, kk = 2 ** 20, 2 ** 15
    p = jax.random.normal(jax.random.PRNGKey(2), (N,))
    g = jax.random.normal(jax.random.PRNGKey(3), (N,))
    idx = jnp.sort(jax.random.choice(jax.random.PRNGKey(4), N, (kk,),
                                     replace=False)).astype(jnp.int32)
    mm = jnp.zeros((kk,))
    vv = jnp.zeros((kk,))
    us_k, _ = timer(lambda: jax.block_until_ready(
        ops.sparse_adam(p, g, idx, mm, vv, 1, lr=1e-3, bn=8192,
                        exact=False)[0]), reps=1)
    us_r, _ = timer(lambda: jax.block_until_ready(
        ref.sparse_adam(p, g, idx, mm, vv, lr=1e-3, b1=0.9, b2=0.999,
                        eps=1e-8, wd=0.0, step=1)[0]), reps=3)
    # dense-masked adam would stream 2 fp32 moment vectors of size N;
    # sparse layout streams k-sized vectors
    saved = 2 * 4 * (N - kk)
    rows.append({"name": "kern/sparse_adam-1M",
                 "us_per_call": us_k,
                 "derived": f"state_saved={saved/2**20:.1f}MiB;"
                            f"ref_us={us_r:.0f}"})
    rows.extend(_selection_rows())
    return rows


if __name__ == "__main__":
    csv_rows(run())
