"""Kernel microbenchmarks.  On this CPU container the Pallas kernels run
through the interpreter, so wall time is NOT indicative of TPU speed; the
`derived` column therefore reports the MODELED TPU HBM traffic each fused
kernel saves vs the materializing baseline (the §Perf-relevant quantity),
alongside the interpret-mode us_per_call for regression tracking.

The `sel/` rows compare the two SelectionEngine backends end-to-end
(dense |A B^T| -> top_k -> sort vs streaming threshold + compaction):

  * dense peak memory is MEASURED via XLA `memory_analysis()` temp bytes
    (the score matrix really lands in memory);
  * streaming HBM is MODELED as the kernel's actual HBM outputs
    (candidate buffer + counts + histograms) — on CPU the interpreter
    spills the kernel's VMEM-resident intermediates into XLA temps, so
    measured temps would overstate the TPU number by orders of magnitude;
  * index agreement between the two backends is MEASURED per row.

The `shardsel/` rows MODEL the per-device footprint of sharded streaming
selection (DESIGN.md §3): for each density and shard count they record
the compaction candidate-buffer slots one device holds and the
O(compact_factor * k / n_shards) bound it must respect — the schema
validator fails CI if the bound is ever exceeded (`within_bound`), and
the uploaded `BENCH_kernels_micro.json` artifact is the perf trajectory.

The `selstruct/` rows compare STRUCTURED selection (paper App. G.7,
block_size in {1, 4, 8}) end-to-end: dense block-sum + top-k vs the
streaming block-summing kernel pipeline, with a MEASURED `matches_dense`
bit per block size — the schema validator fails CI if streaming
structured selection ever diverges from the dense block path on these
fixed-seed cases.

Machine-readable output: `python -m benchmarks.kernels_micro --json
BENCH_kernels_micro.json` (schema: benchmarks/bench_schema.py).
"""
import argparse

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import csv_rows, timer, write_bench_json
from repro.kernels import ops, ref

SEL_CASES = [(512, 512, 16, 0.01), (512, 512, 16, 0.05),
             (256, 384, 16, 0.2)]


def _selection_rows():
    """Dense top-k vs streaming selection across densities and sizes."""
    rows = []
    for m, n, r, density in SEL_CASES:
        k = int(density * m * n)
        a = jax.random.normal(jax.random.PRNGKey(0), (m, r))
        b = jax.random.normal(jax.random.PRNGKey(1), (n, r))

        dense_fn = jax.jit(lambda a, b: jnp.sort(
            jax.lax.top_k(jnp.abs(a @ b.T).reshape(-1), k)[1]))
        stream_fn = jax.jit(lambda a, b: ops.lift_indices(a, b, k)[0])

        us_dense, idx_dense = timer(
            lambda: jax.block_until_ready(dense_fn(a, b)), reps=3)
        us_stream, idx_stream = timer(
            lambda: jax.block_until_ready(stream_fn(a, b)), reps=1)
        agree = len(np.intersect1d(np.asarray(idx_dense),
                                   np.asarray(idx_stream))) / k

        dense_temp = dense_fn.lower(a, b).compile() \
                             .memory_analysis().temp_size_in_bytes
        bm, bn = ops.pick_block(m), ops.pick_block(n)
        cap = ops.compact_capacity(m, n, k, bm, bn)
        tiles = (m // bm) * (n // bn)
        # streaming HBM outputs: candidate idx buffer + per-tile counts
        # + (passes x) histograms + absmax partials (hist passes = 3x512)
        stream_bytes = tiles * cap * 4 + tiles * 4 \
            + 3 * tiles * 512 * 4 + tiles * 4
        name = f"sel/{m}x{n}-d{density}"
        rows.append({
            "name": name + "-dense_topk", "us_per_call": us_dense,
            "derived": f"temp_bytes_measured={dense_temp};k={k}",
            "metrics": {"temp_bytes_measured": int(dense_temp), "k": k,
                        "density": density}})
        rows.append({
            "name": name + "-streaming", "us_per_call": us_stream,
            "derived": f"hbm_bytes_modeled={stream_bytes};"
                       f"dense_bytes_modeled={m * n * 4 * 2};"
                       f"agree={agree:.5f}",
            "metrics": {"hbm_bytes_modeled": int(stream_bytes),
                        "dense_bytes_modeled": int(m * n * 4 * 2),
                        "agree": float(agree), "k": k,
                        "density": density}})
    return rows


def _structured_rows():
    """Structured (block_size > 1) streaming vs dense block-sum top-k.

    One dense + one streaming row per block size; the streaming row
    carries the MEASURED `matches_dense` bit (bitwise index equality on
    this fixed-seed case) and `agree` — both CI-gated by bench_schema.
    The modeled streaming HBM bytes shrink with bs^2: the candidate
    buffer, histograms and counts all live in block-score space."""
    from repro.core.lift import topk_indices
    rows_out = []
    m, n, r, density = 256, 512, 16, 0.05
    a = jax.random.normal(jax.random.PRNGKey(0), (m, r))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, r))
    for bs in (1, 4, 8):
        k = max(bs * bs, int(density * m * n) // (bs * bs) * (bs * bs))

        dense_fn = jax.jit(lambda a, b, bs=bs, k=k: topk_indices(
            jnp.abs(a @ b.T), k, bs))
        stream_fn = jax.jit(lambda a, b, bs=bs, k=k: ops.lift_indices(
            a, b, k, block_size=bs)[0])

        us_dense, idx_dense = timer(
            lambda: jax.block_until_ready(dense_fn(a, b)), reps=3)
        us_stream, idx_stream = timer(
            lambda: jax.block_until_ready(stream_fn(a, b)), reps=1)
        agree = len(np.intersect1d(np.asarray(idx_dense),
                                   np.asarray(idx_stream))) / k
        matches = bool(np.array_equal(np.asarray(idx_dense),
                                      np.asarray(idx_stream)))

        dense_temp = dense_fn.lower(a, b).compile() \
                             .memory_analysis().temp_size_in_bytes
        bm, bn, cap = ops.select_tiling(m, n, k, bs)
        tiles = (m // min(bm, m)) * (n // min(bn, n))
        stream_bytes = tiles * cap * 4 + tiles * 4 \
            + 3 * tiles * 512 * 4 + tiles * 4
        name = f"selstruct/{m}x{n}-d{density}-bs{bs}"
        rows_out.append({
            "name": name + "-dense_topk", "us_per_call": us_dense,
            "derived": f"temp_bytes_measured={dense_temp};k={k}",
            "metrics": {"temp_bytes_measured": int(dense_temp), "k": k,
                        "block_size": bs, "density": density}})
        rows_out.append({
            "name": name + "-streaming", "us_per_call": us_stream,
            "derived": f"hbm_bytes_modeled={stream_bytes};"
                       f"matches_dense={matches};agree={agree:.5f}",
            "metrics": {"hbm_bytes_modeled": int(stream_bytes),
                        "dense_bytes_modeled": int(m * n * 4 * 2),
                        "agree": float(agree), "matches_dense": matches,
                        "k": k, "block_size": bs, "density": density}})
    return rows_out


def _sharded_rows():
    """Per-device candidate-buffer model for sharded streaming selection.

    Pure capacity arithmetic (no devices needed, so the single-device CI
    job records it too): one row per (geometry, density, n_shards) with
    the modeled buffer and its bound.  `within_bound` is a CI-enforced
    invariant — sharded selection must never materialize a per-device
    buffer beyond O(compact_factor * k / n_shards)."""
    rows = []
    for m, n, _r, density in SEL_CASES:
        k = int(density * m * n)
        for n_shards in (2, 4, 8):
            if n % n_shards:
                continue
            rec = ops.shard_buffer_model(m, n, k, n_shards)
            rows.append({
                "name": f"shardsel/{m}x{n}-d{density}-s{n_shards}",
                "us_per_call": 0.0,
                "derived": f"buffer_slots={rec['buffer_slots_per_device']};"
                           f"bound_slots={rec['bound_slots_per_device']};"
                           f"within_bound={rec['within_bound']}",
                "metrics": {**rec, "k": k, "density": density}})
    return rows


def run():
    rows = []
    m, n, r = 1024, 1024, 136
    a = jax.random.normal(jax.random.PRNGKey(0), (m, r))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, r))
    k = int(0.05 * m * n)

    us_ref, _ = timer(lambda: jax.block_until_ready(
        ref.lowrank_abs(a, b)), reps=3)
    us_mask, _ = timer(lambda: jax.block_until_ready(
        ops.lift_mask(a, b, k, bm=256, bn=256)[0]), reps=1)
    # modeled HBM traffic: baseline materializes m*n f32 scores (write+read
    # for the top-k) + mask; fused path writes only the bool mask
    base_bytes = m * n * 4 * 2 + m * n
    fused_bytes = m * n  # bool mask only (3 streaming passes stay in VMEM)
    rows.append({"name": "kern/lift_mask-1024x1024",
                 "us_per_call": us_mask,
                 "derived": f"hbm_saved={(base_bytes - fused_bytes)/2**20:.1f}"
                            f"MiB;ref_abs_us={us_ref:.0f}",
                 "metrics": {"hbm_saved_bytes": int(base_bytes - fused_bytes),
                             "ref_abs_us": float(us_ref)}})

    N, kk = 2 ** 20, 2 ** 15
    p = jax.random.normal(jax.random.PRNGKey(2), (N,))
    g = jax.random.normal(jax.random.PRNGKey(3), (N,))
    idx = jnp.sort(jax.random.choice(jax.random.PRNGKey(4), N, (kk,),
                                     replace=False)).astype(jnp.int32)
    mm = jnp.zeros((kk,))
    vv = jnp.zeros((kk,))
    us_k, _ = timer(lambda: jax.block_until_ready(
        ops.sparse_adam(p, g, idx, mm, vv, 1, lr=1e-3, bn=8192,
                        exact=False)[0]), reps=1)
    us_r, _ = timer(lambda: jax.block_until_ready(
        ref.sparse_adam(p, g, idx, mm, vv, lr=1e-3, b1=0.9, b2=0.999,
                        eps=1e-8, wd=0.0, step=1)[0]), reps=3)
    # dense-masked adam would stream 2 fp32 moment vectors of size N;
    # sparse layout streams k-sized vectors
    saved = 2 * 4 * (N - kk)
    rows.append({"name": "kern/sparse_adam-1M",
                 "us_per_call": us_k,
                 "derived": f"state_saved={saved/2**20:.1f}MiB;"
                            f"ref_us={us_r:.0f}",
                 "metrics": {"state_saved_bytes": int(saved),
                             "ref_us": float(us_r)}})
    rows.extend(_selection_rows())
    rows.extend(_structured_rows())
    rows.extend(_sharded_rows())
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="also write the machine-readable artifact "
                         "(BENCH_kernels_micro.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="kernels_micro")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
