"""App. G.5 toy model, reproduced EXACTLY as specified: two-layer net
f(X) = sigma(X W) a, d=512 h=128, pre-train 5000 samples on the linear+sin
labels, fine-tune 100 samples on the cubic labels; compare Full FT vs LIFT
vs magnitude vs gradient sparse FT.  Paper: Full FT overfits, LIFT attains
the lowest validation loss and the lowest spectral norm.
derived = validation loss (lower is better)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_rows
from repro.core.lift import LiftConfig, scores_for, topk_indices
from repro.core.lowrank import spectral_norm

D, H, N_PRE, N_FT = 512, 128, 5000, 100


def labels_pre(x):
    return x[:, :32].sum(1) + 0.1 * jnp.sin(x[:, 32:64]).sum(1)


def labels_ft(x):
    return (0.2 * x[:, 64] * x[:, 65] * x[:, 66]
            + 0.1 * jnp.sin(x[:, 67] * x[:, 68]))


def net(params, x):
    return jnp.tanh(x @ params["w"]) @ params["a"]


def mse(params, x, y):
    return jnp.mean((net(params, x)[:, 0] - y) ** 2)


def adamw_train(params, x, y, xv, yv, steps, lr, mask=None):
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    best, best_params = np.inf, params
    gfn = jax.jit(jax.grad(mse))
    vfn = jax.jit(mse)
    patience, strikes = 40, 0
    for t in range(1, steps + 1):
        g = gfn(params, x, y)
        if mask is not None:
            g = {"w": g["w"] * mask, "a": g["a"]* 0.0}
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + 0.1 * gg, m, g)
        v = jax.tree.map(lambda vv, gg: 0.999 * vv + 0.001 * gg * gg, v, g)
        mh = jax.tree.map(lambda mm: mm / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda vv: vv / (1 - 0.999 ** t), v)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + 1e-8),
            params, mh, vh)
        val = float(vfn(params, xv, yv))
        if val < best - 1e-6:
            best, best_params, strikes = val, params, 0
        else:
            strikes += 1
            if strikes > patience:  # early stopping (paper setup)
                break
    return best_params, best


def run():
    key = jax.random.PRNGKey(0)
    xp = jax.random.normal(key, (N_PRE, D))
    yp = labels_pre(xp)
    xf = jax.random.normal(jax.random.PRNGKey(1), (N_FT, D))
    yf = labels_ft(xf)
    xv = jax.random.normal(jax.random.PRNGKey(2), (1000, D))
    yv = labels_ft(xv)

    params = {"w": 0.05 * jax.random.normal(jax.random.PRNGKey(3), (D, H)),
              "a": 0.05 * jax.random.normal(jax.random.PRNGKey(4), (H, 1))}
    params, _ = adamw_train(params, xp, yp, xp[:500], yp[:500],
                            steps=400, lr=3e-3)

    rows = []
    density = 0.05
    k = int(density * D * H)
    g0 = jax.grad(mse)(params, xf, yf)["w"]
    for sel in ["full", "lift", "magnitude", "gradient"]:
        if sel == "full":
            mask = None
        else:
            s = scores_for(params["w"], LiftConfig(rank=16, method="exact"),
                           sel, jax.random.PRNGKey(5), grad2d=g0)
            idx = topk_indices(s, k)
            mask = jnp.zeros(D * H).at[idx].set(1.0).reshape(D, H)
        ft, val = adamw_train(dict(params), xf, yf, xv, yv,
                              steps=300, lr=2e-3, mask=mask)
        sn = float(spectral_norm(ft["w"]))
        rows.append({"name": f"toyG5/{sel}", "us_per_call": 0.0,
                     "derived": f"val={val:.4f};spectral={sn:.3f}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
