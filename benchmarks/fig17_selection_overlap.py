"""App. G.9 analog (Fig. 17): overlap of LIFT-selected vs magnitude-selected
parameters (paper: small — 5-20 % on MLP, up to 40 % on Q/K — and growing
with the LRA rank), PLUS the framework's local-quota-vs-global overlap
(DESIGN.md §3 distributed selection).  derived = overlap fractions."""
import numpy as np

from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.core.lift import LiftConfig, scores_for, topk_indices
from repro.core.local_quota import overlap_with_global


def run():
    out = train_method(SMALL, make_method("full"), task="lm", steps=40,
                       eval_n=0)
    params = out["params"]
    rows = []
    for layer, w in [("mlp-up", params["blocks"]["mlp"]["up"][0]),
                     ("attn-wq", params["blocks"]["attn"]["wq"][0])]:
        k = int(0.05 * w.size)
        mag = set(np.asarray(topk_indices(
            scores_for(w, LiftConfig(rank=8), "magnitude"), k)).tolist())
        parts = []
        for rank in (4, 8, 16):
            lift = set(np.asarray(topk_indices(scores_for(
                w, LiftConfig(rank=rank, method="exact"), "lift"),
                k)).tolist())
            parts.append(f"r{rank}={len(lift & mag) / k:.2f}")
        rows.append({"name": f"fig17/lift-vs-magnitude-{layer}",
                     "us_per_call": 0.0, "derived": ";".join(parts)})
    # distributed local-quota deviation (beyond-paper, DESIGN.md §3)
    w = params["blocks"]["mlp"]["up"][0]
    s = scores_for(w, LiftConfig(rank=8, method="exact"), "lift")
    k = 1024
    parts = [f"shards{n}={overlap_with_global(s, k, n):.3f}"
             for n in (4, 8, 16)]
    rows.append({"name": "fig17/local-quota-vs-global",
                 "us_per_call": 0.0, "derived": ";".join(parts)})
    return rows


if __name__ == "__main__":
    csv_rows(run())
