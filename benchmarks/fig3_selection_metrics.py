"""Fig. 3 analog: sparse-FT selection criteria at a fixed parameter budget
(GSM8K stand-in = synthetic arithmetic).  derived = eval accuracy."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method


def run():
    rows = []
    for sel in ["lift", "magnitude", "gradient", "movement", "random"]:
        kind = "lift" if sel == "lift" else sel
        out = train_method(SMALL, make_method(kind), task="arith",
                           steps=150, refresh_every=25, seed=1)
        rows.append({
            "name": f"fig3/select-{sel}",
            "us_per_call": out["us_per_step"],
            "derived": f"acc={out['eval_acc']:.3f}",
        })
    return rows


if __name__ == "__main__":
    csv_rows(run())
