"""Fig. 6 analog: optimizer-state memory breakdown at PAPER scale
(llama2-7b / llama3-8b-class configs, analytic — no allocation) plus a
measured check on the smoke model.  Paper: Full FT 27 GB optimizer ->
LIFT ~1.3 GB (<5 %).  derived = optimizer-state gigabytes."""
import jax
import numpy as np

from benchmarks.common import SMALL, csv_rows, make_method, train_method
from repro.configs import get_arch
from repro.core.lift import LiftConfig, make_plan
from repro.models import build_model
from repro.nn.core import is_spec


def _spec_bytes(spec_tree, per_leaf=4):
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * per_leaf for s in leaves)


def analytic(arch: str):
    cfg = get_arch(arch).full
    model = build_model(cfg)
    spec = model.spec()
    n_params = sum(int(np.prod(s.shape))
                   for s in jax.tree.leaves(spec, is_leaf=is_spec))
    full_opt = 2 * 4 * n_params                       # fp32 m+v
    lcfg = LiftConfig(rank=128, density=0.05, k_multiple=1024)
    plan = make_plan(spec, lcfg)
    k_total = sum(p.k * max(1, int(np.prod(p.stack))) for p in plan.values())
    lift_opt = k_total * (4 + 4 + 4)                  # idx + m + v
    lora_r = 128
    lora_params = sum((p.rows + p.cols) * lora_r
                      * max(1, int(np.prod(p.stack))) for p in plan.values())
    lora_opt = 2 * 4 * lora_params
    return n_params, full_opt, lift_opt, lora_opt


def run():
    rows = []
    n, full_b, lift_b, lora_b = analytic("llama2-7b")
    g = 1 / 2 ** 30
    rows.append({"name": "fig6/llama2-7b-analytic", "us_per_call": 0.0,
                 "derived": f"fullFT={full_b * g:.1f}GB;"
                            f"LIFT={lift_b * g:.2f}GB"
                            f"({100 * lift_b / full_b:.1f}%);"
                            f"LoRA={lora_b * g:.2f}GB"})
    # measured on the smoke model

    def opt_bytes(state):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(state["opt"]))
    for kind in ["full", "lift"]:
        out = train_method(SMALL, make_method(kind), task="arith", steps=4,
                           eval_n=0)
        rows.append({"name": f"fig6/smoke-{kind}-measured",
                     "us_per_call": out["us_per_step"],
                     "derived": f"opt_bytes={opt_bytes(out['state'])}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
