"""Fig. 7 analogs.  (a) LIFT mask update interval sweep;
(b) rank-reduction strategies (largest / smallest / random / hybrid).
derived = eval accuracy."""
from benchmarks.common import SMALL, csv_rows, make_method, train_method


def run():
    rows = []
    for interval in [10, 25, 50, 10_000]:
        out = train_method(SMALL, make_method("lift"), task="arith",
                           steps=120, refresh_every=min(interval, 80),
                           seed=2)
        tag = "never" if interval >= 10_000 else str(interval)
        rows.append({"name": f"fig7a/interval-{tag}",
                     "us_per_call": out["us_per_step"],
                     "derived": f"acc={out['eval_acc']:.3f}"})
    for strat in ["largest", "smallest", "random", "hybrid"]:
        out = train_method(SMALL, make_method("lift", strategy=strat),
                           task="arith", steps=120, refresh_every=25, seed=2)
        rows.append({"name": f"fig7b/strategy-{strat}",
                     "us_per_call": out["us_per_step"],
                     "derived": f"acc={out['eval_acc']:.3f}"})
    return rows


if __name__ == "__main__":
    csv_rows(run())
