"""Quantized-base serving benchmarks (DESIGN.md §12) — BENCH_quant.json.

The serving claim of the quantized base: int8 resident projections plus
the fp32 principal-weight overlay cost a fraction of dense fp32
residency WITHOUT moving a greedy token.  Four CI-gated row families
(schema: benchmarks/bench_schema.py):

  * `residency/` — measured HBM bytes of the quantized operand set
    (int8 q + scales + overlay idx/val) vs the dense fp32 leaves it
    replaces; `hbm_bytes_ratio` <= 0.55 is the gate (the overlay at 5 %
    density costs 8 bytes/entry on top of 1 byte/weight);
  * `parity/` — the fused dequant-scatter-matmul Pallas kernel
    (`kernels/quant_matmul.py`, interpret mode on CPU) and the exact
    lax fallback vs the `kernels.ref.quant_matmul` dense oracle, with
    and without a per-slot adapter delta in the epilogue — the contract
    is BITWISE (`matches_ref`), incl. a block size that does not divide
    the column count;
  * `divergence/` — per-position max |logit - fp32 logit| over a fixed
    prompt batch stays under the committed `bound` (the bound itself is
    baseline-guarded and can never loosen; the measured value is
    drift-guarded at +25 %);
  * `identity/` — greedy decode over the quantized base reproduces the
    fp32 reference token streams exactly through BOTH engines, and a
    decode batch MIXING >= 2 pool adapters per step over the int8 base
    matches fp32 merge-on-load AdapterStore serving token for token.

Machine-readable output: `python -m benchmarks.quant --json
BENCH_quant.json` (schema: benchmarks/bench_schema.py).
"""
import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (SMALL, csv_rows, make_method, train_method,
                               write_bench_json)
from repro.kernels import ops, ref
from repro.quant import QuantConfig, hbm_bytes_ratio, quantize

DENSITY = 0.05
BOUND = 0.25          # committed max-logit-divergence bound (fp32 ref)
SLOTS = 4
REQUESTS = 6
MAX_LEN = 128
MAX_NEW = 16
PAGE_SIZE = 16
KV_PAGES = 48

# kernel-parity sweep: (label, x dtype, scale_mode, with per-slot delta,
# block size) — bn=40 does not divide cols, exercising the padded tail
PARITY_CASES = [
    ("f32-perchan", np.float32, "per-channel", False, 32),
    ("bf16-pertensor", jnp.bfloat16, "per-tensor", False, 32),
    ("f32-perchan-delta", np.float32, "per-channel", True, 32),
    ("f32-perchan-bn40", np.float32, "per-channel", True, 40),
]


def _quant_case(dtype, scale_mode, with_delta, seed=0, b=3, rows=64,
                cols=96, k=24, kd=8):
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, size=(rows, cols)).astype(np.int8)
    scol = cols if scale_mode == "per-channel" else 1
    scale = (rng.uniform(0.5, 2.0, size=(1, scol)) / 127.0).astype(
        np.float32)
    idx = np.sort(rng.choice(rows * cols, k, replace=False)).astype(
        np.int32)
    val = rng.normal(size=(k,)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(b, rows)).astype(np.float32),
                    dtype=dtype)
    didx = dval = None
    if with_delta:
        didx = np.stack([np.sort(rng.choice(rows * cols, kd,
                                            replace=False))
                         for _ in range(b)]).astype(np.int32)
        dval = rng.normal(size=(b, kd)).astype(np.float32)
        didx, dval = jnp.asarray(didx), jnp.asarray(dval)
    qw = {"q": jnp.asarray(q), "scale": jnp.asarray(scale),
          "idx": jnp.asarray(idx), "val": jnp.asarray(val)}
    return x, qw, didx, dval


def parity_rows():
    rows = []
    for label, dtype, scale_mode, with_delta, bn in PARITY_CASES:
        x, qw, didx, dval = _quant_case(dtype, scale_mode, with_delta)
        want = ref.quant_matmul(x, qw["q"], qw["scale"], qw["idx"],
                                qw["val"], didx, dval)
        lax = ops.quant_matmul(x, qw, didx, dval, backend="lax")
        t0 = time.perf_counter()
        ker = ops.quant_matmul(x, qw, didx, dval, backend="kernel",
                               bn=bn, interpret=True)
        jax.block_until_ready(ker)
        dt = time.perf_counter() - t0
        m_lax = bool(np.array_equal(np.asarray(lax), np.asarray(want)))
        m_ker = bool(np.array_equal(np.asarray(ker), np.asarray(want)))
        rows.append({
            "name": f"parity/{label}",
            "us_per_call": dt * 1e6,
            "derived": f"matches_ref={m_lax and m_ker};"
                       f"lax={m_lax};kernel={m_ker};bn={bn}",
            "metrics": {"matches_ref": m_lax and m_ker,
                        "matches_lax": m_lax, "matches_kernel": m_ker,
                        "bn": bn, "scale_mode": scale_mode,
                        "with_delta": bool(with_delta)}})
    return rows


def _prompts(n, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(4, 60, size=n)]


def _serve_greedy(eng, prompts, adapter_ids=None):
    """Greedy-only serve (token identity under quantization holds at
    temperature 0; sampled streams see different logits by design),
    tracking the peak distinct adapters decoding in one step."""
    from repro.serving import Request
    aids = adapter_ids or [None] * len(prompts)
    for i, (p, a) in enumerate(zip(prompts, aids)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=MAX_NEW,
                           temperature=0.0, adapter_id=a))
    mixed, steps = 0, 0
    t0 = time.perf_counter()
    if not hasattr(eng, "sched"):       # dense oracle: no step-level view
        done = eng.run()
        dt = time.perf_counter() - t0
        return {r.uid: tuple(r.out_tokens) for r in done}, 0, dt
    while eng.sched.has_work() and steps < 100_000:
        eng.step()
        steps += 1
        live = {s.req.adapter_id for s in eng.sched.seqs
                if s is not None and s.phase == "decode"
                and s.req.adapter_id is not None}
        mixed = max(mixed, len(live))
    dt = time.perf_counter() - t0
    return {r.uid: tuple(r.out_tokens) for r in eng.done}, mixed, dt


def run():
    from repro.serving import AdapterStore, ServingConfig, make_engine
    from repro.serving.kvpool import AdapterPool
    from repro.serving.oracle import DenseOracle
    rows = parity_rows()

    # a briefly-trained model, not random init: the identity rows are a
    # claim about argmax margins, and random-init logits are near-ties
    # everywhere — any quantizer "passes" or "fails" them by luck.  A
    # trained model has decisive margins, so greedy identity measures
    # the quantizer, not the init.
    trained = train_method(SMALL, make_method("full"), task="arith",
                           steps=100, batch=8, seq=48, eval_n=0)
    model, params = trained["model"], trained["params"]
    art = quantize(model, params, QuantConfig(density=DENSITY),
                   jax.random.PRNGKey(1))
    ratio = hbm_bytes_ratio(art)
    overlay_entries = sum(int(t["val"].size) for t in art.tensors.values())
    qparams = art.to_params(params)
    rows.append({
        "name": f"residency/small-d{DENSITY}",
        "us_per_call": 0.0,
        "derived": f"hbm_bytes_ratio={ratio:.4f};"
                   f"tensors={len(art.tensors)};"
                   f"overlay_entries={overlay_entries}",
        "metrics": {"hbm_bytes_ratio": float(ratio),
                    "tensors": len(art.tensors),
                    "overlay_entries": overlay_entries,
                    "resident_bytes": int(art.resident_nbytes()),
                    "dense_bytes": int(art.dense_nbytes()),
                    "density": DENSITY,
                    "scale_mode": art.manifest["scale_mode"]}})

    # per-position logit divergence vs the fp32 reference forward
    rng = np.random.default_rng(7)
    toks = rng.integers(3, 90, size=(4, 48)).astype(np.int32)
    lf = np.asarray(model.logits(params, {"tokens": toks}),
                    np.float32)
    lq = np.asarray(model.logits(qparams, {"tokens": toks}), np.float32)
    div = float(np.max(np.abs(lf - lq)))
    rows.append({
        "name": f"divergence/logits-d{DENSITY}",
        "us_per_call": 0.0,
        "derived": f"max_logit_divergence={div:.5f};bound={BOUND};"
                   f"within_bound={div <= BOUND}",
        "metrics": {"max_logit_divergence": div, "bound": BOUND,
                    "within_bound": div <= BOUND,
                    "positions": int(lf.shape[0] * lf.shape[1]),
                    "density": DENSITY}})

    # greedy token identity through BOTH engines: quantized base vs the
    # fp32 reference serve of the same prompt mix
    prompts = _prompts(REQUESTS)
    ecfg = ServingConfig(batch_slots=SLOTS, max_len=MAX_LEN, eos_id=2)
    pcfg = ServingConfig(batch_slots=SLOTS, max_len=MAX_LEN, eos_id=2,
                         page_size=PAGE_SIZE, num_pages=KV_PAGES)
    for label, mk in (
            ("dense", lambda p: DenseOracle(model, p, ecfg)),
            ("paged", lambda p: make_engine(model, p, pcfg))):
        want, _, _ = _serve_greedy(mk(params), prompts)
        got, _, dt = _serve_greedy(mk(qparams), prompts)
        matches = bool(got == want)
        rows.append({
            "name": f"identity/greedy-{label}",
            "us_per_call": dt * 1e6,
            "derived": f"matches_ref={matches};requests={REQUESTS}",
            "metrics": {"matches_ref": matches, "requests": REQUESTS,
                        "concurrency": SLOTS, "engine": label,
                        "density": DENSITY}})

    # mixed-adapter decode batch over the int8 base (pool composition in
    # the quant epilogue) vs fp32 merge-on-load AdapterStore serving
    from benchmarks.delta_merge import (POOL_ENTRIES, _plan_meta,
                                        _synthetic_adapter)
    from repro.deltas.format import tree_hash
    base_hash = tree_hash(params)
    meta = _plan_meta(model, DENSITY)
    arts = {aid: _synthetic_adapter(params, base_hash, meta, seed)
            for aid, seed in (("a", 1), ("b", 2))}
    ipool = AdapterPool(params, num_pages=24, entries_per_page=POOL_ENTRIES)
    store = AdapterStore(params)
    for aid, a in arts.items():
        ipool.register(aid, a)
        store.load(aid, a)
    eng_q = make_engine(model, qparams, pcfg, adapter_pool=ipool)
    eng_ref = make_engine(model, params, pcfg, adapters=store)
    aids = [("a", "b", None)[i % 3] for i in range(REQUESTS)]
    want, _, _ = _serve_greedy(eng_ref, prompts, aids)
    got, mixed, dt = _serve_greedy(eng_q, prompts, aids)
    matches = bool(got == want)
    rows.append({
        "name": "identity/pool-mixed-int8",
        "us_per_call": dt * 1e6,
        "derived": f"matches_ref={matches};adapters_mixed={mixed};"
                   f"requests={REQUESTS}",
        "metrics": {"matches_ref": matches, "adapters_mixed": int(mixed),
                    "requests": REQUESTS, "concurrency": SLOTS,
                    "density": DENSITY}})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_quant.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="quant")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
