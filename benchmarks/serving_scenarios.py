"""Fleet-style serving scenarios over the unified paged engine.

Seeded workload generator driven through `launch/serve.py`'s
`build_parser()` / `build_engine_from_args()` pipeline (docs/CI.md):

  * oneshot/reasoning    — mixed prompt/output-length one-shot stream;
  * chat/prefix_heavy    — chat turns sharing a long system prefix
                           through the refcounted prefix cache;
  * adapters/zipf        — zipf-popularity adapter mix over synthetic
                           LIFT delta artifacts (DeltaHub round-trip:
                           save to disk, serve via --delta);
  * storm/preemption     — an undersized pool forces checkpoint/
                           preempt/restore churn; streams must match a
                           roomy-pool reference bitwise;
  * elastic/restart      — `ft.PreemptionSimulator` kills the engine
                           mid-stream; a rebuilt engine resumes the
                           unfinished requests and the union of streams
                           must equal an uninterrupted reference.

Every scenario runs twice from the same seed and reports
`deterministic` (identical token streams).  Latency percentiles and
tok/s ride along for the uploaded trajectory but are NEVER gated
(interpret-mode wall time is noise); the gated metrics are the
determinism bits and the ratio metrics (preemption_rate,
page_hit_rate, peak_pool_occupancy) — see `bench_schema.py` and
`compare.py`.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from benchmarks.common import csv_rows, write_bench_json
from repro.ft import PreemptionSimulator
from repro.launch.serve import build_engine_from_args, build_parser
from repro.serving import Request

ARCH = ["--arch", "qwen3-1.7b", "--smoke"]
SEED = 0


def _parse(extra):
    return build_parser().parse_args(ARCH + ["--seed", str(SEED)] + extra)


def _engine(extra):
    eng, _, cfg = build_engine_from_args(_parse(extra), None)
    return eng, cfg


# ------------------------------------------------------------ workloads
def _requests(specs):
    """specs: list of (uid, prompt, max_new, temperature, adapter_id)."""
    return [Request(uid=u, prompt=np.asarray(p, np.int32),
                    max_new_tokens=m, temperature=t, adapter_id=a)
            for (u, p, m, t, a) in specs]


def _oneshot_specs(vocab, n=12):
    """One-shot reasoning mix: short chat-y prompts interleaved with
    long chain-of-thought prompts, varied output budgets, mixed
    greedy/sampled temperatures."""
    rng = np.random.default_rng(SEED)
    specs = []
    for i in range(n):
        long = i % 3 == 2
        plen = int(rng.integers(24, 48) if long else rng.integers(6, 14))
        prompt = rng.integers(5, vocab, size=plen)
        specs.append((i, prompt, int(rng.integers(6, 18)),
                      0.0 if i % 2 == 0 else 0.8, None))
    return specs


def _chat_specs(vocab, n=10, prefix_len=32):
    """Prefix-heavy chat: every turn shares one long system prefix."""
    rng = np.random.default_rng(SEED)
    prefix = rng.integers(5, vocab, size=prefix_len)
    specs = []
    for i in range(n):
        turn = rng.integers(5, vocab, size=int(rng.integers(4, 12)))
        specs.append((i, np.concatenate([prefix, turn]), 8,
                      0.0 if i % 2 == 0 else 0.7, None))
    return specs


def _zipf_choice(rng, n_items, a=1.5):
    """Zipf-popularity index in [0, n_items): rank 0 dominates."""
    return min(int(rng.zipf(a)) - 1, n_items - 1)


def _drive(eng, reqs):
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    dt = time.perf_counter() - t0
    bad = [r for r in done if getattr(r, "error", None)]
    if bad:
        raise RuntimeError(f"request(s) failed: {bad[0].error}")
    streams = {r.uid: tuple(r.out_tokens) for r in done}
    return streams, dt


def _row(name, eng, streams, dt, n_reqs, *, extra=None, derived_extra=""):
    snap = eng.metrics_snapshot()
    lat = snap["histograms"].get("serve.request_latency_s", {})
    st = eng.kv_stats()
    tokens = sum(len(s) for s in streams.values())
    metrics = {
        "requests": n_reqs,
        "tokens": tokens,
        "p50_latency_s": float(lat.get("p50", 0.0)),
        "p99_latency_s": float(lat.get("p99", 0.0)),
        "tok_s": tokens / max(dt, 1e-9),
        "preemption_rate": st["preemptions"] / n_reqs,
        "page_hit_rate": 0.0,
        "peak_pool_occupancy": st["peak_pages_in_use"] / st["num_pages"],
    }
    if extra:
        metrics.update(extra)
    derived = (f"tok_s={metrics['tok_s']:.1f};"
               f"preempt={metrics['preemption_rate']:.2f};"
               f"occ={metrics['peak_pool_occupancy']:.2f}" + derived_extra)
    return {"name": name, "us_per_call": dt / n_reqs * 1e6,
            "derived": derived, "metrics": metrics}


# ------------------------------------------------------------ scenarios
def _oneshot_row():
    flags = ["--slots", "4", "--max-len", "96", "--pages", "64",
             "--page-size", "16"]
    eng, cfg = _engine(flags)
    specs = _oneshot_specs(cfg.vocab_size)
    streams, dt = _drive(eng, _requests(specs))
    again, _ = _drive(_engine(flags)[0], _requests(specs))
    return _row("oneshot/reasoning-mixed-lengths", eng, streams, dt,
                len(specs), extra={"deterministic": streams == again})


def _chat_row():
    flags = ["--slots", "4", "--max-len", "96", "--pages", "64",
             "--page-size", "16", "--prefix-cache"]
    eng, cfg = _engine(flags)
    specs = _chat_specs(cfg.vocab_size)
    streams, dt = _drive(eng, _requests(specs))
    again, _ = _drive(_engine(flags)[0], _requests(specs))
    st = eng.kv_stats()
    prompt_pages = sum(len(p) // 16 for (_, p, _, _, _) in specs)
    row = _row("chat/prefix-heavy", eng, streams, dt, len(specs),
               extra={"deterministic": streams == again,
                      "prefix_hits": st["prefix_hits"]},
               derived_extra=f";prefix_hits={st['prefix_hits']}")
    row["metrics"]["page_hit_rate"] = st["prefix_hits"] / prompt_pages
    return row


def _zipf_row():
    rng = np.random.default_rng(SEED)
    with tempfile.TemporaryDirectory() as td:
        dirs = _save_synthetic_adapters(td, n=3)
        flags = (["--slots", "4", "--max-len", "96", "--pages", "64",
                  "--page-size", "16"]
                 + [f for d in dirs for f in ("--delta", d)])
        eng, cfg = _engine(flags)
        specs = []
        served = set()
        for i in range(10):
            aid = f"delta{_zipf_choice(rng, len(dirs))}"
            served.add(aid)
            prompt = rng.integers(5, cfg.vocab_size,
                                  size=int(rng.integers(6, 20)))
            specs.append((i, prompt, 8, 0.0 if i % 2 == 0 else 0.8, aid))
        streams, dt = _drive(eng, _requests(specs))
        again, _ = _drive(_engine(flags)[0], _requests(specs))
    return _row("adapters/zipf-popularity-mix", eng, streams, dt,
                len(specs),
                extra={"deterministic": streams == again,
                       "adapters_served": len(served)},
                derived_extra=f";adapters={len(served)}")


def _save_synthetic_adapters(td, n):
    """Synthetic LIFT fine-tunes with the geometry of `deltas.extract`
    (mode="replace" at 5%-density principal positions), saved to disk so
    the scenario exercises the real --delta load path."""
    import jax

    from repro.core.lift import LiftConfig, get_by_path, make_plan
    from repro.deltas import DeltaArtifact, tree_hash
    from repro.deltas.format import make_manifest, num_stack
    from repro.models import build_model

    args = _parse([])
    from repro.configs import get_arch
    cfg = get_arch(args.arch).smoke
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(SEED))
    plan = make_plan(model.spec(), LiftConfig(density=0.05, min_dim=16))
    meta = {p: {"shape": list(t.shape), "stack": list(t.stack),
                "rows": t.rows, "cols": t.cols, "k": t.k,
                "dtype": "float32"}
            for p, t in sorted(plan.items())}
    base_hash = tree_hash(params)
    dirs = []
    for j in range(n):
        rng = np.random.default_rng(100 + j)
        tensors = {}
        for path, m in meta.items():
            ns, k, size = num_stack(m), m["k"], m["rows"] * m["cols"]
            idx = np.stack([np.sort(rng.choice(size, k, replace=False))
                            for _ in range(ns)]).astype(np.int32)
            base = np.asarray(get_by_path(params, path),
                              np.float32).reshape(ns, size)
            val = (np.take_along_axis(base, idx, 1)
                   + rng.normal(scale=0.05, size=(ns, k))
                   ).astype(np.float32)
            tensors[path] = {"idx": idx, "val": val}
        art = DeltaArtifact(
            manifest=make_manifest(mode="replace", base_hash=base_hash,
                                   selection=None, tensors_meta=meta,
                                   step=0),
            tensors=tensors)
        d = os.path.join(td, f"delta{j}")
        art.save(d)
        dirs.append(d)
    return dirs


def _storm_row():
    """Preemption storm: long decodes through a pool sized barely above
    the one-sequence floor, so page growth keeps evicting the youngest
    sequence; a roomy-pool run is the bitwise reference."""
    tiny = ["--slots", "4", "--max-len", "96", "--pages", "16",
            "--page-size", "8"]
    roomy = ["--slots", "4", "--max-len", "96", "--pages", "96",
             "--page-size", "8"]
    rng = np.random.default_rng(SEED)
    eng, cfg = _engine(tiny)
    specs = [(i, rng.integers(5, cfg.vocab_size,
                              size=int(rng.integers(8, 24))),
              24, 0.0 if i % 2 == 0 else 0.8, None)
             for i in range(8)]
    streams, dt = _drive(eng, _requests(specs))
    again, _ = _drive(_engine(tiny)[0], _requests(specs))
    ref, _ = _drive(_engine(roomy)[0], _requests(specs))
    return _row("storm/preemption-tight-pool", eng, streams, dt,
                len(specs),
                extra={"deterministic": streams == again,
                       "matches_ref": streams == ref},
                derived_extra=f";matches_ref={streams == ref}")


def _elastic_row():
    """Elastic restart: a simulated preemption (`ft/resilience.py`)
    kills the serving loop mid-stream; the harness rebuilds the engine
    through the same `launch/serve.py` pipeline and resubmits the
    unfinished requests.  Per-request sampling streams are keyed by
    (seed, uid), so the union of pre-crash completions and post-restart
    completions must equal an uninterrupted run bitwise."""
    flags = ["--slots", "4", "--max-len", "96", "--pages", "64",
             "--page-size", "16"]
    rng = np.random.default_rng(SEED)
    eng, cfg = _engine(flags)
    specs = [(i, rng.integers(5, cfg.vocab_size,
                              size=int(rng.integers(6, 20))),
              12, 0.0 if i % 2 == 0 else 0.8, None)
             for i in range(10)]
    sim = PreemptionSimulator(crash_at_step=18)
    t0 = time.perf_counter()
    for r in _requests(specs):
        eng.submit(r)
    step = 0
    crashed = False
    try:
        while eng.sched.has_work():
            sim.check(step)
            eng.step()
            step += 1
    except SystemExit:
        crashed = True
    finished = {r.uid: tuple(r.out_tokens) for r in eng.done
                if not getattr(r, "error", None)}
    # restart: a fresh engine (same pipeline, same config) takes over
    # the requests the crashed engine never finished
    eng2, _ = _engine(flags)
    redo = [s for s in specs if s[0] not in finished]
    streams2, _ = _drive(eng2, _requests(redo))
    union = dict(finished)
    union.update(streams2)
    dt = time.perf_counter() - t0
    ref, _ = _drive(_engine(flags)[0], _requests(specs))
    again = dict(finished)
    again.update(_drive(_engine(flags)[0], _requests(redo))[0])
    return _row("elastic/restart-mid-stream", eng2, union, dt,
                len(specs),
                extra={"deterministic": union == again,
                       "restart_matches": union == ref,
                       "crashed": crashed,
                       "resubmitted": len(redo)},
                derived_extra=f";resubmitted={len(redo)};"
                              f"restart_matches={union == ref}")


def run():
    return [_oneshot_row(), _chat_row(), _zipf_row(), _storm_row(),
            _elastic_row()]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_serving_scenarios.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="serving_scenarios")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
