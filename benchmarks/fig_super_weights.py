"""Super-weight survival under rank reduction (DESIGN.md §12).

The quantized-base overlay keeps two ingredients in high precision: the
top-density entries of the rank-reduced LIFT score |W'| (the Principal
Weights, paper eq. 2) and the super-weight outliers (|w| above a sigma
threshold).  This figure checks the part the paper's thesis rests on:
rank reduction does NOT wash out the outliers that dominate quantization
error.  Gaussian weight matrices get super-weight entries (~50 sigma)
injected into a handful of columns; at every paper rank the rank-r
score must place ALL of them inside the top-5% mask — `run()` asserts
it, so `benchmarks/run.py` fails if rank reduction ever loses one.

A final row drives `repro.quant.quantize.principal_indices` (the
quantizer's actual selection, sigma guard on): even with the guard
DISABLED the outliers survive scoring; with it on they are guaranteed
regardless of rank — both facts are asserted.

Machine-readable output: `python -m benchmarks.fig_super_weights --json
BENCH_fig_super_weights.json` (schema: benchmarks/bench_schema.py).
"""
import argparse
import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import csv_rows, write_bench_json
from repro.core.lift import LiftConfig, scores_for, topk_indices
from repro.quant.quantize import principal_indices

ROWS, COLS = 256, 512
SIGMA = 0.02                 # bulk weight scale
RANKS = (4, 8, 16, 32)       # paper operating ranks
DENSITY = 0.05               # top-5% mask
OUTLIER_COLS = (7, 133, 310, 471)
OUTLIERS_PER_COL = 4
OUTLIER_SIGMA = 50.0         # injected |w| in bulk-sigma units


def _matrix(seed=0):
    """Gaussian bulk + injected super-weight outliers; returns the
    matrix and the sorted flat indices of the injected entries."""
    rng = np.random.default_rng(seed)
    w = rng.normal(scale=SIGMA, size=(ROWS, COLS)).astype(np.float32)
    injected = []
    for c in OUTLIER_COLS:
        for r in rng.choice(ROWS, OUTLIERS_PER_COL, replace=False):
            sign = 1.0 if rng.random() < 0.5 else -1.0
            w[r, c] = sign * OUTLIER_SIGMA * SIGMA * (1.0 + rng.random())
            injected.append(r * COLS + c)
    return w, np.unique(np.asarray(injected, np.int64))


def run():
    w, injected = _matrix()
    wj = jnp.asarray(w)
    k = int(DENSITY * ROWS * COLS)
    rows = []
    for rank in RANKS:
        cfg = LiftConfig(rank=rank, density=DENSITY, method="exact",
                         min_dim=16)
        t0 = time.perf_counter()
        mask = np.asarray(topk_indices(scores_for(wj, cfg, "lift"), k))
        dt = time.perf_counter() - t0
        captured = int(np.intersect1d(injected, mask).size)
        rate = captured / injected.size
        assert captured == injected.size, (
            f"rank {rank}: only {captured}/{injected.size} injected "
            f"super-weights survived rank-reduced scoring into the "
            f"top-{DENSITY:.0%} mask — the principal-overlay premise "
            f"(DESIGN.md §12) is broken")
        rows.append({
            "name": f"super/rank{rank}",
            "us_per_call": dt * 1e6,
            "derived": f"capture_rate={rate:.3f};"
                       f"captured={captured}/{injected.size}",
            "metrics": {"capture_rate": float(rate),
                        "captured": captured,
                        "injected": int(injected.size),
                        "all_captured": captured == injected.size,
                        "rank": rank, "density": DENSITY,
                        "outlier_sigma": OUTLIER_SIGMA}})

    # the quantizer's own selection, sigma guard ON: capture is
    # guaranteed by construction at ANY rank (50-sigma entries trip the
    # 6-sigma guard), independent of what scoring does
    cfg = LiftConfig(rank=RANKS[0], density=DENSITY, method="exact",
                     min_dim=16)
    t0 = time.perf_counter()
    guarded = principal_indices(wj, cfg, k, superw_sigma=6.0)
    dt = time.perf_counter() - t0
    captured = int(np.intersect1d(injected, guarded).size)
    assert captured == injected.size, (
        f"sigma guard lost {injected.size - captured} super-weights — "
        f"quantize.principal_indices guard broken")
    rows.append({
        "name": "super/guard-sigma6",
        "us_per_call": dt * 1e6,
        "derived": f"captured={captured}/{injected.size};rank={RANKS[0]}",
        "metrics": {"capture_rate": 1.0, "captured": captured,
                    "injected": int(injected.size),
                    "all_captured": True,
                    "rank": RANKS[0], "density": DENSITY,
                    "superw_sigma": 6.0}})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the machine-readable artifact here "
                         "(BENCH_fig_super_weights.json; docs/CI.md)")
    args = ap.parse_args()
    rows = run()
    csv_rows(rows)
    if args.json:
        write_bench_json(args.json, rows, suite="fig_super_weights")
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
