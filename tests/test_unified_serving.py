"""Every family on ONE engine (DESIGN.md §5): the unified paged engine
built by `repro.serving.make_engine` must reproduce the dense reference
engine's token streams bitwise for every model family at any
temperature — sliding-window attention through a ring of refcounted
pages, rwkv6 / zamba-hybrid recurrent state through "state"-class slab
pages from the same `KVPool`, with state CHECKPOINTED on preemption so
a restart resumes decode instead of re-running prefill.  Plus the page
classes' cross-allocation invariants and the public-API surface of the
`ServingConfig` / `make_engine` redesign."""
import jax
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.serving import Request, ServingConfig, make_engine
from repro.serving.kvpool import KVPool
from repro.serving.oracle import DenseOracle

DENSE_KW = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                head_dim=16, d_ff=128, vocab_size=97)


def _prompts(n, seed=3, lo=3, hi=40):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve(eng, prompts, temps=None, max_new=10):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           temperature=temps[i] if temps else 0.0))
    done = eng.run()
    assert len(done) == len(prompts), (len(done), len(prompts))
    for r in done:
        assert not getattr(r, "error", None), r.error
    return {r.uid: tuple(r.out_tokens) for r in done}


# --------------------------------------------- SWA ring-page identity
@pytest.mark.parametrize("window", [16, 12])   # divides page_size 8 / not
def test_swa_ring_pages_match_dense_oracle(window):
    """Sliding-window decode from a fixed ring of pages per slot must be
    token-identical to the dense rolling-buffer reference, for a window
    that divides the page size and one that straddles page boundaries
    (the ring then carries one extra partially-masked page)."""
    cfg = ModelConfig(family="dense", sliding_window=window, **DENSE_KW)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(6)
    temps = [0.0, 0.8, 0.0, 1.2, 0.0, 0.6]
    want = _serve(DenseOracle(model, params,
                              ServingConfig(batch_slots=2, max_len=64)),
                  prompts, temps)
    eng = make_engine(model, params,
                      ServingConfig(batch_slots=2, max_len=64,
                                    page_size=8, num_pages=24))
    got = _serve(eng, prompts, temps)
    assert eng._ring == 3            # ceil(W/8)+1 for both windows
    assert got == want
    # the ring never grows: per-slot residency is bounded by the ring
    assert eng.kv_stats()["peak_pages_in_use"] <= 2 * eng._ring


def test_swa_refuses_window_wider_than_max_len():
    cfg = ModelConfig(family="dense", sliding_window=64, **DENSE_KW)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="window"):
        make_engine(model, params, ServingConfig(max_len=64))


# ------------------------------------------ recurrent state-slab slots
def test_rwkv6_state_slabs_match_dense_oracle():
    cfg = ModelConfig(family="rwkv6", num_layers=2, d_model=64,
                      num_heads=8, head_dim=8, d_ff=128, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(4, seed=9, lo=8, hi=30)
    temps = [0.0, 0.9, 0.0, 0.7]
    want = _serve(DenseOracle(model, params,
                              ServingConfig(batch_slots=2, max_len=64)),
                  prompts, temps)
    eng = make_engine(model, params,
                      ServingConfig(batch_slots=2, max_len=64,
                                    page_size=8, num_pages=64))
    got = _serve(eng, prompts, temps)
    assert got == want
    st = eng.kv_stats()
    assert st["state_pages"] > 0     # slabs charged to the shared pool
    # slabs are the ONLY pool usage for a pure-recurrent family
    assert eng.sched.pool.pages_in_use("kv") == 0


def test_rwkv6_preempt_checkpoints_state_no_prefill_rerun():
    """Forced mid-decode preemption of a recurrent sequence must
    checkpoint its state slab and restore it bitwise on re-admission —
    the stream continues where it left off and prefill NEVER re-runs."""
    cfg = ModelConfig(family="rwkv6", num_layers=2, d_model=64,
                      num_heads=8, head_dim=8, d_ff=128, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(2, seed=9, lo=10, hi=30)
    temps = [0.0, 0.9]
    want = _serve(DenseOracle(model, params,
                              ServingConfig(batch_slots=2, max_len=64)),
                  prompts, temps, max_new=12)
    eng = make_engine(model, params,
                      ServingConfig(batch_slots=2, max_len=64,
                                    page_size=8, num_pages=64))
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=12,
                           temperature=temps[i]))
    for _ in range(4):               # both slots well into decode
        eng.step()
    pc_before = eng.prefill_chunks
    eng.sched.preempt(0)             # forced mid-decode preemption
    eng._clear_slot(0)
    got = {r.uid: tuple(r.out_tokens) for r in eng.run()}
    assert got == want
    assert eng.checkpoints == 1 and eng.restores == 1
    assert eng.prefill_chunks == pc_before   # restored, not recomputed


def test_hybrid_tight_pool_preempts_checkpoints_and_matches():
    """Zamba-style hybrid (shared-attention KV pages + mamba state
    slabs) through a pool too small for every sequence at once: page
    exhaustion must preempt WITH a state checkpoint, and the final
    streams must still match the dense reference bitwise."""
    cfg = ModelConfig(family="hybrid", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=97, shared_attn_period=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(5, seed=5, lo=10, hi=30)
    temps = [0.0, 0.9, 0.0, 0.7, 0.0]
    want = _serve(DenseOracle(model, params,
                              ServingConfig(batch_slots=3, max_len=64)),
                  prompts, temps)
    roomy = make_engine(model, params,
                        ServingConfig(batch_slots=3, max_len=64,
                                      page_size=8, num_pages=48))
    assert _serve(roomy, prompts, temps) == want
    slab = roomy._slab_pages
    tight = make_engine(model, params,
                        ServingConfig(batch_slots=3, max_len=64,
                                      page_size=8,
                                      num_pages=8 + 3 * slab))
    got = _serve(tight, prompts, temps)
    assert got == want
    assert tight.sched.preemptions > 0
    assert tight.checkpoints > 0 and tight.restores > 0
    # both page classes drew from the one shared pool
    st = tight.kv_stats()
    assert st["state_pages"] == slab > 0


# ------------------------------------------------ the whole family zoo
@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-1.2b",
                                  "mixtral-8x22b", "qwen3-1.7b"])
def test_make_engine_serves_every_zoo_family(arch):
    """Acceptance sweep: every zoo smoke config — recurrent, hybrid,
    SWA + MoE, dense — serves through `make_engine` bitwise-identical
    to the dense reference engine on a mixed-temperature stream."""
    from repro.configs import get_arch
    cfg = get_arch(arch).smoke
    if cfg.input_mode == "embeddings":
        cfg = cfg.replace(input_mode="tokens")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(4, seed=11, lo=6, hi=24)
    temps = [0.0, 0.8, 0.0, 0.6]
    want = _serve(DenseOracle(model, params,
                              ServingConfig(batch_slots=2, max_len=64)),
                  prompts, temps, max_new=8)
    eng = make_engine(model, params,
                      ServingConfig(batch_slots=2, max_len=64,
                                    page_size=8, num_pages=48))
    got = _serve(eng, prompts, temps, max_new=8)
    assert got == want


# --------------------------------------------- pool page-class fuzzing
def test_pool_page_classes_never_cross_allocate():
    """Randomized alloc/release interleaving of "kv" and "state" pages:
    a live page belongs to exactly one class, the per-class counters
    always sum to the total, and a page freed from one class is
    reusable by the other only AFTER its refcount returns to zero."""
    rng = np.random.default_rng(0)
    pool = KVPool(num_pages=24, page_size=4)
    live = {"kv": [], "state": []}
    for _ in range(600):
        op = rng.integers(0, 3)
        cls = "kv" if rng.integers(0, 2) == 0 else "state"
        if op == 0:                                   # alloc
            got = pool.alloc(int(rng.integers(1, 4)), cls=cls)
            if got is not None:
                assert all(pool.cls_of[p] == cls for p in got)
                live[cls].extend(got)
        elif op == 1 and live[cls]:                   # release
            p = live[cls].pop(int(rng.integers(0, len(live[cls]))))
            pool.release(p)
            assert pool.cls_of[p] is None             # class cleared
        elif op == 2 and live[cls]:                   # retain+release
            p = live[cls][int(rng.integers(0, len(live[cls])))]
            pool.retain(p)
            assert pool.cls_of[p] == cls              # still that class
            pool.release(p)
        # global invariants after every step
        assert set(live["kv"]) & set(live["state"]) == set()
        assert pool.pages_in_use("kv") == len(live["kv"])
        assert pool.pages_in_use("state") == len(live["state"])
        assert (pool.pages_in_use("kv") + pool.pages_in_use("state")
                == pool.pages_in_use())
    for cls in live:
        for p in live[cls]:
            pool.release(p)
    assert pool.pages_in_use() == 0


def test_pool_rejects_unknown_page_class():
    pool = KVPool(num_pages=4, page_size=4)
    with pytest.raises(ValueError, match="page class"):
        pool.alloc(1, cls="weights")


def test_state_pages_never_enter_prefix_cache():
    pool = KVPool(num_pages=6, page_size=4)
    (slab,) = pool.alloc(1, cls="state")
    with pytest.raises(AssertionError, match="kv pages"):
        pool.cache_put("chain0", slab)


# ------------------------------------------------- public API surface
def test_dense_engine_is_not_public():
    """The API redesign's contract: ONE config + ONE factory.  The dense
    engine survives only as the non-exported test oracle."""
    import repro.serving as serving
    assert "make_engine" in serving.__all__
    assert "ServingConfig" in serving.__all__
    for legacy in ("Engine", "EngineConfig", "PagedEngineConfig",
                   "DenseOracle"):
        assert legacy not in serving.__all__
        assert not hasattr(serving, legacy)
    with pytest.raises(ImportError):
        from repro.serving import Engine  # noqa: F401


def test_serving_config_defaults_build_paged_engine():
    model = build_model(ModelConfig(family="dense", **DENSE_KW))
    params = model.init(jax.random.PRNGKey(0))
    eng = make_engine(model, params, ServingConfig(max_len=64))
    from repro.serving.kvpool import PagedEngine
    assert isinstance(eng, PagedEngine)
    assert _serve(eng, _prompts(2), max_new=4)
