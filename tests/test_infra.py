"""Infrastructure: data loader, checkpointing (atomicity, pruning, async,
elastic restore), fault tolerance (preemption resume bit-exactness,
straggler detection), serving engine, HLO collective parser."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from hypothesis_fallback import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.data.loader import LoaderState, ShardedLoader
from repro.data.synthetic import EOS, VOCAB_SIZE, generate
from repro.ft import PreemptionSimulator, StragglerMonitor
from repro.launch.hlo import analyze_collectives
from repro.models import ModelConfig, build_model
from repro.serving import Request, ServingConfig, make_engine
from repro.training import trainer as T

CFG = ModelConfig(family="dense", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=2, head_dim=8, d_ff=64,
                  vocab_size=max(97, VOCAB_SIZE))


# ------------------------------------------------------------------ data
def test_loader_deterministic_and_resumable():
    data = generate("arith", 128, 32, seed=0)
    l1 = ShardedLoader(data, batch_size=16, seed=1)
    batches = [l1.next_batch() for _ in range(6)]
    l2 = ShardedLoader(data, batch_size=16, seed=1,
                       state=LoaderState(0, 3))
    for i in range(3, 6):
        b = l2.next_batch()
        assert np.array_equal(b["tokens"], batches[i]["tokens"])


def test_loader_shards_disjoint_cover():
    data = generate("arith", 64, 32, seed=0)
    la = ShardedLoader(data, batch_size=16, seed=3, shard_id=0, num_shards=4)
    lb = ShardedLoader(data, batch_size=16, seed=3, shard_id=1, num_shards=4)
    ba, bb = la.next_batch(), lb.next_batch()
    assert ba["tokens"].shape[0] == 4 and bb["tokens"].shape[0] == 4
    sa_ = {r.tobytes() for r in ba["tokens"]}
    sb_ = {r.tobytes() for r in bb["tokens"]}
    assert not (sa_ & sb_)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([1, 2, 4, 8, 16]), st.integers(0, 40),
       st.integers(0, 2 ** 10))
def test_prop_loader_elastic_reshard_covers_batch(shards, step, seed):
    """Union of per-shard batches == global batch for any shard count that
    divides the global batch (the framework's elastic contract)."""
    n = 128
    data = {"x": np.arange(n * 3).reshape(n, 3)}
    bs = 16
    full = ShardedLoader(data, batch_size=bs, seed=seed,
                         state=LoaderState(0, step % 8))
    want = full.next_batch()["x"]
    got = []
    for sid in range(shards):
        ld = ShardedLoader(data, batch_size=bs, seed=seed, shard_id=sid,
                           num_shards=shards,
                           state=LoaderState(0, step % 8))
        got.append(ld.next_batch()["x"])
    got = np.concatenate(got)
    assert sorted(map(tuple, got.tolist())) == sorted(map(tuple,
                                                          want.tolist()))


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_prune_async():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(2, 8)
    tree = {"params": params, "cache": cache, "step": jnp.asarray(3)}
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep=2)
        cm.save(1, tree, meta={"loader": {"epoch": 0, "step": 1}})
        cm.save_async(2, tree)
        cm.wait()
        cm.save(3, tree)
        assert cm.all_steps() == [2, 3]
        r = cm.restore(3, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(r)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert cm.restore_meta(1) if 1 in cm.all_steps() else True


def test_checkpoint_atomicity_partial_write_invisible():
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep=5)
        cm.save(1, {"x": jnp.ones(4)})
        # simulate a crashed mid-write: stray .tmp dir must be ignored
        os.makedirs(os.path.join(td, "step_00000002.tmp"))
        with open(os.path.join(td, "step_00000002.tmp", "garbage"),
                  "w") as f:
            f.write("boom")
        assert cm.all_steps() == [1]
        assert cm.latest_step() == 1


def test_checkpoint_corrupt_manifest_ignored():
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep=5)
        cm.save(1, {"x": jnp.ones(4)})
        os.makedirs(os.path.join(td, "step_00000005"))
        # step_5 has no manifest -> incomplete, ignored
        assert cm.latest_step() == 1


# -------------------------------------------------------- fault tolerance
def test_preemption_resume_bit_exact():
    """Crash at step 6, auto-resume, final params == uninterrupted run."""
    m = build_model(CFG)
    mcfg = T.MethodConfig(kind="lift",
                          lift=LiftConfig(rank=4, match_rank=1,
                                          method="exact", min_dim=16))
    data = generate("arith", 64, 24, seed=0)

    def fresh():
        params = m.init(jax.random.PRNGKey(0))
        params, state = T.init_train_state(m, params, mcfg,
                                           jax.random.PRNGKey(1))
        step = jax.jit(T.make_train_step(m, mcfg, sa.AdamConfig(lr=1e-3),
                                         T.constant_lr(1e-3)))
        return params, state, step

    def run(steps, ckpt=None, resume=False, crash_at=None):
        params, state, step = fresh()
        loader = ShardedLoader(data, batch_size=8, seed=2)
        start = 0
        if resume:
            latest = ckpt.latest_step()
            r = ckpt.restore(latest, {"params": params, "state": state})
            params, state = r["params"], r["state"]
            loader.state = LoaderState.from_dict(
                ckpt.restore_meta(latest)["loader"])
            start = latest
        pre = PreemptionSimulator(crash_at)
        for i in range(start, steps):
            b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
            params, state, _ = step(params, state, b)
            if ckpt is not None and (i + 1) % 3 == 0:
                ckpt.save(i + 1, {"params": params, "state": state},
                          meta={"loader": loader.state.to_dict()})
            try:
                pre.check(i + 1)
            except SystemExit:
                return None, None
        return params, state

    p_ref, _ = run(10)
    with tempfile.TemporaryDirectory() as td:
        cm = CheckpointManager(td, keep=3)
        out = run(10, ckpt=cm, crash_at=6)
        assert out[0] is None  # crashed
        p_res, _ = run(10, ckpt=cm, resume=True)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_res)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_slow_rank():
    sm = StragglerMonitor(z_threshold=3.0, patience=2)
    rng = np.random.default_rng(0)
    for _ in range(30):
        v = sm.observe(0, 1.0 + 0.02 * rng.standard_normal())
        assert not v.is_straggler
    assert not sm.observe(1, 2.5).is_straggler  # first strike
    assert sm.observe(1, 2.5).is_straggler      # second strike -> flagged
    # healthy rank unaffected
    assert not sm.observe(0, 1.0).is_straggler


def test_straggler_baseline_not_poisoned():
    sm = StragglerMonitor(z_threshold=3.0, patience=1)
    for _ in range(20):
        sm.observe(0, 1.0)
    base = sm.mean
    for _ in range(5):
        sm.observe(1, 50.0)
    assert sm.mean == pytest.approx(base, rel=0.05)


# ---------------------------------------------------------------- serving
def test_engine_continuous_batching_completes_all():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    eng = make_engine(m, params, ServingConfig(batch_slots=2, max_len=48,
                                               eos_id=EOS))
    for i in range(5):
        eng.submit(Request(uid=i, prompt=np.arange(3 + i) % 50,
                           max_new_tokens=6))
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2, 3, 4]
    assert all(0 < len(r.out_tokens) <= 6 for r in done)


def test_engine_greedy_matches_manual_decode():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    prompt = np.arange(5) % 50
    eng = make_engine(m, params, ServingConfig(batch_slots=1, max_len=32,
                                               eos_id=EOS))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    got = eng.run()[0].out_tokens

    ctx = list(prompt)
    want = []
    for _ in range(4):
        lg = m.logits(params, {"tokens": jnp.asarray([ctx], jnp.int32)})
        nxt = int(jnp.argmax(lg[0, -1]))
        want.append(nxt)
        if nxt == EOS:
            break
        ctx.append(nxt)
    if EOS in want:
        want = want[:want.index(EOS)]
    assert got == want, (got, want)


# ------------------------------------------------------------- HLO parser
HLO_SAMPLE = """
HloModule test
ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}
  %rs = f32[4,128]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %a2a = bf16[16,128]{1,0} all-to-all(%z), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %prom = f32[32,32]{1,0} all-reduce(%q), replica_groups={{0,1}}, to_apply=%add.clone_promoted
}
"""


def test_hlo_collective_parser_factors():
    st_ = analyze_collectives(HLO_SAMPLE, 8)
    by = st_.by_kind
    assert by["all-reduce"] == pytest.approx(
        2 * 3 / 4 * 16 * 128 * 4        # plain f32 AR over groups of 4
        + 2 * 1 / 2 * 32 * 32 * 2)      # promoted: counted at bf16 width
    assert by["all-gather"] == pytest.approx(1 / 2 * 64 * 128 * 2)
    assert by["reduce-scatter"] == pytest.approx(3 * 4 * 128 * 4)
    assert by["all-to-all"] == pytest.approx(7 / 8 * 16 * 128 * 2)
    assert by["collective-permute"] == pytest.approx(8 * 8 * 4)
    assert st_.count == 6
