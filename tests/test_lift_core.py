"""LIFT core invariants: low-rank approximation, Principal-Weight masks,
sparse AdamW, state migration (paper Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from hypothesis_fallback import given, settings, st

from repro.core import lowrank, sparse_adam as sa
from repro.core.lift import (
    LiftConfig, compute_indices, make_plan, topk_indices, get_by_path, scores_for)
from repro.models import ModelConfig, build_model

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)


def _rand(m, n, seed=0, rank=None):
    k = jax.random.PRNGKey(seed)
    if rank is None:
        return jax.random.normal(k, (m, n))
    a = jax.random.normal(k, (m, rank))
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (rank, n))
    return a @ b / np.sqrt(rank)


# ------------------------------------------------------------- lowrank
def test_exact_lowrank_is_eckart_young():
    w = _rand(48, 64, seed=1)
    a, b = lowrank.exact_lowrank(w, 8)
    w8 = a @ b.T
    u, s, vt = np.linalg.svd(np.asarray(w), full_matrices=False)
    best = (u[:, :8] * s[:8]) @ vt[:8]
    assert np.allclose(np.asarray(w8), best, atol=1e-4)


def test_randomized_matches_exact_on_lowrank_matrix():
    w = _rand(96, 80, seed=2, rank=6)  # exactly rank 6
    a, b = lowrank.randomized_lowrank(w, 6, key=jax.random.PRNGKey(3))
    assert np.allclose(np.asarray(a @ b.T), np.asarray(w), atol=1e-3)


def test_randomized_spectral_error_bound():
    w = _rand(128, 96, seed=4)
    r = 16
    a, b = lowrank.randomized_lowrank(w, r, key=jax.random.PRNGKey(5),
                                      oversample=8, iters=2)
    err = np.linalg.norm(np.asarray(w - a @ b.T), 2)
    s = np.linalg.svd(np.asarray(w), compute_uv=False)
    # sigma_{r+1} is the optimum; subspace iteration should be within 1.5x
    assert err <= 1.5 * s[r] + 1e-5, (err, s[r])


def test_rank_strategies_select_expected_spectrum():
    w = _rand(40, 40, seed=6)
    s = np.linalg.svd(np.asarray(w), compute_uv=False)
    a, b = lowrank.exact_lowrank(w, 4, strategy="smallest")
    # reconstruction built from the smallest singular values has tiny norm
    assert np.linalg.norm(np.asarray(a @ b.T), 2) <= s[-4] + 1e-4
    a, b = lowrank.exact_lowrank(w, 4, strategy="hybrid")
    assert np.linalg.norm(np.asarray(a @ b.T), 2) >= s[0] - 1e-4


def test_spectral_norm_power_iteration():
    w = _rand(64, 48, seed=7)
    sn = float(lowrank.spectral_norm(w, iters=64))
    ref = float(np.linalg.svd(np.asarray(w), compute_uv=False)[0])
    assert abs(sn - ref) / ref < 1e-3


# ---------------------------------------------------------------- masks
def test_topk_indices_match_numpy():
    s = jnp.abs(_rand(32, 48, seed=8))
    idx = np.asarray(topk_indices(s, 100))
    ref = np.sort(np.argpartition(-np.asarray(s).ravel(), 100)[:100])
    assert np.array_equal(idx, ref)
    assert np.all(np.diff(idx) > 0)  # sorted unique


def test_structured_mask_blocks():
    s = jnp.abs(_rand(32, 32, seed=9))
    idx = np.asarray(topk_indices(s, 64, block_size=4))
    mask = np.zeros(32 * 32, bool)
    mask[idx] = True
    mask = mask.reshape(32, 32)
    blocks = mask.reshape(8, 4, 8, 4).sum((1, 3))
    assert set(np.unique(blocks)) <= {0, 16}  # whole 4x4 blocks only
    assert blocks.sum() == 64


def test_lift_mask_is_topk_of_lowrank_abs():
    w = _rand(64, 96, seed=10)
    cfg = LiftConfig(rank=8, method="exact")
    s = scores_for(w, cfg, "lift")
    ref = jnp.abs(jnp.asarray(
        np.linalg.svd(np.asarray(w), full_matrices=False)[0][:, :8]
        * np.linalg.svd(np.asarray(w), compute_uv=False)[:8]) @
        np.linalg.svd(np.asarray(w), full_matrices=False)[2][:8])
    assert np.allclose(np.asarray(s), np.asarray(ref), atol=1e-4)


def test_plan_geometry_and_budget():
    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact")
    plan = make_plan(m.spec(), lcfg)
    # attention + mlp tensors planned; embeddings/norms excluded
    assert "blocks/attn/wq" in plan and "blocks/mlp/up" in plan
    assert not any("embed" in p for p in plan)
    assert not any("ln" in p for p in plan)
    p = plan["blocks/mlp/up"]
    assert (p.rows, p.cols) == (64, 128)
    assert p.k == 2 * (64 + 128)
    p = plan["blocks/attn/wo"]  # flat storage: (heads*hd, d)
    assert (p.rows, p.cols) == (64, 64)


def test_scope_mlp_restricts_plan():
    m = build_model(CFG)
    plan = make_plan(m.spec(), LiftConfig(scope="mlp", match_rank=2))
    assert all("mlp" in p for p in plan)


# --------------------------------------------------------- sparse adam
def _setup_state(seed=0, use_master=False):
    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact")
    plan = make_plan(m.spec(), lcfg)
    params = m.init(jax.random.PRNGKey(seed))
    idx = compute_indices(params, plan, lcfg, jax.random.PRNGKey(seed + 1))
    state = sa.init_state(params, idx, plan, use_master=use_master)
    return m, lcfg, plan, params, idx, state


def test_sparse_adam_equals_dense_masked_adam():
    """THE key paper invariant: LIFT's (k,)-vector optimizer is bit-
    equivalent to dense AdamW with a frozen binary mask."""
    m, lcfg, plan, params, idx, state = _setup_state()
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32),
             "loss_mask": jnp.ones((2, 16))}
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    opt = sa.AdamConfig(lr=1e-3, weight_decay=0.01)

    sparse_p, _ = sa.apply_updates(params, grads, state, plan, opt)

    # dense reference: adam on everything, then mask the delta
    dstate = sa.dense_init(params)
    dense_p, _ = sa.dense_apply(params, grads, dstate, opt)
    for path, p in plan.items():
        ns = int(np.prod(p.stack)) if p.stack else 1
        mask = np.zeros((ns, p.rows * p.cols), bool)
        np.put_along_axis(mask, np.asarray(idx[path]), True, axis=1)
        got = np.asarray(get_by_path(sparse_p, path)).reshape(ns, -1)
        want_dense = np.asarray(get_by_path(dense_p, path)).reshape(ns, -1)
        orig = np.asarray(get_by_path(params, path)).reshape(ns, -1)
        want = np.where(mask, want_dense, orig)
        assert np.allclose(got, want, atol=1e-6), path


def test_update_touches_only_masked_entries():
    m, lcfg, plan, params, idx, state = _setup_state()
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32),
             "loss_mask": jnp.ones((2, 16))}
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    new_p, _ = sa.apply_updates(params, grads, state, plan,
                                sa.AdamConfig(lr=1e-2))
    for path, p in plan.items():
        ns = int(np.prod(p.stack)) if p.stack else 1
        delta = (np.asarray(get_by_path(new_p, path))
                 - np.asarray(get_by_path(params, path))).reshape(ns, -1)
        changed = {(i, j) for i, j in zip(*np.nonzero(delta))}
        allowed = {(i, int(j)) for i in range(ns)
                   for j in np.asarray(idx[path])[i]}
        assert changed <= allowed, path


def test_migration_keeps_surviving_moments():
    m, lcfg, plan, params, idx, state = _setup_state()
    # fabricate distinctive moments
    for path in plan:
        t = state["tensors"][path]
        t["m"] = jnp.arange(t["m"].size, dtype=jnp.float32
                            ).reshape(t["m"].shape) + 1.0
        t["v"] = t["m"] * 10.0
    new_idx = compute_indices(params, plan,
                              lcfg.replace(selection="magnitude"),
                              jax.random.PRNGKey(99))
    new_state = sa.migrate(params, state, new_idx, plan)
    for path, p in plan.items():
        old_i = np.asarray(idx[path])
        new_i = np.asarray(new_idx[path])
        old_m = np.asarray(state["tensors"][path]["m"])
        new_m = np.asarray(new_state["tensors"][path]["m"])
        for r in range(old_i.shape[0]):
            lut = {int(ii): float(mm) for ii, mm in zip(old_i[r], old_m[r])}
            for jj, mm in zip(new_i[r], new_m[r]):
                expect = lut.get(int(jj), 0.0)
                assert mm == pytest.approx(expect), (path, r, int(jj))


# ------------------------------------------------------ hypothesis props
@settings(max_examples=25, deadline=None)
@given(st.integers(8, 64), st.integers(8, 64), st.integers(1, 60),
       st.integers(0, 2 ** 16))
def test_prop_topk_count_and_range(m, n, k, seed):
    k = min(k, m * n)
    s = jnp.abs(_rand(m, n, seed=seed))
    idx = np.asarray(topk_indices(s, k))
    assert idx.shape == (k,)
    assert idx.min() >= 0 and idx.max() < m * n
    assert len(np.unique(idx)) == k
    # every selected score >= every unselected score
    flat = np.asarray(s).ravel()
    sel = np.zeros(m * n, bool)
    sel[idx] = True
    if k < m * n:
        assert flat[sel].min() >= flat[~sel].max() - 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16), st.integers(1, 30), st.integers(1, 30))
def test_prop_migration_is_projection(seed, k_old, k_new):
    """Migrated moments are exactly the old moments where indices survive,
    zero elsewhere (Algorithm 1)."""
    rng = np.random.default_rng(seed)
    N = 64
    k_old, k_new = min(k_old, N), min(k_new, N)
    old_idx = np.sort(rng.choice(N, k_old, replace=False))
    new_idx = np.sort(rng.choice(N, k_new, replace=False))
    m_old = rng.normal(size=k_old).astype(np.float32)

    pos = np.searchsorted(old_idx, new_idx)
    pos_c = np.minimum(pos, k_old - 1)
    hit = old_idx[pos_c] == new_idx
    got = np.where(hit, m_old[pos_c], 0.0)

    lut = dict(zip(old_idx.tolist(), m_old.tolist()))
    want = np.asarray([lut.get(int(j), 0.0) for j in new_idx], np.float32)
    assert np.array_equal(got, want)
