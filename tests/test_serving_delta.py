"""Adapter-aware serving (DESIGN.md §4): bucketed prefill compiles once
per power-of-two length bucket and stays token-identical to exact-length
prefill; the AdapterStore merges deltas on load (LRU-bounded) and the
scheduler batches same-adapter requests; serving base + delta is
token-identical to serving the dense fine-tuned checkpoint end to end
(the launch/serve.py --base/--delta path, in process)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.data.loader import ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, generate
from repro.deltas import DeltaArtifact, DeltaMismatchError, extract
from repro.models import ModelConfig, build_model
from repro.serving import AdapterStore, Request, ServingConfig
from repro.serving.oracle import DenseOracle
from repro.training import trainer as T

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=max(VOCAB_SIZE, 97))


def _model_params(seed=0):
    model = build_model(CFG)
    return model, model.init(jax.random.PRNGKey(seed))


def _prompts(n, seed=3, lo=3, hi=33):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve(model, params, prompts, *, buckets=True, adapters=None,
           adapter_ids=None, slots=2, max_new=8):
    eng = DenseOracle(model, params,
                 ServingConfig(batch_slots=slots, max_len=64, eos_id=2,
                              prefill_buckets=buckets), adapters=adapters)
    for i, p in enumerate(prompts):
        eng.submit(Request(
            uid=i, prompt=p, max_new_tokens=max_new,
            adapter_id=adapter_ids[i] if adapter_ids else None))
    done = eng.run()
    assert len(done) == len(prompts)
    return {r.uid: tuple(r.out_tokens) for r in done}, eng


# -------------------------------------------------------- prefill buckets
def test_bucketed_prefill_token_identical_fewer_compiles():
    model, params = _model_params()
    prompts = _prompts(8)
    lens = {len(p) for p in prompts}
    a, eng_b = _serve(model, params, prompts, buckets=True)
    b, eng_e = _serve(model, params, prompts, buckets=False)
    assert a == b
    assert eng_e.prefill_compilations == len(lens)
    assert eng_b.prefill_compilations <= len(
        {max(16, 1 << (int(s) - 1).bit_length()) for s in lens})
    assert eng_b.prefill_compilations < eng_e.prefill_compilations


@pytest.mark.parametrize("family, kw", [
    ("rwkv6", dict(num_heads=2, head_dim=32)),   # recurrent state
    ("moe", dict(num_experts=4, num_experts_per_tok=2)),  # pads eat slots
])
def test_bucketing_disabled_for_pad_sensitive_families(family, kw):
    """Families where pad tokens change real-token math (recurrent
    state, MoE capacity-limited dispatch) must keep the exact-length
    path."""
    cfg = ModelConfig(family=family, num_layers=2, d_model=64,
                      num_heads=kw.get("num_heads", 4), num_kv_heads=2,
                      head_dim=kw.get("head_dim", 16), d_ff=128,
                      vocab_size=max(VOCAB_SIZE, 97),
                      **{k: v for k, v in kw.items()
                         if k not in ("num_heads", "head_dim")})
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = DenseOracle(model, params, ServingConfig(batch_slots=1, max_len=64,
                                             eos_id=2))
    assert not eng._bucketing
    assert eng._bucket_len(13) == 13


# ----------------------------------------------------------- AdapterStore
def _tiny_delta(model, base, seed, tmp_path, tag):
    method = T.MethodConfig(
        kind="lift", lift=LiftConfig(rank=8, density=0.05, method="exact",
                                     min_dim=16))
    engine = T.selection_engine(model, method)
    params, state = T.init_train_state(model, base, method,
                                       jax.random.PRNGKey(seed),
                                       engine=engine)
    step_fn = jax.jit(T.make_train_step(model, method,
                                        sa.AdamConfig(lr=1e-2),
                                        T.constant_lr(1e-2)))
    loader = ShardedLoader(generate("arith", 64, 24, seed=seed),
                           batch_size=8, seed=seed)
    for _ in range(3):
        b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        params, state, _ = step_fn(params, state, b)
    ck = CheckpointManager(str(tmp_path / f"ckpt_{tag}"))
    ck.save(3, {"params": params, "state": state},
            meta={"selection": engine.plan_meta()})
    return extract(ck, 3, base), params


def test_adapter_store_lru_and_refusal(tmp_path):
    model, base = _model_params()
    d1, tuned1 = _tiny_delta(model, base, 11, tmp_path, "a")
    d2, tuned2 = _tiny_delta(model, base, 22, tmp_path, "b")
    d3, _ = _tiny_delta(model, base, 33, tmp_path, "c")
    store = AdapterStore(base, capacity=2, backend="kernel")
    store.load("a", d1)
    store.load("b", d2)
    got = store.params_for("a")
    assert all(np.array_equal(np.asarray(x), np.asarray(y)) for x, y in
               zip(jax.tree.leaves(got), jax.tree.leaves(tuned1)))
    # loading a third evicts the LRU ("b": "a" was touched more recently)
    store.load("c", d3)
    assert store.evictions == 1
    assert set(store.adapter_ids()) == {"a", "c"}
    with pytest.raises(KeyError):
        store.params_for("b")
    assert store.params_for(None) is base
    # wrong-base refusal at load time
    other = jax.tree.map(lambda x: x + 1e-3, base)
    bad_store = AdapterStore(other, backend="kernel")
    with pytest.raises(DeltaMismatchError):
        bad_store.load("a", d1)
    # plan-fingerprint refusal when the store knows the consumer's plan
    wrong_plan = dict(d1.manifest["selection"], quota="local",
                      quota_shards=4)
    picky = AdapterStore(base, backend="kernel", plan_meta=wrong_plan)
    with pytest.raises(DeltaMismatchError, match="quota"):
        picky.load("a", d1)
    ok = AdapterStore(base, backend="kernel",
                      plan_meta=d1.manifest["selection"])
    ok.load("a", d1)


def test_same_adapter_slot_batching(tmp_path):
    """Mixed-adapter queue: the scheduler batches per adapter; every
    request's output equals the single-adapter run's output."""
    model, base = _model_params()
    d1, tuned1 = _tiny_delta(model, base, 11, tmp_path, "a")
    d2, tuned2 = _tiny_delta(model, base, 22, tmp_path, "b")
    store = AdapterStore(base, backend="kernel")
    store.load("a", d1)
    store.load("b", d2)
    prompts = _prompts(6, seed=5)
    ids = ["a", "b", None, "a", "b", None]
    mixed, _ = _serve(model, base, prompts, adapters=store,
                      adapter_ids=ids)
    for aid, params_ref in (("a", tuned1), ("b", tuned2), (None, base)):
        sub = [i for i, x in enumerate(ids) if x == aid]
        solo, _ = _serve(model, params_ref,
                         [prompts[i] for i in sub], max_new=8)
        for j, i in enumerate(sub):
            assert mixed[i] == solo[j], (aid, i)


def test_evicted_adapter_fails_only_its_request(tmp_path):
    """LRU eviction between submit and scheduling must fail ONLY the
    affected request (req.error, no tokens) — never crash the run or
    drop other requests."""
    model, base = _model_params()
    d1, _ = _tiny_delta(model, base, 11, tmp_path, "a")
    d2, _ = _tiny_delta(model, base, 22, tmp_path, "b")
    store = AdapterStore(base, capacity=1, backend="kernel")
    store.load("a", d1)
    eng = DenseOracle(model, base, ServingConfig(batch_slots=2, max_len=64,
                                           eos_id=2), adapters=store)
    prompts = _prompts(3, seed=6)
    eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=4,
                       adapter_id="a"))
    store.load("b", d2)          # capacity=1 -> evicts "a"
    eng.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=4,
                       adapter_id="b"))
    eng.submit(Request(uid=2, prompt=prompts[2], max_new_tokens=4))
    done = {r.uid: r for r in eng.run()}
    assert len(done) == 3
    assert done[0].error and "a" in done[0].error and not done[0].out_tokens
    assert done[1].error is None and len(done[1].out_tokens) == 4
    assert done[2].error is None and len(done[2].out_tokens) == 4


def test_engine_rejects_adapter_without_store():
    model, base = _model_params()
    eng = DenseOracle(model, base, ServingConfig(batch_slots=1, max_len=64))
    with pytest.raises(ValueError):
        eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32),
                           adapter_id="ghost"))


# ------------------------------------------------------------ end to end
def test_serve_delta_token_identical_to_dense(tmp_path):
    """The acceptance proof: base + delta artifact serves token-identical
    to the dense fine-tuned checkpoint, via the saved artifact and both
    merge backends."""
    model, base = _model_params()
    delta, tuned = _tiny_delta(model, base, 44, tmp_path, "e2e")
    delta.save(str(tmp_path / "delta"))
    loaded = DeltaArtifact.load(str(tmp_path / "delta"))
    prompts = _prompts(5, seed=9)
    want, _ = _serve(model, tuned, prompts)
    for backend in ("kernel", "ref"):
        store = AdapterStore(base, backend=backend)
        store.load("ft", loaded)
        got, _ = _serve(model, base, prompts, adapters=store,
                        adapter_ids=["ft"] * len(prompts))
        assert got == want, backend
