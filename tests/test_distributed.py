"""Distributed semantics: a LIFT train step on an 8-device (4 data x 2
model) mesh must match the single-device result (pjit global-view
invariance).  Runs in a subprocess so the 8 placeholder host devices don't
leak into other tests."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig, build_model
from repro.parallel.sharding import set_sharding_ctx, tree_shardings
from repro.training import trainer as T

cfg = ModelConfig(family="moe", num_layers=2, d_model=32, num_heads=4,
                  num_kv_heads=2, head_dim=8, d_ff=32, vocab_size=128,
                  num_experts=4, num_experts_per_tok=2, capacity_factor=4.0,
                  moe_groups=4)
m = build_model(cfg)
mcfg = T.MethodConfig(kind="lift", lift=LiftConfig(
    rank=4, match_rank=1, method="exact", min_dim=16, k_multiple=8))
adam = sa.AdamConfig(lr=1e-3)
key = jax.random.PRNGKey(2)
batch = {"tokens": jax.random.randint(key, (8, 16), 0, 128),
         "labels": jax.random.randint(key, (8, 16), 0, 128),
         "loss_mask": jnp.ones((8, 16))}

def run(mesh):
    if mesh is not None:
        set_sharding_ctx(mesh)
    params = m.init(jax.random.PRNGKey(0))
    params, state = T.init_train_state(m, params, mcfg, jax.random.PRNGKey(1))
    step = T.make_train_step(m, mcfg, adam, T.constant_lr(1e-3))
    if mesh is not None:
        sh = tree_shardings(m.axes(), mesh)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, sh)
        jstep = jax.jit(step)
    else:
        jstep = jax.jit(step)
    for _ in range(3):
        params, state, metrics = jstep(params, state, batch)
    set_sharding_ctx(None)
    return (np.asarray(jax.tree.leaves(params)[3], np.float32),
            float(metrics["loss"]))

p_single, l_single = run(None)
mesh = make_host_mesh(4, 2)
p_mesh, l_mesh = run(mesh)
assert abs(l_single - l_mesh) < 1e-5, (l_single, l_mesh)
err = float(np.max(np.abs(p_single - p_mesh)))
assert err < 1e-5, err
print("DISTRIBUTED-OK", l_single, l_mesh, err)
"""


def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DISTRIBUTED-OK" in r.stdout, r.stdout
