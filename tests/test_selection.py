"""SelectionEngine contracts: streaming/dense parity, no-score-matrix
guarantee, fused migration, plan validation and checkpoint metadata."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig, TensorPlan, make_plan
from repro.core.selection import SelectionEngine
from repro.models import ModelConfig, build_model

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)


def _plan_1tensor(stack, rows, cols, k):
    shape = tuple(stack) + (rows, cols)
    return {"t": TensorPlan("t", shape, tuple(stack), rows, cols, k)}


def _rand_params(stack, rows, cols, dtype, seed=0, rank=None):
    shape = tuple(stack) + (rows, cols)
    key = jax.random.PRNGKey(seed)
    if rank is None:
        w = jax.random.normal(key, shape)
    else:  # soft low-rank structure: realistic for trained weights
        a = jax.random.normal(key, tuple(stack) + (rows, rank))
        b = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              tuple(stack) + (rank, cols))
        w = a @ b / np.sqrt(rank) \
            + 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 2), shape)
    return {"t": w.astype(dtype)}


def _agreement(idx_a, idx_b):
    """Min per-matrix fraction of shared indices for (ns, k) index sets."""
    a, b = np.asarray(idx_a), np.asarray(idx_b)
    assert a.shape == b.shape
    return min(len(np.intersect1d(a[i], b[i])) / a.shape[-1]
               for i in range(a.shape[0]))


# ------------------------------------------------------ streaming parity
@pytest.mark.parametrize("density", [0.01, 0.05, 0.2])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_streaming_matches_dense_topk(density, dtype):
    rows, cols = 128, 192
    k = max(1, int(density * rows * cols))
    plan = _plan_1tensor((), rows, cols, k)
    params = _rand_params((), rows, cols, dtype, seed=hash(density) % 97,
                          rank=12)
    base = LiftConfig(rank=8, method="exact", min_dim=16)
    dense = SelectionEngine(plan, base).select(params, jax.random.PRNGKey(0))
    eng = SelectionEngine(plan, base.replace(use_kernel=True))
    assert eng.backend == "streaming"
    stream, stats = eng.select_with_stats(params, jax.random.PRNGKey(0))
    assert int(stats["overflow"]) == 0
    si = np.asarray(stream["t"])
    assert np.all(np.diff(si, axis=-1) > 0)  # sorted unique per matrix
    assert _agreement(dense["t"], stream["t"]) >= 1 - 1e-3


def test_streaming_parity_stacked_tensors():
    """Stacked (layers, experts) leaves go through the same batched
    program; every matrix in the stack must agree with dense top-k."""
    stack, rows, cols = (2, 3), 96, 64
    k = int(0.05 * rows * cols)
    plan = _plan_1tensor(stack, rows, cols, k)
    params = _rand_params(stack, rows, cols, jnp.float32, seed=5, rank=10)
    base = LiftConfig(rank=8, method="exact", min_dim=16)
    dense = SelectionEngine(plan, base).select(params, jax.random.PRNGKey(1))
    stream = SelectionEngine(plan, base.replace(use_kernel=True)).select(
        params, jax.random.PRNGKey(1))
    assert dense["t"].shape == stream["t"].shape == (6, k)
    assert _agreement(dense["t"], stream["t"]) >= 1 - 1e-3


def test_engine_dense_is_bit_identical_to_legacy_contract():
    """compute_indices (now a thin engine wrapper) and a model-spec engine
    must produce identical indices for the dense backend."""
    from repro.core.lift import compute_indices
    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", min_dim=16)
    plan = make_plan(m.spec(), lcfg)
    params = m.init(jax.random.PRNGKey(0))
    via_wrapper = compute_indices(params, plan, lcfg, jax.random.PRNGKey(7))
    via_engine = SelectionEngine(plan, lcfg).select(params,
                                                    jax.random.PRNGKey(7))
    for path in plan:
        assert np.array_equal(np.asarray(via_wrapper[path]),
                              np.asarray(via_engine[path])), path


# --------------------------------------------- no-score-matrix guarantee
def test_streaming_path_is_exercised(monkeypatch):
    """With use_kernel=True the engine must never call the dense scoring
    path: poisoning scores_for and the materializing |A B^T| kernel proves
    no (rows, cols) score matrix is ever formed."""
    import repro.core.lift as liftmod
    import repro.kernels.ops as kops

    def boom(*a, **kw):
        raise AssertionError("dense score path reached under use_kernel")

    monkeypatch.setattr(liftmod, "scores_for", boom)
    monkeypatch.setattr(kops, "lowrank_abs", boom)

    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", min_dim=16,
                      use_kernel=True)
    eng = SelectionEngine.from_spec(m.spec(), lcfg)
    assert eng.backend == "streaming"
    params = m.init(jax.random.PRNGKey(0))
    idx = eng.select(params, jax.random.PRNGKey(1))
    assert set(idx) == set(eng.plan)
    for path, p in eng.plan.items():
        assert idx[path].shape[-1] == p.k


def test_structured_is_streaming_nonlift_falls_back_to_dense():
    """block_size > 1 now runs the streaming kernel path (the tentpole of
    the structured-selection PR); only non-"lift" score rules still fall
    back to dense."""
    assert SelectionEngine(
        _plan_1tensor((), 64, 64, 64),
        LiftConfig(use_kernel=True, block_size=4)).backend == "streaming"
    assert SelectionEngine(
        _plan_1tensor((), 64, 64, 64),
        LiftConfig(use_kernel=True, selection="magnitude")).backend == "dense"


# --------------------------------------------------- structured streaming
@pytest.mark.parametrize("bs", [2, 4, 8])
def test_structured_streaming_matches_dense_block_topk(bs):
    """Streaming block-sum selection must agree with the dense block path
    (`topk_indices(block_size=bs)`) — bitwise on these cases (ties inside
    the final histogram bin are the only permitted divergence, and block
    sums of continuous scores don't tie)."""
    rows, cols = 128, 192
    k = (int(0.05 * rows * cols) // (bs * bs)) * (bs * bs)
    plan = _plan_1tensor((), rows, cols, k)
    params = _rand_params((), rows, cols, jnp.float32, seed=11, rank=12)
    base = LiftConfig(rank=8, method="exact", min_dim=16, block_size=bs)
    dense = SelectionEngine(plan, base).select(params, jax.random.PRNGKey(0))
    eng = SelectionEngine(plan, base.replace(use_kernel=True))
    assert eng.backend == "streaming"
    stream, stats = eng.select_with_stats(params, jax.random.PRNGKey(0))
    assert int(stats["overflow"]) == 0
    si = np.asarray(stream["t"])
    assert si.shape == (1, k)
    assert np.all(np.diff(si, axis=-1) > 0)       # sorted unique
    assert np.array_equal(si, np.asarray(dense["t"]))
    # whole (bs x bs) blocks: every selected element's block is full
    r, c = si[0] // cols, si[0] % cols
    blocks = set(zip((r // bs).tolist(), (c // bs).tolist()))
    assert len(blocks) * bs * bs == k


def test_structured_streaming_stacked_and_bf16():
    stack, rows, cols, bs = (2, 2), 96, 64, 4
    k = (int(0.1 * rows * cols) // (bs * bs)) * (bs * bs)
    plan = _plan_1tensor(stack, rows, cols, k)
    params = _rand_params(stack, rows, cols, jnp.bfloat16, seed=6, rank=10)
    base = LiftConfig(rank=8, method="exact", min_dim=16, block_size=bs)
    dense = SelectionEngine(plan, base).select(params, jax.random.PRNGKey(2))
    stream = SelectionEngine(plan, base.replace(use_kernel=True)).select(
        params, jax.random.PRNGKey(2))
    assert dense["t"].shape == stream["t"].shape == (4, k)
    assert _agreement(dense["t"], stream["t"]) >= 1 - 1e-3


def test_structured_streaming_never_touches_dense_scores(monkeypatch):
    """The no-score-matrix guarantee extends to structured LIFT: with
    use_kernel=True and block_size > 1 neither the dense scoring path nor
    the materializing |A B^T| kernel may run."""
    import repro.core.lift as liftmod
    import repro.kernels.ops as kops

    def boom(*a, **kw):
        raise AssertionError("dense score path reached under structured "
                             "streaming selection")

    monkeypatch.setattr(liftmod, "scores_for", boom)
    monkeypatch.setattr(kops, "lowrank_abs", boom)

    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", min_dim=16,
                      use_kernel=True, block_size=4)
    eng = SelectionEngine.from_spec(m.spec(), lcfg)
    assert eng.backend == "streaming"
    params = m.init(jax.random.PRNGKey(0))
    idx = eng.select(params, jax.random.PRNGKey(1))
    for path, p in eng.plan.items():
        assert idx[path].shape[-1] == p.k
        assert p.k % 16 == 0                      # bs^2-aligned plan


def test_structured_local_quota_streaming():
    """quota='local' + block_size > 1 (the restriction this PR lifts):
    per-slab quotas hold exactly, whole blocks are selected, and the
    streaming path agrees with the dense structured local path."""
    rows, cols, bs, n = 128, 192, 4, 4
    k = 1216
    plan = _plan_1tensor((), rows, cols, k)
    params = _rand_params((), rows, cols, jnp.float32, seed=8, rank=12)
    cfg = LiftConfig(rank=8, method="exact", min_dim=16, block_size=bs,
                     quota="local", quota_shards=n)
    dense = SelectionEngine(plan, cfg).select(params, jax.random.PRNGKey(3))
    eng = SelectionEngine(plan, cfg.replace(use_kernel=True))
    assert eng.group_exec == {(rows, cols, k): "streaming-local"}
    stream = eng.select(params, jax.random.PRNGKey(3))
    assert _agreement(dense["t"], stream["t"]) >= 1 - 1e-3
    for out in (dense, stream):
        sel = np.asarray(out["t"]).reshape(-1)
        shard = (sel % cols) // (cols // n)
        assert (np.bincount(shard, minlength=n) == k // n).all()
        r, c = sel // cols, sel % cols
        blocks = set(zip((r // bs).tolist(), (c // bs).tolist()))
        assert len(blocks) * bs * bs == k


def test_structured_fused_refresh_migrates_moments():
    """refresh_opt at block_size > 1: surviving indices keep their
    moments, fresh ones restart at zero — the (ns, k) element-index
    contract is unchanged by block encoding, so `remap_moments` needs no
    structured special case."""
    rows, cols, bs = 96, 128, 4
    k = (int(0.05 * rows * cols) // (bs * bs)) * (bs * bs)
    plan = _plan_1tensor((1,), rows, cols, k)
    params = _rand_params((1,), rows, cols, jnp.float32, seed=3, rank=10)
    lcfg = LiftConfig(rank=8, method="exact", min_dim=16, use_kernel=True,
                      block_size=bs)
    eng = SelectionEngine(plan, lcfg)
    idx0 = eng.select(params, jax.random.PRNGKey(0))
    state = sa.init_state(params, idx0, plan)
    t = state["tensors"]["t"]
    t["m"] = jnp.arange(t["m"].size, dtype=jnp.float32
                        ).reshape(t["m"].shape) + 1.0
    t["v"] = t["m"] * 10.0
    params = {"t": params["t"] + 0.5 * jax.random.normal(
        jax.random.PRNGKey(9), params["t"].shape)}
    new_opt, stats = eng.refresh_opt(params, state, jax.random.PRNGKey(5))
    assert int(stats["overflow"]) == 0
    old_i = np.asarray(idx0["t"])[0]
    new_i = np.asarray(new_opt["tensors"]["t"]["idx"])[0]
    old_m = np.asarray(t["m"])[0]
    new_m = np.asarray(new_opt["tensors"]["t"]["m"])[0]
    lut = dict(zip(old_i.tolist(), old_m.tolist()))
    for j, mm in zip(new_i, new_m):
        assert mm == pytest.approx(lut.get(int(j), 0.0)), int(j)
    assert set(new_i.tolist()) != set(old_i.tolist())
    # the refreshed mask is still whole blocks
    r, c = new_i // cols, new_i % cols
    blocks = set(zip((r // bs).tolist(), (c // bs).tolist()))
    assert len(blocks) * bs * bs == k


def test_structured_kernel_rejects_nondivisible_shapes():
    """The kernel entry points refuse non-tiling structured geometry
    loudly instead of mis-selecting."""
    from repro.kernels import ops
    a = jnp.ones((96, 4))
    b = jnp.ones((100, 4))                        # 100 % 8 != 0
    with pytest.raises(ValueError, match="does not tile"):
        ops.lift_indices(a, b, 64, block_size=8)
    b2 = jnp.ones((128, 4))
    with pytest.raises(ValueError, match="block_size"):
        ops.lift_indices(a, b2, 100, block_size=4)   # k % 16 != 0
    with pytest.raises(ValueError, match="local-quota slab"):
        # per-slab quota 72 is not a multiple of block_size^2 = 64
        ops.lift_indices_local(a, b2, 144, n_shards=2, block_size=8)


def test_validate_meta_rejects_block_size_change():
    """A checkpoint selected at one structure granularity must not
    restore under another (same k, different index rule)."""
    rows, cols, k = 64, 64, 64
    plan = _plan_1tensor((), rows, cols, k)
    unstructured = SelectionEngine(plan, LiftConfig(min_dim=16))
    structured = SelectionEngine(plan, LiftConfig(min_dim=16, block_size=4))
    with pytest.raises(ValueError, match="block_size mismatch"):
        unstructured.validate_meta(structured.plan_meta())
    with pytest.raises(ValueError, match="block_size mismatch"):
        structured.validate_meta(unstructured.plan_meta())
    structured.validate_meta(structured.plan_meta())   # self-consistent
    old = json.loads(json.dumps(unstructured.plan_meta()))
    del old["block_size"]                    # pre-structured checkpoints
    unstructured.validate_meta(old)


# ------------------------------------------------------ fused migration
@pytest.mark.parametrize("use_kernel", [False, True])
def test_fused_refresh_preserves_surviving_moments(use_kernel):
    """refresh_opt (select + migrate in one program) keeps the moments of
    every surviving index and zeroes fresh ones — under both backends."""
    rows, cols = 96, 128
    k = int(0.05 * rows * cols)
    plan = _plan_1tensor((1,), rows, cols, k)
    params = _rand_params((1,), rows, cols, jnp.float32, seed=3, rank=10)
    lcfg = LiftConfig(rank=8, method="exact", min_dim=16,
                      use_kernel=use_kernel)
    eng = SelectionEngine(plan, lcfg)
    idx0 = eng.select(params, jax.random.PRNGKey(0))
    state = sa.init_state(params, idx0, plan)
    t = state["tensors"]["t"]
    t["m"] = jnp.arange(t["m"].size, dtype=jnp.float32
                        ).reshape(t["m"].shape) + 1.0
    t["v"] = t["m"] * 10.0

    # perturb params so the refreshed mask differs
    params = {"t": params["t"] + 0.3 * jax.random.normal(
        jax.random.PRNGKey(9), params["t"].shape)}
    new_opt, stats = eng.refresh_opt(params, state, jax.random.PRNGKey(5))
    assert int(stats["overflow"]) == 0

    old_i = np.asarray(idx0["t"])[0]
    new_i = np.asarray(new_opt["tensors"]["t"]["idx"])[0]
    old_m = np.asarray(t["m"])[0]
    new_m = np.asarray(new_opt["tensors"]["t"]["m"])[0]
    lut = dict(zip(old_i.tolist(), old_m.tolist()))
    for j, mm in zip(new_i, new_m):
        assert mm == pytest.approx(lut.get(int(j), 0.0)), int(j)
    # the refresh changed something (otherwise the test proves nothing)
    assert set(new_i.tolist()) != set(old_i.tolist())


def test_lift_indices_overflow_never_leaks_sentinels():
    """Force compaction-capacity overflow (all mass in one tile): the
    overflow must be reported AND every returned index must still be a
    valid flat position — sentinels never leak into the mask."""
    from repro.kernels import ops
    m = n = 256
    # rank-1 factors with one dominant row/col block -> one hot tile
    a = jnp.ones((m, 1)).at[128:].set(1e-3)
    b = jnp.ones((n, 1)).at[128:].set(1e-3)
    k = 512
    idx, _tau, ovf = ops.lift_indices(a, b, k, capacity=128, bm=128, bn=128)
    assert int(ovf) > 0  # the probe really overflowed
    idx = np.asarray(idx)
    assert idx.shape == (k,)
    assert idx.min() >= 0 and idx.max() < m * n


# ------------------------------------------------------- plan validation
def test_make_plan_rejects_nondivisible_block_size():
    m = build_model(CFG)
    with pytest.raises(ValueError) as ei:
        make_plan(m.spec(), LiftConfig(match_rank=2, block_size=5,
                                       min_dim=16))
    msg = str(ei.value)
    assert "block_size=5" in msg
    assert "blocks/" in msg  # names the offending tensor path


def test_plan_meta_roundtrip_and_mismatch():
    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", min_dim=16)
    eng = SelectionEngine.from_spec(m.spec(), lcfg)
    meta = json.loads(json.dumps(eng.plan_meta()))  # JSON round-trip
    eng.validate_meta(meta)          # self-consistent
    eng.validate_meta(None)          # pre-engine checkpoints pass through

    bad = json.loads(json.dumps(meta))
    path = sorted(bad["tensors"])[0]
    bad["tensors"][path]["k"] += 8
    with pytest.raises(ValueError, match="geometry mismatch"):
        eng.validate_meta(bad)

    bad2 = json.loads(json.dumps(meta))
    bad2["tensors"]["not/a/tensor"] = bad2["tensors"][path]
    with pytest.raises(ValueError, match="different tensors"):
        eng.validate_meta(bad2)


def test_validate_meta_rejects_quota_policy_change():
    """A checkpoint selected under one quota policy must not restore
    under another: the tensor geometry is identical in both modes, but
    the (ns, k) index sets were chosen by a different rule."""
    m = build_model(CFG)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", min_dim=16)
    eng = SelectionEngine.from_spec(m.spec(), lcfg)
    local = SelectionEngine.from_spec(
        m.spec(), lcfg.replace(quota="local", quota_shards=4))
    with pytest.raises(ValueError, match="quota mismatch"):
        eng.validate_meta(local.plan_meta())
    with pytest.raises(ValueError, match="quota mismatch"):
        local.validate_meta(eng.plan_meta())
    # a different LOCAL shard count is a different policy too
    local8 = SelectionEngine.from_spec(
        m.spec(), lcfg.replace(quota="local", quota_shards=8))
    with pytest.raises(ValueError, match="quota mismatch"):
        local.validate_meta(local8.plan_meta())
    local.validate_meta(local.plan_meta())   # self-consistent
    # pre-quota checkpoints (no "quota" key) still pass through
    old = json.loads(json.dumps(eng.plan_meta()))
    del old["quota"], old["quota_shards"]
    eng.validate_meta(old)


# ------------------------------------------------------------ end-to-end
def test_smoke_train_streaming_subprocess():
    """`launch.train --smoke --method lift --use-kernel` must run init +
    refresh through the streaming SelectionEngine end-to-end."""
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "qwen3-1.7b", "--smoke", "--method", "lift",
           "--use-kernel", "--steps", "2", "--batch", "2", "--seq", "16",
           "--update-interval", "2", "--data-size", "64"]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "mask refresh dispatched at step 2" in out.stdout
    assert "done" in out.stdout


# ------------------------------------------- overflow-adaptive capacity
def test_overflow_retry_recovers_clean_selection():
    """ROADMAP item: a compaction overflow (candidates concentrated in
    one tile beyond its capacity) is recovered host-side by re-running
    ONLY the affected tensor at doubled compact_factor — bitwise equal
    to what the fused program returns with enough capacity, and the
    fused refresh re-migrates the fixed mask's moments."""
    rows = cols = 512                       # pick_block -> 256 => 4 tiles
    k = 1024
    plan = _plan_1tensor((), rows, cols, k)
    rng = np.random.default_rng(0)
    w = rng.normal(size=(rows, cols)).astype(np.float32) * 1e-4
    w[:256, :256] += rng.normal(size=(256, 256)).astype(np.float32) * 10.0
    params = {"t": jnp.asarray(w)}
    cfg = LiftConfig(rank=32, method="exact", use_kernel=True,
                     compact_factor=1, min_dim=16)
    eng = SelectionEngine(plan, cfg)
    key = jax.random.PRNGKey(0)
    idx, stats = eng.select_with_stats(params, key)
    assert int(stats["overflow"]) > 0
    assert int(stats["overflow_by_path"]["t"]) == int(stats["overflow"])

    fixed, retried, unresolved = eng.retry_overflow(params, key, idx, stats)
    assert retried == ["t"] and not unresolved
    big = SelectionEngine(plan, cfg.replace(compact_factor=8))
    want, big_stats = big.select_with_stats(params, key)
    assert int(big_stats["overflow"]) == 0
    assert np.array_equal(np.asarray(fixed["t"]), np.asarray(want["t"]))

    # refresh wiring: make_refresh_step retries and re-migrates in place
    from repro.training import trainer as T

    class _NoSpec:  # engine passed explicitly; spec() must not be needed
        def spec(self):
            raise AssertionError("refresh must reuse the given engine")

    method = T.MethodConfig(kind="lift", lift=cfg)
    state = {"step": jnp.zeros((), jnp.int32),
             "opt": sa.init_state(params, want, plan)}
    # drop the factor the retry above persisted so the refresh exercises
    # the overflow->retry wiring from a cold engine (the persistence
    # itself is covered by test_overflow_retry_persists_adapted_factor)
    eng.adapted_factors.clear()
    refresh = T.make_refresh_step(_NoSpec(), method, engine=eng)
    new_state = refresh(params, state, key)
    assert refresh.retried_history and \
        refresh.retried_history[0][0] == ("t",)
    assert np.array_equal(
        np.asarray(new_state["opt"]["tensors"]["t"]["idx"]),
        np.asarray(want["t"]))


def test_overflow_retry_persists_adapted_factor():
    """Satellite (ROADMAP follow-up): the compact_factor a retry had to
    raise is PERSISTED per tensor in engine state — the next fused
    selection starts at the adapted capacity, reports zero overflow, and
    returns the recovered indices without another host-side retry."""
    rows = cols = 512
    k = 1024
    plan = _plan_1tensor((), rows, cols, k)
    rng = np.random.default_rng(0)
    w = rng.normal(size=(rows, cols)).astype(np.float32) * 1e-4
    w[:256, :256] += rng.normal(size=(256, 256)).astype(np.float32) * 10.0
    params = {"t": jnp.asarray(w)}
    cfg = LiftConfig(rank=32, method="exact", use_kernel=True,
                     compact_factor=1, min_dim=16)
    eng = SelectionEngine(plan, cfg)
    key = jax.random.PRNGKey(0)
    idx, stats = eng.select_with_stats(params, key)
    assert int(stats["overflow"]) > 0
    fixed, retried, unresolved = eng.retry_overflow(params, key, idx, stats)
    assert retried == ["t"] and not unresolved
    assert eng.adapted_factors["t"] > cfg.compact_factor

    # the NEXT fused selection runs at the adapted capacity: clean, and
    # bitwise equal to the retry's recovered indices
    idx2, stats2 = eng.select_with_stats(params, key)
    assert int(stats2["overflow"]) == 0
    assert np.array_equal(np.asarray(idx2["t"]), np.asarray(fixed["t"]))
    out, retried2, _ = eng.retry_overflow(params, key, idx2, stats2)
    assert retried2 == []         # nothing left to recover


def test_overflow_retry_noop_when_clean():
    plan = _plan_1tensor((), 128, 192, 64)
    params = _rand_params((), 128, 192, jnp.float32, seed=4, rank=12)
    cfg = LiftConfig(rank=8, method="exact", use_kernel=True, min_dim=16)
    eng = SelectionEngine(plan, cfg)
    idx, stats = eng.select_with_stats(params, jax.random.PRNGKey(0))
    assert int(stats["overflow"]) == 0
    out, retried, unresolved = eng.retry_overflow(
        params, jax.random.PRNGKey(0), idx, stats)
    assert retried == [] and unresolved == []
    assert np.array_equal(np.asarray(out["t"]), np.asarray(idx["t"]))
