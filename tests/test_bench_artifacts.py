"""Benchmark artifact schema (docs/CI.md): the BENCH_*.json documents CI
uploads must validate, and the validator must catch the semantic
invariants (index agreement, per-device buffer bound) — those gate the
job; absolute timings never do."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_schema import SCHEMA_VERSION, validate  # noqa: E402
from benchmarks.common import (_parse_derived, bench_doc,  # noqa: E402
                               write_bench_json)


def _rows():
    return [
        {"name": "kern/x", "us_per_call": 1.5, "derived": "a=1;b=2.5;c=z",
         "metrics": {"a": 1, "b": 2.5, "c": "z"}},
        {"name": "sel/64x64-d0.05-streaming", "us_per_call": 2.0,
         "derived": "agree=1.00000", "metrics": {"agree": 1.0}},
        {"name": "shardsel/64x64-d0.05-s4", "us_per_call": 0.0,
         "derived": "within_bound=True",
         "metrics": {"within_bound": True, "buffer_slots_per_device": 10,
                     "bound_slots_per_device": 20}},
    ]


def test_valid_doc_roundtrips(tmp_path):
    path = tmp_path / "BENCH_kernels_micro.json"
    write_bench_json(str(path), _rows(), suite="kernels_micro")
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == SCHEMA_VERSION
    assert validate(doc) == []
    assert doc["rows"][0]["metrics"] == {"a": 1, "b": 2.5, "c": "z"}


def test_parse_derived_fallback_for_legacy_rows():
    assert _parse_derived("k=3;f=0.5;s=abc;malformed") == {
        "k": 3, "f": 0.5, "s": "abc"}
    doc = bench_doc([{"name": "fig/x", "us_per_call": 0.0,
                      "derived": "r4=0.17;r8=0.22"}], suite="fig17")
    assert doc["rows"][0]["metrics"] == {"r4": 0.17, "r8": 0.22}
    assert validate(doc) == []


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d.pop("rows"), "rows"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d["rows"][0].update(us_per_call=-1), "us_per_call"),
    (lambda d: d["rows"][1]["metrics"].update(agree=0.5), "agreement"),
    (lambda d: d["rows"][2]["metrics"].update(within_bound=False),
     "within_bound"),
])
def test_validator_catches_violations(mutate, expect):
    doc = bench_doc(_rows(), suite="kernels_micro")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def _delta_rows():
    return [
        {"name": "merge/4x256x512-d0.05-kernel", "us_per_call": 1.0,
         "derived": "matches_ref=True",
         "metrics": {"matches_ref": True, "density": 0.05}},
        {"name": "ratio/4x256x512-d0.05", "us_per_call": 0.0,
         "derived": "bytes_ratio=0.1",
         "metrics": {"bytes_ratio": 0.1, "density": 0.05}},
        {"name": "ratio/4x256x512-d0.1", "us_per_call": 0.0,
         "derived": "bytes_ratio=0.2",
         "metrics": {"bytes_ratio": 0.2, "density": 0.1}},
    ]


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d["rows"][0]["metrics"].update(matches_ref=False),
     "matches_ref"),
    (lambda d: d["rows"][1]["metrics"].update(bytes_ratio=0.15), "12%"),
    (lambda d: d["rows"][1]["metrics"].pop("bytes_ratio"), "bytes_ratio"),
])
def test_delta_merge_invariants(mutate, expect):
    """The delta-artifact size bound (<= 12% of dense at <= 5% density)
    and kernel/ref parity gate CI; a 0.2 ratio at density 0.1 is fine."""
    doc = bench_doc(_delta_rows(), suite="delta_merge")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def _paged_rows():
    return [
        {"name": "decode/mixed-8req-paged", "us_per_call": 1.0,
         "derived": "matches_dense=True",
         "metrics": {"matches_dense": True, "tok_s": 50.0,
                     "concurrency": 8}},
        {"name": "kvbytes/mixed-8req", "us_per_call": 0.0,
         "derived": "kv_bytes_ratio=0.4",
         "metrics": {"kv_bytes_ratio": 0.4, "within_live_bound": True,
                     "peak_kv_bytes": 1000, "peak_live_tokens": 100}},
    ]


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d["rows"][0]["metrics"].update(matches_dense=False),
     "matches_dense"),
    (lambda d: d["rows"][1]["metrics"].update(kv_bytes_ratio=1.2),
     "live working set"),
    (lambda d: d["rows"][1]["metrics"].pop("kv_bytes_ratio"),
     "kv_bytes_ratio"),
    (lambda d: d["rows"][1]["metrics"].update(within_live_bound=False),
     "within_live_bound"),
])
def test_paged_decode_invariants(mutate, expect):
    """PagedKV gates (DESIGN.md §5): token identity to the dense engine
    and KV memory bounded by the live working set fail CI; throughput
    never does."""
    doc = bench_doc(_paged_rows(), suite="paged_decode")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def test_writer_refuses_invalid_rows(tmp_path):
    bad = [{"name": "shardsel/overflowing", "us_per_call": 0.0,
            "derived": "", "metrics": {"within_bound": False}}]
    with pytest.raises(ValueError, match="within_bound"):
        write_bench_json(str(tmp_path / "x.json"), bad,
                         suite="kernels_micro")
    assert not (tmp_path / "x.json").exists()
