"""Benchmark artifact schema (docs/CI.md): the BENCH_*.json documents CI
uploads must validate, and the validator must catch the semantic
invariants (index agreement, per-device buffer bound) — those gate the
job; absolute timings never do."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_schema import SCHEMA_VERSION, validate  # noqa: E402
from benchmarks.common import (_parse_derived, bench_doc,  # noqa: E402
                               write_bench_json)
from benchmarks.compare import compare_docs  # noqa: E402


def _rows():
    return [
        {"name": "kern/x", "us_per_call": 1.5, "derived": "a=1;b=2.5;c=z",
         "metrics": {"a": 1, "b": 2.5, "c": "z"}},
        {"name": "sel/64x64-d0.05-streaming", "us_per_call": 2.0,
         "derived": "agree=1.00000", "metrics": {"agree": 1.0}},
        {"name": "selstruct/64x64-d0.05-bs4-streaming", "us_per_call": 2.0,
         "derived": "matches_dense=True;agree=1.00000",
         "metrics": {"agree": 1.0, "matches_dense": True,
                     "block_size": 4, "hbm_bytes_modeled": 4096}},
        {"name": "shardsel/64x64-d0.05-s4", "us_per_call": 0.0,
         "derived": "within_bound=True",
         "metrics": {"within_bound": True, "buffer_slots_per_device": 10,
                     "bound_slots_per_device": 20}},
    ]


def test_valid_doc_roundtrips(tmp_path):
    path = tmp_path / "BENCH_kernels_micro.json"
    write_bench_json(str(path), _rows(), suite="kernels_micro")
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == SCHEMA_VERSION
    assert validate(doc) == []
    assert doc["rows"][0]["metrics"] == {"a": 1, "b": 2.5, "c": "z"}


def test_parse_derived_fallback_for_legacy_rows():
    assert _parse_derived("k=3;f=0.5;s=abc;malformed") == {
        "k": 3, "f": 0.5, "s": "abc"}
    doc = bench_doc([{"name": "fig/x", "us_per_call": 0.0,
                      "derived": "r4=0.17;r8=0.22"}], suite="fig17")
    assert doc["rows"][0]["metrics"] == {"r4": 0.17, "r8": 0.22}
    assert validate(doc) == []


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d.pop("rows"), "rows"),
    (lambda d: d.update(schema_version=99), "schema_version"),
    (lambda d: d["rows"][0].update(us_per_call=-1), "us_per_call"),
    (lambda d: d["rows"][1]["metrics"].update(agree=0.5), "agreement"),
    (lambda d: d["rows"][2]["metrics"].update(matches_dense=False),
     "matches_dense"),
    (lambda d: d["rows"][2]["metrics"].update(agree=0.9), "agreement"),
    (lambda d: d["rows"][2]["metrics"].pop("matches_dense"),
     "matches_dense"),
    (lambda d: d["rows"][3]["metrics"].update(within_bound=False),
     "within_bound"),
])
def test_validator_catches_violations(mutate, expect):
    doc = bench_doc(_rows(), suite="kernels_micro")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def _delta_rows():
    return [
        {"name": "merge/4x256x512-d0.05-kernel", "us_per_call": 1.0,
         "derived": "matches_ref=True",
         "metrics": {"matches_ref": True, "density": 0.05}},
        {"name": "ratio/4x256x512-d0.05", "us_per_call": 0.0,
         "derived": "bytes_ratio=0.1",
         "metrics": {"bytes_ratio": 0.1, "density": 0.05}},
        {"name": "ratio/4x256x512-d0.1", "us_per_call": 0.0,
         "derived": "bytes_ratio=0.2",
         "metrics": {"bytes_ratio": 0.2, "density": 0.1}},
    ]


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d["rows"][0]["metrics"].update(matches_ref=False),
     "matches_ref"),
    (lambda d: d["rows"][1]["metrics"].update(bytes_ratio=0.15), "12%"),
    (lambda d: d["rows"][1]["metrics"].pop("bytes_ratio"), "bytes_ratio"),
])
def test_delta_merge_invariants(mutate, expect):
    """The delta-artifact size bound (<= 12% of dense at <= 5% density)
    and kernel/ref parity gate CI; a 0.2 ratio at density 0.1 is fine."""
    doc = bench_doc(_delta_rows(), suite="delta_merge")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def _paged_rows():
    return [
        {"name": "decode/mixed-8req-paged", "us_per_call": 1.0,
         "derived": "matches_dense=True",
         "metrics": {"matches_dense": True, "tok_s": 50.0,
                     "concurrency": 8}},
        {"name": "kvbytes/mixed-8req", "us_per_call": 0.0,
         "derived": "kv_bytes_ratio=0.4",
         "metrics": {"kv_bytes_ratio": 0.4, "within_live_bound": True,
                     "peak_kv_bytes": 1000, "peak_live_tokens": 100}},
    ]


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d["rows"][0]["metrics"].update(matches_dense=False),
     "matches_dense"),
    (lambda d: d["rows"][1]["metrics"].update(kv_bytes_ratio=1.2),
     "live working set"),
    (lambda d: d["rows"][1]["metrics"].pop("kv_bytes_ratio"),
     "kv_bytes_ratio"),
    (lambda d: d["rows"][1]["metrics"].update(within_live_bound=False),
     "within_live_bound"),
])
def test_paged_decode_invariants(mutate, expect):
    """PagedKV gates (DESIGN.md §5): token identity to the dense engine
    and KV memory bounded by the live working set fail CI; throughput
    never does."""
    doc = bench_doc(_paged_rows(), suite="paged_decode")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def _quant_rows():
    return [
        {"name": "residency/small-d0.05", "us_per_call": 0.0,
         "derived": "hbm_bytes_ratio=0.37",
         "metrics": {"hbm_bytes_ratio": 0.37, "tensors": 7,
                     "density": 0.05}},
        {"name": "parity/f32-perchan", "us_per_call": 1.0,
         "derived": "matches_ref=True",
         "metrics": {"matches_ref": True, "bn": 32}},
        {"name": "divergence/logits-d0.05", "us_per_call": 0.0,
         "derived": "max_logit_divergence=0.09;bound=0.25",
         "metrics": {"max_logit_divergence": 0.09, "bound": 0.25,
                     "within_bound": True}},
        {"name": "identity/pool-mixed-int8", "us_per_call": 1.0,
         "derived": "matches_ref=True;adapters_mixed=2",
         "metrics": {"matches_ref": True, "adapters_mixed": 2}},
    ]


@pytest.mark.parametrize("mutate, expect", [
    (lambda d: d["rows"][0]["metrics"].update(hbm_bytes_ratio=0.6),
     "55%"),
    (lambda d: d["rows"][0]["metrics"].pop("hbm_bytes_ratio"),
     "hbm_bytes_ratio"),
    (lambda d: d["rows"][1]["metrics"].update(matches_ref=False),
     "bitwise"),
    (lambda d: d["rows"][2]["metrics"].update(within_bound=False),
     "within_bound"),
    (lambda d: d["rows"][2]["metrics"].pop("max_logit_divergence"),
     "max_logit_divergence"),
    (lambda d: d["rows"][3]["metrics"].update(matches_ref=False),
     "moved a token"),
    (lambda d: d["rows"][3]["metrics"].update(adapters_mixed=1),
     "adapters_mixed"),
])
def test_quant_invariants(mutate, expect):
    """Quantized-base gates (DESIGN.md §12): residency bound, bitwise
    kernel/oracle parity, divergence bound, greedy token identity."""
    doc = bench_doc(_quant_rows(), suite="quant")
    assert validate(doc) == []
    mutate(doc)
    errs = validate(doc)
    assert errs and any(expect in e for e in errs), (expect, errs)


def test_quant_compare_guards():
    """The baseline gate never lets the committed divergence bound
    loosen, and holds hbm_bytes_ratio within +5%."""
    base = bench_doc(_quant_rows(), suite="quant")
    cur = json.loads(json.dumps(base))
    cur["rows"][2]["metrics"]["bound"] = 0.30        # loosened bound
    errs = compare_docs(cur, base)
    assert any("bound regressed" in e for e in errs), errs
    cur = json.loads(json.dumps(base))
    cur["rows"][0]["metrics"]["hbm_bytes_ratio"] = 0.45   # > +5%
    errs = compare_docs(cur, base)
    assert any("hbm_bytes_ratio regressed" in e for e in errs), errs
    cur = json.loads(json.dumps(base))
    cur["rows"][0]["metrics"]["hbm_bytes_ratio"] = 0.38   # within +5%
    cur["rows"][2]["metrics"]["max_logit_divergence"] = 0.10  # within +25%
    assert compare_docs(cur, base) == []


# ----------------------------------------------- baseline regression gate
def _baseline_doc():
    return bench_doc(_rows(), suite="kernels_micro")


def test_compare_passes_on_identical_docs():
    base = _baseline_doc()
    assert compare_docs(json.loads(json.dumps(base)), base) == []


def test_compare_ignores_wall_time_and_unguarded_metrics():
    """Absolute timings and unguarded metrics NEVER gate: a 100x slower
    run with identical semantics passes."""
    base = _baseline_doc()
    cur = json.loads(json.dumps(base))
    for r in cur["rows"]:
        r["us_per_call"] = r["us_per_call"] * 100 + 1e6
    cur["rows"][0]["metrics"]["a"] = 999     # unguarded metric
    assert compare_docs(cur, base) == []


@pytest.mark.parametrize("mutate, expect", [
    # coverage regression: a baseline row vanished
    (lambda d: d["rows"].pop(2), "missing from the current artifact"),
    # guarded bool flipped
    (lambda d: d["rows"][2]["metrics"].update(matches_dense=False),
     "matches_dense regressed"),
    # guarded ratio grew beyond tolerance
    (lambda d: d["rows"][2]["metrics"].update(hbm_bytes_modeled=999999),
     "hbm_bytes_modeled regressed"),
    # guarded agreement dropped beyond tolerance
    (lambda d: d["rows"][1]["metrics"].update(agree=0.99),
     "agree regressed"),
    # guarded metric disappeared
    (lambda d: d["rows"][1]["metrics"].pop("agree"), "disappeared"),
])
def test_compare_catches_regressions(mutate, expect):
    base = _baseline_doc()
    cur = json.loads(json.dumps(base))
    mutate(cur)
    errs = compare_docs(cur, base)
    assert errs and any(expect in e for e in errs), (expect, errs)


def test_compare_tolerates_small_drift_and_new_rows():
    base = _baseline_doc()
    cur = json.loads(json.dumps(base))
    cur["rows"][1]["metrics"]["agree"] = 0.999      # within abs_tol 0.002
    cur["rows"][2]["metrics"]["hbm_bytes_modeled"] = 4300  # within +10%
    cur["rows"].append({"name": "sel/new-row-streaming",
                       "us_per_call": 1.0, "derived": "",
                        "metrics": {"agree": 1.0}})
    assert compare_docs(cur, base) == []


def test_committed_baselines_are_valid_and_self_consistent():
    """The baselines the CI gate runs against must themselves pass the
    schema AND compare clean against themselves (guards a malformed
    re-baseline commit)."""
    bdir = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines")
    names = sorted(os.listdir(bdir))
    assert "BENCH_kernels_micro.json" in names
    for name in names:
        with open(os.path.join(bdir, name)) as f:
            doc = json.load(f)
        assert validate(doc) == [], name
        assert compare_docs(json.loads(json.dumps(doc)), doc) == [], name
    # the kernels_micro baseline must cover the structured rows the
    # acceptance criteria gate on
    with open(os.path.join(bdir, "BENCH_kernels_micro.json")) as f:
        km = json.load(f)
    names = [r["name"] for r in km["rows"]]
    for bs in (1, 4, 8):
        assert any(f"-bs{bs}-streaming" in n for n in names), (bs, names)


def test_writer_refuses_invalid_rows(tmp_path):
    bad = [{"name": "shardsel/overflowing", "us_per_call": 0.0,
            "derived": "", "metrics": {"within_bound": False}}]
    with pytest.raises(ValueError, match="within_bound"):
        write_bench_json(str(tmp_path / "x.json"), bad,
                         suite="kernels_micro")
    assert not (tmp_path / "x.json").exists()
