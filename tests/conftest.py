import os
import sys

# src/ layout import path (tests run as `PYTHONPATH=src pytest tests/`,
# but make it work without the env var too)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — tests must see the real single
# device; only launch/dryrun.py requests 512 placeholder devices.

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
