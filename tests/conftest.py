import os
import sys

# src/ layout import path (tests run as `PYTHONPATH=src pytest tests/`,
# but make it work without the env var too)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — tests must see the real single
# device; only launch/dryrun.py requests 512 placeholder devices.

import gc  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Release compiled executables between test modules.

    Every XLA:CPU executable keeps JIT code pages mapped for the life of
    the process; a full-suite run accumulates tens of thousands of maps
    and segfaults inside `backend_compile` when it crosses the kernel's
    `vm.max_map_count` (65530 by default) — deterministically, in
    whichever innocent test compiles next.  Dropping the jit caches at
    module teardown bounds the accumulation; module-internal
    compile-count invariants (e.g. decode_compilations == 1) are
    unaffected because the clear runs after the module finishes.
    """
    yield
    jax.clear_caches()
    gc.collect()
