"""Deterministic stand-in for `hypothesis` when it isn't installed.

The CI image pins hypothesis (requirements.txt), but the bare container
this repo sometimes runs on does not ship it, and a module-level
`from hypothesis import ...` kills collection for the WHOLE file —
including the non-property tests.  Test modules therefore do:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypothesis_fallback import given, settings, st

This shim re-implements just the strategy surface those tests use
(`st.integers`, `st.floats`, `st.sampled_from`) with a fixed-seed RNG:
each @given test runs `max_examples` deterministic samples.  No shrinking,
no database — strictly weaker than hypothesis, strictly stronger than
skipping the module.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class st:  # noqa: N801 — mimics `hypothesis.strategies` module naming
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))])


def given(*strategies):
    def deco(fn):
        # deliberately NOT functools.wraps: pytest must see a zero-arg
        # signature (hypothesis likewise swallows the generated params),
        # otherwise it hunts for fixtures named after them
        def run():
            rng = np.random.default_rng(0)
            for _ in range(getattr(run, "_max_examples", 10)):
                fn(*(s.sample(rng) for s in strategies))
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run._max_examples = getattr(fn, "_max_examples", 10)
        return run
    return deco


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
