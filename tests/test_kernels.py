"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref


def _factors(m, n, r, dtype, seed=0):
    a = jax.random.normal(jax.random.PRNGKey(seed), (m, r)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, r)).astype(dtype)
    return a, b


SHAPES = [(128, 128, 8), (256, 128, 16), (384, 512, 24), (512, 256, 33)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,n,r", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_lowrank_abs_sweep(m, n, r, dtype):
    a, b = _factors(m, n, r, dtype)
    got = ops.lowrank_abs(a, b, bm=128, bn=128)
    want = ref.lowrank_abs(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,n,r", SHAPES)
def test_lowrank_count_and_absmax_sweep(m, n, r):
    a, b = _factors(m, n, r, jnp.float32, seed=7)
    s = ref.lowrank_abs(a, b)
    for q in (0.5, 0.95, 0.999):
        tau = float(jnp.quantile(s, q))
        got = int(ops.lowrank_count(a, b, tau, bm=128, bn=128))
        want = int(ref.lowrank_count(a, b, tau))
        assert got == want, (q, got, want)
    np.testing.assert_allclose(float(ops.lowrank_absmax(a, b, bm=128, bn=128)),
                               float(ref.lowrank_absmax(a, b)), rtol=1e-6)


@pytest.mark.parametrize("nbins", [16, 64, 256])
def test_lowrank_hist_sweep(nbins):
    a, b = _factors(256, 384, 16, jnp.float32, seed=3)
    hi = float(ref.lowrank_absmax(a, b)) * 1.000001
    got = ops.lowrank_hist(a, b, 0.0, hi, nbins=nbins, bm=128, bn=128)
    want = ref.lowrank_hist(a, b, 0.0, hi, nbins)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) == 256 * 384


@pytest.mark.parametrize("bs", [2, 4, 8])
def test_block_summed_stats_match_oracle(bs):
    """count/absmax/hist at block granularity == the same stats computed
    on the dense block-score oracle (structured LIFT, App. G.7)."""
    a, b = _factors(128, 192, 12, jnp.float32, seed=5)
    sb = np.asarray(ref.lowrank_block_scores(a, b, bs))
    got_max = float(ops.lowrank_absmax(a, b, 64, 64, bs))
    np.testing.assert_allclose(got_max, float(sb.max()), rtol=1e-6)
    for q in (0.5, 0.95):
        tau = float(np.quantile(sb, q))
        got = int(ops.lowrank_count(a, b, tau, 64, 64, bs))
        assert got == int((sb > tau).sum()), (q, got)
    hi = float(sb.max()) * 1.000001
    nbins = 64
    got_h = np.asarray(ops.lowrank_hist(a, b, 0.0, hi, nbins, 64, 64, bs))
    ids = np.clip(np.floor(sb / (hi / nbins)), 0, nbins - 1).astype(int)
    assert np.array_equal(got_h, np.bincount(ids.ravel(), minlength=nbins))
    assert int(got_h.sum()) == sb.size


@pytest.mark.parametrize("bs", [2, 4])
def test_block_compact_matches_block_threshold_oracle(bs):
    """The block-compaction kernel emits exactly the above-tau BLOCK
    indices (ascending, slot-padded) the dense oracle predicts."""
    a, b = _factors(128, 192, 12, jnp.float32, seed=9)
    sb = np.asarray(ref.lowrank_block_scores(a, b, bs))
    tau = float(np.quantile(sb, 0.9))
    kb = int((sb > tau).sum())
    tiles, counts = ops.lowrank_compact(a, b, tau, capacity=1024,
                                        bm=64, bn=64, bs=bs)
    assert int(counts.sum()) == kb
    got = np.sort(np.asarray(tiles).reshape(-1))[:kb]
    want = np.asarray(ref.block_threshold_indices(a, b, tau, kb, bs))
    assert np.array_equal(got, np.sort(want))


def test_expand_block_indices_matches_dense_expansion():
    from repro.core.lift import topk_indices
    bs, rows, cols = 4, 32, 48
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (rows, cols)))
    blocks = s.reshape(rows // bs, bs, cols // bs, bs).sum(axis=(1, 3))
    kb = 6
    _, bidx = jax.lax.top_k(blocks.reshape(-1), kb)
    got = ops.expand_block_indices(jnp.sort(bidx), cols // bs, cols, bs)
    want = topk_indices(s, kb * bs * bs, bs)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("density", [0.01, 0.05, 0.2])
def test_lift_mask_threshold_accuracy(density):
    a, b = _factors(384, 512, 24, jnp.float32, seed=11)
    k = int(density * 384 * 512)
    mask, tau = ops.lift_mask(a, b, k, bm=128, bn=128)
    cnt = int(mask.sum())
    assert k <= cnt <= k * 1.001 + 8, (k, cnt)  # within the final bin
    # top-k of the oracle must all be inside the kernel mask
    s = np.asarray(ref.lowrank_abs(a, b)).ravel()
    top = np.argpartition(-s, k - 1)[:k]
    assert np.asarray(mask).ravel()[top].all()


@pytest.mark.parametrize("N,k,bn,cap", [
    (4096, 128, 1024, 0), (4096, 128, 1024, 8), (10000, 500, 2048, 0),
    (1000, 37, 512, 0), (65536, 4096, 4096, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_adam_sweep(N, k, bn, cap, dtype):
    key = jax.random.PRNGKey(N + k)
    p = jax.random.normal(key, (N,)).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (N,)).astype(dtype)
    idx = jnp.sort(jax.random.choice(jax.random.PRNGKey(2), N, (k,),
                                     replace=False)).astype(jnp.int32)
    m = jax.random.uniform(jax.random.PRNGKey(3), (k,))
    v = jax.random.uniform(jax.random.PRNGKey(4), (k,))
    kw = dict(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, wd=0.01)
    pk, mk, vk = ops.sparse_adam(p, g, idx, m, v, 5, bn=bn, capacity=cap,
                                 **kw)
    pr, mr, vr = ref.sparse_adam(p, g, idx, m, v, step=5, **kw)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(pk, np.float32),
                               np.asarray(pr, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 2e-5)
    # untouched entries bit-identical
    mask = np.ones(N, bool)
    mask[np.asarray(idx)] = False
    assert np.array_equal(np.asarray(pk)[mask], np.asarray(p)[mask])


@settings(max_examples=15, deadline=None)
@given(st.integers(100, 3000), st.integers(1, 200), st.integers(0, 2 ** 16))
def test_prop_sparse_adam_matches_oracle(N, k, seed):
    k = min(k, N)
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.normal(size=N), jnp.float32)
    g = jnp.asarray(rng.normal(size=N), jnp.float32)
    idx = jnp.asarray(np.sort(rng.choice(N, k, replace=False)), jnp.int32)
    m = jnp.asarray(rng.uniform(size=k), jnp.float32)
    v = jnp.asarray(rng.uniform(size=k), jnp.float32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.99, eps=1e-8, wd=0.0)
    pk, mk, vk = ops.sparse_adam(p, g, idx, m, v, 2, bn=256, **kw)
    pr, mr, vr = ref.sparse_adam(p, g, idx, m, v, step=2, **kw)
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), atol=1e-6)


# ---------------------------------------------------- flash attention kernel
from repro.kernels.flash_attention import flash_attention_fwd


@pytest.mark.parametrize("S,D,H,causal", [
    (128, 64, 2, True), (256, 128, 1, True), (128, 80, 2, False),
    (256, 256, 1, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel_sweep(S, D, H, causal, dtype):
    B = 2
    key = jax.random.PRNGKey(S + D)
    q = jax.random.normal(key, (B, S, H, D)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D)).astype(dtype)
    got = flash_attention_fwd(q, k, v, causal=causal, q_blk=64, kv_blk=64)
    want = ref.naive_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_kernel_matches_jax_flash():
    from repro.nn.flash import causal_bias, flash_attention
    B, S, H, D = 1, 128, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    got = flash_attention_fwd(q, k, v, causal=True, q_blk=32, kv_blk=32)
    want = flash_attention(q, k, v, causal_bias(), D ** -0.5, 32, 32, False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
