"""Quantized-base serving contracts (DESIGN.md §12): the fused
dequant-scatter-matmul kernel and the lax fallback are BITWISE-identical
to the `kernels.ref` oracle across dtypes / scale modes / per-slot
deltas; the artifact round-trips through save/load and refuses the
wrong base or format version; overlay + adapter composition equals
merge-then-matmul; greedy decode over the quantized base is
token-identical to the fp32 reference through BOTH engines; and the
per-position logit divergence stays under the committed bound."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.deltas.format import DeltaMismatchError  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.models import ModelConfig, build_model  # noqa: E402
from repro.quant import (QuantArtifact, QuantConfig,  # noqa: E402
                         hbm_bytes_ratio, quantize)

from repro.data.synthetic import VOCAB_SIZE  # noqa: E402

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=max(VOCAB_SIZE, 97))

DIVERGENCE_BOUND = 0.25      # same committed bound as BENCH_quant.json


def _case(dtype, scale_mode, with_delta, seed=0, b=3, rows=48, cols=80,
          k=20, kd=6):
    rng = np.random.default_rng(seed)
    q = rng.integers(-127, 128, size=(rows, cols)).astype(np.int8)
    scol = cols if scale_mode == "per-channel" else 1
    scale = (rng.uniform(0.5, 2.0, size=(1, scol)) / 127.0).astype(
        np.float32)
    idx = np.sort(rng.choice(rows * cols, k, replace=False)).astype(
        np.int32)
    val = rng.normal(size=(k,)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(b, rows)).astype(np.float32),
                    dtype=dtype)
    didx = dval = None
    if with_delta:
        didx = jnp.asarray(np.stack(
            [np.sort(rng.choice(rows * cols, kd, replace=False))
             for _ in range(b)]).astype(np.int32))
        dval = jnp.asarray(rng.normal(size=(b, kd)).astype(np.float32))
    qw = {"q": jnp.asarray(q), "scale": jnp.asarray(scale),
          "idx": jnp.asarray(idx), "val": jnp.asarray(val)}
    return x, qw, didx, dval


# ------------------------------------------------------ kernel parity
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scale_mode", ["per-tensor", "per-channel"])
@pytest.mark.parametrize("with_delta", [False, True])
def test_quant_matmul_parity(dtype, scale_mode, with_delta):
    """Fused kernel (interpret) and lax fallback vs the dense oracle —
    bitwise, f32 and bf16 activations, both scale granularities, with
    and without a per-slot adapter delta in the epilogue."""
    x, qw, didx, dval = _case(dtype, scale_mode, with_delta)
    want = np.asarray(ref.quant_matmul(x, qw["q"], qw["scale"], qw["idx"],
                                       qw["val"], didx, dval))
    lax = np.asarray(ops.quant_matmul(x, qw, didx, dval, backend="lax"))
    ker = np.asarray(ops.quant_matmul(x, qw, didx, dval,
                                      backend="kernel", bn=32,
                                      interpret=True))
    np.testing.assert_array_equal(lax, want)
    np.testing.assert_array_equal(ker, want)


def test_quant_matmul_nondividing_block():
    """bn that does not divide cols exercises the padded tail columns:
    zero-padded q/scale contribute exactly 0 and slicing restores the
    logical width — still bitwise."""
    x, qw, didx, dval = _case(jnp.float32, "per-channel", True)
    want = np.asarray(ref.quant_matmul(x, qw["q"], qw["scale"], qw["idx"],
                                       qw["val"], didx, dval))
    ker = np.asarray(ops.quant_matmul(x, qw, didx, dval,
                                      backend="kernel", bn=28,
                                      interpret=True))
    np.testing.assert_array_equal(ker, want)


def test_delta_overrides_principal_on_collision():
    """Sequential scatter order: an adapter entry landing on a principal
    index wins, in every backend."""
    x, qw, _, _ = _case(jnp.float32, "per-channel", False)
    k = int(qw["idx"].shape[0])
    b = int(x.shape[0])
    didx = jnp.broadcast_to(qw["idx"][:4][None], (b, 4))
    dval = jnp.asarray(
        np.arange(b * 4, dtype=np.float32).reshape(b, 4) + 100.0)
    want = np.asarray(ref.quant_matmul(x, qw["q"], qw["scale"], qw["idx"],
                                       qw["val"], didx, dval))
    for backend in ("lax", "kernel"):
        got = np.asarray(ops.quant_matmul(x, qw, didx, dval,
                                          backend=backend, bn=32,
                                          interpret=True))
        np.testing.assert_array_equal(got, want, err_msg=backend)
    # and the result actually differs from the principal-only matmul
    plain = np.asarray(ops.quant_matmul(x, qw, backend="lax"))
    assert not np.array_equal(want, plain)


def test_overlay_composition_matches_merge_then_matmul():
    """`quant_overlay_matmul` (the nn-layer entry point) composes base +
    principal + per-slot delta identically to merging the dense weight
    first — for decode (B, d), one-token (B, 1, d) and multi-query
    (B, T, d) activation shapes."""
    x2, qw, didx, dval = _case(jnp.float32, "per-channel", True)
    ov = {"idx": didx, "val": dval}
    want = np.asarray(ref.quant_matmul(x2, qw["q"], qw["scale"],
                                       qw["idx"], qw["val"], didx, dval))
    got2 = np.asarray(ops.quant_overlay_matmul(x2, qw, ov))
    np.testing.assert_array_equal(got2, want)
    got3 = np.asarray(ops.quant_overlay_matmul(x2[:, None, :], qw, ov))
    np.testing.assert_array_equal(got3[:, 0, :], want)
    # (B, T, d): per-position columns of the same per-slot merged weight
    xT = jnp.stack([x2, x2 * 0.5], axis=1)
    gotT = np.asarray(ops.quant_overlay_matmul(xT, qw, ov))
    np.testing.assert_array_equal(gotT[:, 0, :], want)


# --------------------------------------------------- artifact round-trip
@pytest.fixture(scope="module")
def quantized():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    art = quantize(model, params, QuantConfig(density=0.05),
                   jax.random.PRNGKey(1))
    return model, params, art


def test_pack_roundtrip(tmp_path, quantized):
    model, params, art = quantized
    assert hbm_bytes_ratio(art) <= 0.55
    art.check_against(params)            # overlay values == base entries
    art.save(str(tmp_path / "q"))
    loaded = QuantArtifact.load(str(tmp_path / "q"))
    assert loaded.manifest == art.manifest
    for path, t in art.tensors.items():
        for part in ("q", "scale", "idx", "val"):
            np.testing.assert_array_equal(loaded.tensors[path][part],
                                          t[part], err_msg=f"{path}/{part}")
    a = jax.tree.leaves(art.to_params(params))
    b = jax.tree.leaves(loaded.to_params(params))
    assert all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def test_refuses_wrong_base_and_version(tmp_path, quantized):
    model, params, art = quantized
    other = jax.tree.map(lambda x: x + 1e-3, params)
    with pytest.raises(DeltaMismatchError, match="base"):
        art.to_params(other)
    art.save(str(tmp_path / "q"))
    import json
    mpath = tmp_path / "q" / "quant.json"
    m = json.loads(mpath.read_text())
    m["format_version"] = 999
    mpath.write_text(json.dumps(m))
    with pytest.raises(DeltaMismatchError, match="format_version"):
        QuantArtifact.load(str(tmp_path / "q"))


def test_quantized_forward_divergence_bound(quantized):
    """Per-position max logit divergence vs the fp32 forward stays under
    the committed BENCH_quant bound — the regression guard that keeps
    the quantizer honest without demanding bitwise logits."""
    model, params, art = quantized
    qparams = art.to_params(params)
    rng = np.random.default_rng(7)
    toks = rng.integers(3, 90, size=(4, 48)).astype(np.int32)
    lf = np.asarray(model.logits(params, {"tokens": toks}), np.float32)
    lq = np.asarray(model.logits(qparams, {"tokens": toks}), np.float32)
    assert float(np.max(np.abs(lf - lq))) <= DIVERGENCE_BOUND


# ------------------------------------------------------- e2e serving
def test_greedy_identity_both_engines():
    """Greedy decode over the int8 base + principal overlay reproduces
    the fp32 token streams through the dense AND the paged engine.  A
    briefly-trained model, not random init: identity is a claim about
    argmax margins, and random-init logits are near-ties everywhere."""
    from benchmarks.common import SMALL, make_method, train_method
    from repro.serving import Request, ServingConfig, make_engine
    from repro.serving.oracle import DenseOracle
    trained = train_method(SMALL, make_method("full"), task="arith",
                           steps=100, batch=8, seq=48, eval_n=0)
    model, params = trained["model"], trained["params"]
    art = quantize(model, params, QuantConfig(density=0.05),
                   jax.random.PRNGKey(1))
    qparams = art.to_params(params)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(3, 90, size=int(s)).astype(np.int32)
               for s in rng.integers(4, 40, size=4)]

    def serve(mk, p):
        eng = mk(p)
        for i, pr in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=pr, max_new_tokens=8,
                               temperature=0.0))
        return {r.uid: tuple(r.out_tokens) for r in eng.run()}

    ecfg = ServingConfig(batch_slots=2, max_len=64, eos_id=2)
    pcfg = ServingConfig(batch_slots=2, max_len=64, eos_id=2,
                         page_size=16, num_pages=24)
    for mk in (lambda p: DenseOracle(model, p, ecfg),
               lambda p: make_engine(model, p, pcfg)):
        assert serve(mk, qparams) == serve(mk, params)


def test_fig_super_weights_asserts_capture():
    """The figure module's own assertions (outliers survive rank
    reduction into the top-5% mask at every paper rank) run green."""
    from benchmarks import fig_super_weights
    rows = fig_super_weights.run()
    assert all(r["metrics"]["all_captured"] for r in rows)
