"""Unified telemetry (DESIGN.md §11, docs/OBSERVABILITY.md).

The contract under test, layer by layer:

  * `obs.registry` — histogram percentiles are EXACT (bitwise equal to
    `numpy.percentile(method="linear")`) while the stream fits the raw
    window and bounded by one log-bucket width after; passing a device
    array to any instrument raises instead of forcing a host sync; one
    lock makes engine-thread mutation + snapshot polling safe;
  * `obs.tracing` — spans round-trip through JSONL, the hot-path tile
    buffer drains into Spans and histograms with epoch-relative stamps,
    and `request_breakdown` reconstructs per-request wall time from the
    engine's step tiling;
  * `obs.audit` — `instrument_jit` counts new traces exactly as jax's
    own compile cache does (shape changes retrace, values never do,
    static args retrace by value) on BOTH detection paths, and
    `CompileAuditor.check` enforces the committed compile-budget
    manifest;
  * end to end — a mixed speculative + multi-adapter serve under a
    fresh ObsContext passes the committed manifest audit, its trace
    decomposes each request's latency to within 5%, and a deliberately
    un-bucketed prefill fails the audit loudly.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.lift import LiftConfig, get_by_path, make_plan
from repro.deltas import DeltaArtifact
from repro.deltas.format import make_manifest, num_stack, tree_hash
from repro.models import ModelConfig, build_model
from repro.obs.registry import Histogram, MetricsRegistry, log_edges
from repro.obs.tracing import Span, Tracer, read_jsonl, request_breakdown
from repro.serving import Request, ServingConfig
from repro.serving.kvpool import AdapterPool, PagedEngine

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)

MANIFEST = "benchmarks/compilations_manifest.json"


@pytest.fixture(scope="module")
def model_params():
    model = build_model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(n, seed=3, lo=3, hi=40):
    rng = np.random.default_rng(seed)
    return [rng.integers(3, 90, size=int(s)).astype(np.int32)
            for s in rng.integers(lo, hi, size=n)]


def _serve(model, params, prompts, ctx, *, max_new=8, speculate=0,
           apool=None, ids=None, **cfg_kw):
    eng = PagedEngine(model, params, ServingConfig(
        batch_slots=3, max_len=64, eos_id=2, page_size=8, num_pages=40,
        speculate=speculate, draft_source="ngram", **cfg_kw),
        adapter_pool=apool, obs=ctx)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new,
                           adapter_id=ids[i] if ids else None))
    eng.run()
    assert len(eng.done) == len(prompts)
    assert not any(r.error for r in eng.done)
    return {r.uid: tuple(r.out_tokens) for r in eng.done}, eng


# ------------------------------------------------------------- registry
def test_histogram_percentiles_exact_vs_numpy():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-7.0, sigma=2.0, size=513)
    h = Histogram("t", threading.RLock())
    for v in xs:
        h.observe(float(v))
    assert h.exact
    for q in (0.0, 10.0, 50.0, 90.0, 99.0, 100.0):
        assert h.percentile(q) == float(
            np.percentile(xs, q, method="linear"))
    s = h.summary()
    assert s["count"] == len(xs) and s["exact"]
    assert s["min"] == xs.min() and s["max"] == xs.max()


def test_histogram_bucket_fallback_bounded():
    """Past the raw window the estimate answers from bucket upper edges:
    within one log-bucket (10^(1/per_decade) = ~1.78x at the default 4
    per decade) of the true percentile, and `exact` flips off."""
    rng = np.random.default_rng(1)
    xs = rng.lognormal(mean=-6.0, sigma=1.5, size=400)
    h = Histogram("t", threading.RLock(), max_samples=32)
    for v in xs:
        h.observe(float(v))
    assert not h.exact and not h.summary()["exact"]
    width = 10 ** (1 / 4)
    for q in (50.0, 90.0, 99.0):
        truth = float(np.percentile(xs, q, method="linear"))
        est = h.percentile(q)
        assert truth / width <= est <= truth * width, (q, truth, est)


def test_device_values_rejected_everywhere():
    """The no-host-sync rule: a jax.Array never reaches an instrument."""
    reg = MetricsRegistry()
    dev = jnp.float32(1.0)
    with pytest.raises(TypeError, match="host sync"):
        reg.counter("c").inc(dev)
    with pytest.raises(TypeError, match="host sync"):
        reg.gauge("g").set(dev)
    with pytest.raises(TypeError, match="host sync"):
        reg.histogram("h").observe(dev)
    # host-side numpy scalars are fine
    reg.counter("c").inc(np.int64(2))
    reg.histogram("h").observe(np.float64(0.5))
    assert reg.counter("c").value == 2


def test_snapshot_and_render():
    reg = MetricsRegistry()
    reg.counter("serve.tokens").inc(7)
    reg.gauge("pool.peak").set_max(3)
    reg.gauge("pool.peak").set_max(1)          # running max keeps 3
    reg.histogram("lat").observe(0.25)
    snap = reg.snapshot()
    assert snap["counters"]["serve.tokens"] == 7
    assert snap["gauges"]["pool.peak"] == 3
    assert snap["histograms"]["lat"]["count"] == 1
    text = obs.render_snapshot(snap)
    assert "serve.tokens = 7" in text and "lat:" in text
    assert "serve.tokens" not in obs.render_snapshot(snap, prefix="pool")


def test_registry_thread_safe_under_polling():
    reg = MetricsRegistry()
    stop = threading.Event()

    def mutate():
        c = reg.counter("n")
        h = reg.histogram("h")
        while not stop.is_set():
            c.inc()
            h.observe(0.001)

    threads = [threading.Thread(target=mutate) for _ in range(3)]
    for t in threads:
        t.start()
    for _ in range(50):
        snap = reg.snapshot()
        assert snap["histograms"].get("h", {}).get("count", 0) >= 0
    stop.set()
    for t in threads:
        t.join()
    assert reg.counter("n").value == reg.histogram("h").count


# -------------------------------------------------------------- tracing
def test_span_jsonl_roundtrip(tmp_path):
    tr = Tracer()
    s = tr.begin("prefill", "prefill", uid=1, uids=(1,), C=32)
    tr.end(s, padded=32)
    tr.add("queue.wait", "queue", 0.0, 0.5, uid=2, uids=(2,))
    path = str(tmp_path / "trace.jsonl")
    assert tr.write_jsonl(path) == 2
    back = read_jsonl(path)
    assert [b["t0"] for b in back] == sorted(b["t0"] for b in back)
    by_name = {b["name"]: b for b in back}
    q = by_name["queue.wait"]
    assert q["cat"] == "queue" and q["uid"] == 2 and q["dur"] == 0.5
    p = by_name["prefill"]
    assert p["attrs"] == {"C": 32, "padded": 32} and p["uids"] == [1]


def test_tile_buffer_drains_spans_and_histograms():
    """The engines' hot path: `tile()` is one tuple append of RAW
    perf_counter stamps; `drain()` (implicit on `.spans`) materializes
    epoch-relative Spans and feeds the tile histogram."""
    tr = Tracer()
    h = Histogram("d", threading.RLock())
    t0 = tr.epoch + 1.0
    tr.tile("decode", "decode", t0, t0 + 0.25, (1, 2), (3,), h,
            {"batch": 2})
    assert not tr._spans and h.count == 0      # nothing materialized yet
    spans = tr.spans                           # property drains
    assert len(spans) == 1 and h.count == 1
    s = spans[0]
    assert (s.t0, s.t1) == (1.0, 1.25) and s.uids == (1, 2)
    assert s.co_uids == (3,) and s.attrs == {"batch": 2}
    assert h.sum == pytest.approx(0.25)
    tr.drain()                                 # idempotent
    assert len(tr.spans) == 1 and h.count == 1


def test_tracer_bounded_and_disabled():
    tr = Tracer(max_spans=2)
    for i in range(5):
        tr.add("s", "x", 0.0, 1.0, uid=i)
    assert len(tr.spans) == 2 and tr.dropped == 3
    off = Tracer(enabled=False)
    assert off.begin("a", "b") is None
    assert off.end(None) is None               # call sites stay linear
    assert off.add("a", "b", 0.0, 1.0) is None
    off.tile("a", "b", 0.0, 1.0, (), (), None, None)
    assert off.spans == [] and off.dropped == 0


def test_request_breakdown_tiling():
    spans = [
        Span("queue.wait", "queue", 0.0, 1.0, uid=1, uids=(1,)),
        Span("prefill", "prefill", 1.0, 3.0, uids=(1,), co_uids=(2,)),
        Span("decode", "decode", 3.0, 7.0, uids=(1, 2)),
        Span("request", "request", 0.0, 7.0, uid=1, uids=(1,)),
    ]
    bd = request_breakdown(spans)
    assert bd[1]["by_cat"] == {"queue": 1.0, "prefill": 2.0, "decode": 4.0}
    assert bd[1]["total"] == 7.0 and bd[1]["e2e"] == 7.0
    # request 2 waited out request 1's prefill as a co-resident
    assert bd[2]["by_cat"] == {"batch": 2.0, "decode": 4.0}
    assert bd[2]["e2e"] is None


# ---------------------------------------------------------------- audit
def _jit_probe(ctx):
    return obs.instrument_jit(
        lambda x, n: x * n, name="probe", obs=ctx)


def test_instrument_jit_counts_traces_like_jax():
    ctx = obs.ObsContext.fresh()
    fn = _jit_probe(ctx)
    a = jnp.ones((4,), jnp.float32)
    fn(a, 2)
    fn(a + 1, 2)                   # same abstract shape: cache hit
    fn(a, 3)                       # weak-typed python scalar: cache hit
    assert ctx.auditor.compilations("probe") == 1
    fn(jnp.ones((8,), jnp.float32), 2)      # new shape: retrace
    fn(jnp.ones((4,), jnp.int32), 2)        # new dtype: retrace
    assert ctx.auditor.compilations("probe") == 3
    cs = fn.cache_size()
    if cs is not None:             # cross-check vs jax's own cache
        assert cs == 3
    rep = ctx.auditor.report()["probe"]
    assert rep["calls"] == 5 and rep["compilations"] == 3


def test_instrument_jit_static_args_retrace_by_value():
    ctx = obs.ObsContext.fresh()
    fn = obs.instrument_jit(lambda x, n: x * n, name="stat", obs=ctx,
                            static_argnames=("n",))
    a = jnp.ones((4,), jnp.float32)
    fn(a, n=2)
    fn(a, n=2)
    assert ctx.auditor.compilations("stat") == 1
    fn(a, n=3)                     # static arg changed: IS a retrace
    assert ctx.auditor.compilations("stat") == 2


def test_fingerprint_fallback_matches_cache_size():
    """Force the `call_fingerprint` path (no `_cache_size` fast path)
    and hold it equal to jax's own compile count on the same calls."""
    ctx = obs.ObsContext.fresh()
    fn = _jit_probe(ctx)
    if fn.cache_size() is None:
        pytest.skip("jax version exposes no _cache_size to compare")
    fn._cs_fn = None               # fallback from the first call on
    a = jnp.ones((4,), jnp.float32)
    for arg, n in ((a, 2), (a + 1, 2), (a, 5),
                   (jnp.ones((2,), jnp.float32), 2)):
        fn(arg, n)
    assert ctx.auditor.compilations("probe") == fn.cache_size() == 2


def test_manifest_check_semantics():
    aud = obs.CompileAuditor()
    for name, fps in (("a", ("f1",)), ("b", ("f1", "f2", "f3")),
                      ("c", ("f1", "f2")), ("d", ("f1",))):
        for fp in fps:
            aud.note_call(name, fp)
    man = {"version": 1, "require_listed": True,
           "entries": {"a": {"exact": 1}, "b": {"max": 2},
                       "c": {"any": True}}}
    errs = aud.check(man)
    assert len(errs) == 2
    assert any("b: 3" in e and "re-trace" in e for e in errs)
    assert any(e.startswith("d:") and "not in the manifest" in e
               for e in errs)
    man["entries"]["b"] = {"max": 3}
    man["require_listed"] = False
    assert aud.check(man) == []
    # a name never CALLED is never audited (train vs serve manifests)
    man["entries"]["ghost"] = {"exact": 99}
    assert aud.check(man) == []
    man["entries"]["a"] = {}
    assert any("none of exact/max/any" in e for e in aud.check(man))


def test_load_manifest_validates(tmp_path):
    good = tmp_path / "m.json"
    good.write_text(json.dumps(
        {"version": 1, "entries": {"x": {"exact": 1}}}))
    assert obs.load_manifest(str(good))["entries"]["x"] == {"exact": 1}
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"version": 2, "entries": {}}))
    with pytest.raises(ValueError, match="version"):
        obs.load_manifest(str(bad))
    bad.write_text(json.dumps({"version": 1}))
    with pytest.raises(ValueError, match="entries"):
        obs.load_manifest(str(bad))


# ----------------------------------------------------- engine integration
def _plan_meta(model, density=0.05):
    plan = make_plan(model.spec(), LiftConfig(density=density, min_dim=16))
    return {p: {"shape": list(t.shape), "stack": list(t.stack),
                "rows": t.rows, "cols": t.cols, "k": t.k,
                "dtype": "float32"} for p, t in sorted(plan.items())}


def _synthetic_adapter(base_params, meta, seed):
    rng = np.random.default_rng(seed)
    tensors = {}
    for path, m in meta.items():
        ns, k = num_stack(m), m["k"]
        size = m["rows"] * m["cols"]
        idx = np.stack([np.sort(rng.choice(size, k, replace=False))
                        for _ in range(ns)]).astype(np.int32)
        base = np.asarray(get_by_path(base_params, path),
                          np.float32).reshape(ns, size)
        val = np.take_along_axis(base, idx, 1) \
            + rng.normal(scale=0.05, size=(ns, k)).astype(np.float32)
        tensors[path] = {"idx": idx, "val": val.astype(np.float32)}
    return DeltaArtifact(
        manifest=make_manifest(mode="replace",
                               base_hash=tree_hash(base_params),
                               selection=None, tensors_meta=meta, step=0),
        tensors=tensors)


def test_engine_audit_passes_committed_manifest(model_params):
    """A mixed speculative + multi-adapter serve under a fresh context:
    the committed compile-budget manifest holds, the trace has every
    step-phase category, and instrumentation never changes tokens."""
    model, params = model_params
    meta = _plan_meta(model)
    apool = AdapterPool(params, num_pages=24, entries_per_page=512)
    for aid, seed in (("a", 11), ("b", 22)):
        apool.register(aid, _synthetic_adapter(params, meta, seed))
    prompts = _prompts(6, seed=5)
    ids = ["a", "b", None, "a", "b", "a"]

    ctx = obs.ObsContext.fresh(trace=True)
    got, eng = _serve(model, params, prompts, ctx, speculate=2,
                      apool=apool, ids=ids)
    want, _ = _serve(model, params, prompts, obs.ObsContext.disabled(),
                     speculate=2, apool=apool, ids=ids)
    assert got == want                       # observability is read-only

    errs = ctx.auditor.check(obs.load_manifest(MANIFEST))
    assert errs == []
    rep = ctx.auditor.report()
    assert rep["serve.paged.verify"]["compilations"] == 1
    cats = {s.cat for s in ctx.tracer.spans}
    assert {"queue", "prefill", "verify", "accept", "pool",
            "request"} <= cats
    # the registry saw the same stream the engine counted
    snap = eng.metrics_snapshot()
    assert snap["counters"]["serve.tokens_emitted"] == \
        sum(len(t) for t in got.values())
    assert snap["histograms"]["serve.decode_step_s"]["count"] == \
        eng.decode_steps


def test_unbucketed_prefill_fails_audit_loudly(model_params):
    """The regression the auditor exists to catch: switching off prefill
    bucketing re-traces the prefill per distinct prompt length, blowing
    the manifest's serve.paged.prefill_whole budget."""
    model, params = model_params
    ctx = obs.ObsContext.fresh()
    prompts = [np.arange(3, 3 + n, dtype=np.int32).astype(np.int32)
               for n in (5, 7, 9, 14, 19, 23, 27, 31, 35, 38)]
    # 10 distinct lengths: past the max-8 budget that unbucketed
    # families (SWA/MoE/recurrent) are allowed
    _serve(model, params, prompts, ctx, prefill_buckets=False)
    errs = ctx.auditor.check(obs.load_manifest(MANIFEST))
    assert errs, "un-bucketed prefill must fail the compile audit"
    assert any("serve.paged.prefill_whole" in e and "re-trace" in e
               for e in errs)
    # the same workload WITH bucketing stays inside the budget
    ctx2 = obs.ObsContext.fresh()
    _serve(model, params, prompts, ctx2)
    assert ctx2.auditor.check(obs.load_manifest(MANIFEST)) == []


def test_trace_decomposition_within_bound(model_params):
    """queue wait + step tiles (subject or co-resident) reconstruct each
    request's submit->finish latency to within 5% in aggregate."""
    model, params = model_params
    ctx = obs.ObsContext.fresh(trace=True)
    _serve(model, params, _prompts(6, seed=9), ctx, max_new=16)
    bd = request_breakdown(ctx.tracer.spans)
    assert set(bd) == set(range(6))
    tot = sum(d["total"] for d in bd.values())
    e2e = sum(d["e2e"] for d in bd.values())
    assert all(d["e2e"] is not None for d in bd.values())
    assert abs(tot - e2e) / e2e < 0.05, (tot, e2e)
    for uid, d in bd.items():
        assert {"queue", "prefill", "decode"} <= set(d["by_cat"]), uid
        # no tile may exceed the envelope it tiles
        assert d["total"] <= d["e2e"] * 1.05, (uid, d)


def test_engine_loop_thread_vs_snapshot_polling(model_params):
    """The serving loop in one thread, a metrics reader in another —
    the single registry lock keeps both consistent (no torn reads, no
    deadlock)."""
    model, params = model_params
    ctx = obs.ObsContext.fresh(trace=True)
    eng = PagedEngine(model, params, ServingConfig(
        batch_slots=3, max_len=64, eos_id=2, page_size=8, num_pages=40),
        obs=ctx)
    for i, p in enumerate(_prompts(6, seed=4)):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=12))
    t = threading.Thread(target=eng.run)
    t.start()
    seen = 0
    while t.is_alive():
        snap = eng.metrics_snapshot()
        steps = snap["counters"].get("serve.decode_steps", 0)
        assert steps >= seen                 # monotone under the lock
        seen = steps
    t.join()
    assert len(eng.done) == 6
    assert eng.metrics_snapshot()["counters"]["serve.decode_steps"] \
        == eng.decode_steps > 0
