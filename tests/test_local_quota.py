"""Shard-local quota selection (DESIGN.md §3 'local' mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis: deterministic shim
    from hypothesis_fallback import given, settings, st

from repro.core.lift import LiftConfig, make_plan, topk_indices
from repro.core.local_quota import (compute_indices_local,
                                    local_topk_indices, overlap_with_global)
from repro.models import ModelConfig, build_model


def test_local_topk_quota_per_shard():
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (32, 64)))
    k, n = 64, 4
    idx = np.asarray(local_topk_indices(s, k, n))
    assert idx.shape == (k,)
    assert len(np.unique(idx)) == k
    # exactly k/n indices per column slab
    cols = 64
    shard = (idx % cols) // (cols // n)
    counts = np.bincount(shard, minlength=n)
    assert (counts == k // n).all(), counts


def test_local_equals_global_when_one_shard():
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (24, 48)))
    a = np.asarray(local_topk_indices(s, 40, 1))
    b = np.asarray(topk_indices(s, 40))
    assert np.array_equal(a, b)


def test_local_selects_shard_maxima():
    """Each shard's selected entries are its own top-k/n."""
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (16, 32)))
    k, n = 16, 4
    idx = np.asarray(local_topk_indices(s, k, n))
    flat = np.asarray(s).ravel()
    w = 32 // n
    for j in range(n):
        slab_cols = range(j * w, (j + 1) * w)
        slab_flat = [r * 32 + c for r in range(16) for c in slab_cols]
        slab_sel = [i for i in idx if (i % 32) // w == j]
        slab_vals = sorted((flat[i] for i in slab_flat), reverse=True)
        thresh = slab_vals[k // n - 1]
        assert all(flat[i] >= thresh - 1e-7 for i in slab_sel)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 2 ** 12))
def test_prop_local_overlap_bounds(n, seed):
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed), (32, 64)))
    k = 64
    ov = overlap_with_global(s, k, n)
    assert 0.0 <= ov <= 1.0
    if n == 1:
        assert ov == 1.0


def test_compute_indices_local_plugs_into_plan():
    cfg = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97)
    m = build_model(cfg)
    lcfg = LiftConfig(rank=8, match_rank=2, method="exact", min_dim=16,
                      k_multiple=8)
    plan = make_plan(m.spec(), lcfg)
    params = m.init(jax.random.PRNGKey(0))
    idx = compute_indices_local(params, plan, lcfg, jax.random.PRNGKey(1),
                                n_shards=4)
    for path, p in plan.items():
        a = np.asarray(idx[path])
        assert a.shape[-1] == p.k
        assert (np.diff(a, axis=-1) > 0).all()  # sorted unique
        assert a.min() >= 0 and a.max() < p.rows * p.cols


def test_compute_indices_local_rejects_ragged_with_tensor_path():
    """A plan tensor whose cols or k don't divide by n_shards must raise
    at once, naming the tensor — the historical silent fallback to a
    global top-k made 'local' selection geometry-dependent in a way no
    caller could observe."""
    from repro.core.lift import TensorPlan
    plan = {"blocks/mlp/up": TensorPlan("blocks/mlp/up", (64, 100), (),
                                        64, 100, 200)}
    params = {"blocks/mlp/up": jax.random.normal(jax.random.PRNGKey(0),
                                                 (64, 100))}
    with pytest.raises(ValueError, match="blocks/mlp/up"):
        compute_indices_local(params, plan, LiftConfig(rank=4, min_dim=16),
                              jax.random.PRNGKey(1), n_shards=8)


def test_overlap_with_global_rejects_ragged():
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (32, 60)))
    with pytest.raises(ValueError, match="divisible"):
        overlap_with_global(s, 64, 8)     # cols 60 % 8 != 0
    with pytest.raises(ValueError, match="divisible"):
        overlap_with_global(s, 63, 4)     # k 63 % 4 != 0


def test_local_topk_structured_quota_and_blocks():
    """block_size > 1 under a local quota: per-slab budgets hold exactly
    AND every selected element belongs to a fully-selected block."""
    rows, cols, k, n, bs = 64, 96, 384, 4, 4
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (rows, cols)))
    idx = np.asarray(local_topk_indices(s, k, n, block_size=bs))
    assert idx.shape == (k,)
    assert len(np.unique(idx)) == k
    shard = (idx % cols) // (cols // n)
    assert (np.bincount(shard, minlength=n) == k // n).all()
    r, c = idx // cols, idx % cols
    blocks = set(zip((r // bs).tolist(), (c // bs).tolist()))
    assert len(blocks) * bs * bs == k
    # per-slab block budget: each slab's blocks are its own top blocks
    blk = np.asarray(s).reshape(rows // bs, bs, cols // bs, bs).sum((1, 3))
    wb = (cols // bs) // n
    for j in range(n):
        slab_blocks = [(br, bc) for (br, bc) in blocks
                       if j * wb <= bc < (j + 1) * wb]
        assert len(slab_blocks) == k // (bs * bs * n)
        thresh = np.sort(blk[:, j * wb:(j + 1) * wb].ravel()
                         )[-len(slab_blocks)]
        assert all(blk[br, bc] >= thresh - 1e-6 for br, bc in slab_blocks)


def test_local_topk_structured_equals_global_when_one_shard():
    from repro.core.lift import topk_indices
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (48, 64)))
    a = np.asarray(local_topk_indices(s, 128, 1, block_size=4))
    b = np.asarray(topk_indices(s, 128, block_size=4))
    assert np.array_equal(a, b)


def test_local_topk_structured_rejects_ragged():
    s = jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (32, 60)))
    with pytest.raises(ValueError, match="block_size"):
        local_topk_indices(s, 64, 2, block_size=8)    # 60 % 8 != 0
    s2 = jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (32, 64)))
    with pytest.raises(ValueError, match="block_size"):
        local_topk_indices(s2, 72, 2, block_size=4)   # k % 16 != 0
    with pytest.raises(ValueError, match="divisible"):
        # slab (64/4=16 block cols over 32 shards) is ragged in blocks
        local_topk_indices(s2, 64, 32, block_size=4)


def test_overlap_high_on_lowrank_spectra():
    """On low-rank-structured scores (LIFT's actual regime) the quota
    deviation is small."""
    a = jax.random.normal(jax.random.PRNGKey(3), (128, 8))
    b = jax.random.normal(jax.random.PRNGKey(4), (96, 8))
    s = jnp.abs(a @ b.T)
    ov = overlap_with_global(s, 512, 8)
    assert ov > 0.8, ov
