"""DeltaHub contracts (DESIGN.md §4): the delta round-trip
extract -> save -> load -> merge reproduces the fine-tuned checkpoint
BITWISE (dense ref and Pallas scatter-merge kernel), refusal on the wrong
base hash / mismatched plan_meta, diff/apply_diff shipping round-trip,
partial checkpoint reads, and shard-local merge parity on 1/2/8 host
devices (subprocess, like test_sharded_selection, so the placeholder
devices never leak into other tests)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import sparse_adam as sa
from repro.core.lift import LiftConfig
from repro.data.loader import ShardedLoader
from repro.data.synthetic import VOCAB_SIZE, generate
from repro.deltas import (DeltaArtifact, DeltaMismatchError, apply_diff,
                          diff, extract, merge_delta)
from repro.kernels import ops, ref
from repro.models import ModelConfig, build_model
from repro.training import trainer as T

CFG = ModelConfig(family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=max(VOCAB_SIZE, 97))


def _train_lift(steps=5, seed=0, lr=1e-2):
    """Tiny fixed-mask LIFT run; returns (model, base, tuned, state,
    engine).  No refresh between init and the checkpoint, so the stored
    index sets cover every trained entry (the extraction exactness
    contract)."""
    model = build_model(CFG)
    method = T.MethodConfig(
        kind="lift", lift=LiftConfig(rank=8, density=0.05, method="exact",
                                     min_dim=16))
    base = model.init(jax.random.PRNGKey(seed))
    engine = T.selection_engine(model, method)
    params, state = T.init_train_state(model, base, method,
                                       jax.random.PRNGKey(seed + 1),
                                       engine=engine)
    step_fn = jax.jit(T.make_train_step(model, method, sa.AdamConfig(lr=lr),
                                        T.constant_lr(lr)))
    loader = ShardedLoader(generate("arith", 128, 32, seed=seed),
                           batch_size=8, seed=seed)
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        params, state, _ = step_fn(params, state, b)
    return model, base, params, state, engine


def _save_ckpt(tmp_path, step, params, state, engine):
    ck = CheckpointManager(str(tmp_path / "ckpt"))
    ck.save(step, {"params": params, "state": state},
            meta={"selection": engine.plan_meta()})
    return ck


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------------------ round-trip
@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_delta_roundtrip_bitwise(tmp_path, backend):
    """extract -> save -> load -> merge == the fine-tuned checkpoint,
    bit for bit, on both merge backends."""
    model, base, tuned, state, engine = _train_lift()
    ck = _save_ckpt(tmp_path, 5, tuned, state, engine)
    delta = extract(ck, 5, base)
    assert delta.manifest["mode"] == "replace"
    assert delta.nbytes() < delta.dense_nbytes() * 0.12  # ~2x density
    delta.save(str(tmp_path / "delta"))
    loaded = DeltaArtifact.load(str(tmp_path / "delta"))
    merged = merge_delta(base, loaded, backend=backend,
                         plan_meta=engine.plan_meta())
    assert _trees_equal(merged, tuned)


def test_delta_add_mode_close(tmp_path):
    """mode="add" ships differences; merging accumulates in fp32 —
    allclose, not bitwise (replace is the bitwise mode)."""
    model, base, tuned, state, engine = _train_lift()
    ck = _save_ckpt(tmp_path, 5, tuned, state, engine)
    delta = extract(ck, 5, base, mode="add")
    merged = merge_delta(base, delta, backend="kernel")
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(tuned)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# --------------------------------------------------------------- refusal
def test_delta_refuses_wrong_base(tmp_path):
    model, base, tuned, state, engine = _train_lift()
    ck = _save_ckpt(tmp_path, 5, tuned, state, engine)
    delta = extract(ck, 5, base)
    wrong = jax.tree.map(lambda x: x + 1e-3, base)
    with pytest.raises(DeltaMismatchError) as ei:
        merge_delta(wrong, delta)
    assert "base" in str(ei.value)
    # the artifact hash pins the EXACT bytes: an equal copy passes
    merge_delta(jax.tree.map(jnp.array, base), delta)


def test_delta_refuses_mismatched_plan(tmp_path):
    model, base, tuned, state, engine = _train_lift()
    ck = _save_ckpt(tmp_path, 5, tuned, state, engine)
    delta = extract(ck, 5, base)
    # consumer with a different density -> different k per tensor
    other = T.selection_engine(
        model, T.MethodConfig(kind="lift",
                              lift=LiftConfig(rank=8, density=0.10,
                                              method="exact", min_dim=16)))
    with pytest.raises(DeltaMismatchError) as ei:
        delta.validate_plan(other.plan_meta())
    assert "geometry" in str(ei.value) or "tensors" in str(ei.value)
    # and a different quota policy
    meta = dict(engine.plan_meta(), quota="local", quota_shards=4)
    with pytest.raises(DeltaMismatchError) as ei:
        delta.validate_plan(meta)
    assert "quota" in str(ei.value)


def test_delta_refuses_non_lift_checkpoint(tmp_path):
    ck = CheckpointManager(str(tmp_path / "ckpt"))
    ck.save(1, {"params": {"w": np.zeros((4, 4), np.float32)}}, meta={})
    with pytest.raises(DeltaMismatchError):
        extract(ck, 1, {"w": np.zeros((4, 4), np.float32)})


def test_format_version_gate(tmp_path):
    model, base, tuned, state, engine = _train_lift(steps=1)
    ck = _save_ckpt(tmp_path, 1, tuned, state, engine)
    delta = extract(ck, 1, base)
    delta.manifest["format_version"] = 999
    delta.save(str(tmp_path / "delta"))
    with pytest.raises(DeltaMismatchError) as ei:
        DeltaArtifact.load(str(tmp_path / "delta"))
    assert "format_version" in str(ei.value)


def test_format_v1_artifacts_still_load(tmp_path):
    """The v2 bump (optional fp16 values) must not orphan v1 artifacts:
    a manifest without value_dtype fields loads and merges unchanged."""
    model, base, tuned, state, engine = _train_lift(steps=1)
    ck = _save_ckpt(tmp_path, 1, tuned, state, engine)
    delta = extract(ck, 1, base)
    delta.manifest["format_version"] = 1          # as a v1 writer made it
    delta.save(str(tmp_path / "delta"))
    loaded = DeltaArtifact.load(str(tmp_path / "delta"))
    assert _trees_equal(merge_delta(base, loaded, backend="kernel"),
                        tuned)


# ------------------------------------------------------------ fp16 values
def test_fp16_values_roundtrip_and_upcast_on_merge(tmp_path):
    """format v2 satellite: extract(..., value_dtype="float16") halves
    the value payload; merging upcasts so merged == fp32(fp16(tuned)) at
    the shipped indices — quantized exactly once, at extraction."""
    model, base, tuned, state, engine = _train_lift(steps=3)
    ck = _save_ckpt(tmp_path, 3, tuned, state, engine)
    full = extract(ck, 3, base)
    half = extract(ck, 3, base, value_dtype="float16")
    for path, t in half.tensors.items():
        assert t["val"].dtype == np.float16
        assert half.manifest["tensors"][path]["value_dtype"] == "float16"
    assert half.nbytes() < full.nbytes()
    half.save(str(tmp_path / "delta16"))
    loaded = DeltaArtifact.load(str(tmp_path / "delta16"))
    from repro.deltas.format import DELTA_FORMAT_VERSION
    assert loaded.manifest["format_version"] == DELTA_FORMAT_VERSION
    from repro.core.lift import get_by_path
    for backend in ("kernel", "ref"):
        merged = merge_delta(base, loaded, backend=backend)
        for path, t in loaded.tensors.items():
            ns = t["idx"].shape[0]
            got = np.asarray(get_by_path(merged, path)).reshape(ns, -1)
            np.testing.assert_array_equal(
                np.take_along_axis(got, t["idx"], axis=-1),
                t["val"].astype(np.float32),
                err_msg=f"{backend}:{path}")
    # refusal semantics unchanged: wrong base still refuses
    other = jax.tree.map(lambda x: x + 1e-3, base)
    with pytest.raises(DeltaMismatchError):
        merge_delta(other, loaded, backend="kernel")


def test_v2_artifacts_still_load(tmp_path):
    """The v3 bump (int8 values + value_scale) must not orphan v2
    artifacts: an fp16-value manifest stamped format_version=2 loads and
    merges to the same tree as the v3-stamped artifact."""
    model, base, tuned, state, engine = _train_lift(steps=1)
    ck = _save_ckpt(tmp_path, 1, tuned, state, engine)
    half = extract(ck, 1, base, value_dtype="float16")
    half.manifest["format_version"] = 2           # as a v2 writer made it
    half.save(str(tmp_path / "delta2"))
    loaded = DeltaArtifact.load(str(tmp_path / "delta2"))
    assert loaded.manifest["format_version"] == 2
    assert _trees_equal(merge_delta(base, loaded, backend="kernel"),
                        merge_delta(base, half, validate=True))


# ------------------------------------------------------------ int8 values
def test_int8_values_dequantize_on_merge(tmp_path):
    """format v3 satellite: extract(..., value_dtype="int8") shrinks the
    value payload 4x with one per-tensor absmax/127 `value_scale`; every
    consumer decodes through the ONE shared `decode_values`, so merging
    (ref and kernel) plants fp32(int8(w) * scale) at the shipped
    indices."""
    from repro.core.lift import get_by_path
    from repro.deltas.format import decode_values
    model, base, tuned, state, engine = _train_lift(steps=3)
    ck = _save_ckpt(tmp_path, 3, tuned, state, engine)
    full = extract(ck, 3, base)
    q = extract(ck, 3, base, value_dtype="int8")
    for path, t in q.tensors.items():
        assert t["val"].dtype == np.int8
        m = q.manifest["tensors"][path]
        assert m["value_dtype"] == "int8" and m["value_scale"] > 0
    # int32 idx + int8 val vs int32 idx + fp32 val: ~5/8 of the payload
    assert q.nbytes() < 0.7 * full.nbytes()
    q.save(str(tmp_path / "delta8"))
    loaded = DeltaArtifact.load(str(tmp_path / "delta8"))
    assert loaded.manifest["format_version"] == 3
    for backend in ("kernel", "ref"):
        merged = merge_delta(base, loaded, backend=backend)
        for path, t in loaded.tensors.items():
            m = loaded.manifest["tensors"][path]
            ns = t["idx"].shape[0]
            got = np.asarray(get_by_path(merged, path)).reshape(ns, -1)
            np.testing.assert_array_equal(
                np.take_along_axis(got, t["idx"], axis=-1),
                np.asarray(decode_values(t["val"], m)),
                err_msg=f"{backend}:{path}")


def test_int8_pool_residency_equals_merge_on_load(tmp_path):
    """Pool packing and merge-on-load share `decode_values`: the
    device-resident entries of an int8 artifact are exactly the values
    its merge would plant — composing them in-matmul reproduces
    merge-on-load serving bit for bit (DESIGN.md §5)."""
    from repro.deltas.pool_layout import PoolLayout, SENTINEL_IDX
    model, base, tuned, state, engine = _train_lift(steps=3)
    ck = _save_ckpt(tmp_path, 3, tuned, state, engine)
    q = extract(ck, 3, base, value_dtype="int8")
    lay = PoolLayout(q.manifest["tensors"], entries_per_page=512)
    idx_pages, val_pages = lay.pack(base, q)
    from repro.deltas.format import decode_values
    for path, (off, ns, k) in lay.slices().items():
        m = q.manifest["tensors"][path]
        got = val_pages.reshape(-1)[off:off + ns * k].reshape(ns, k)
        np.testing.assert_array_equal(
            got, np.asarray(decode_values(q.tensors[path]["val"], m),
                            np.float32), err_msg=path)
        gi = idx_pages.reshape(-1)[off:off + ns * k].reshape(ns, k)
        assert np.all(gi < SENTINEL_IDX)
        np.testing.assert_array_equal(gi, q.tensors[path]["idx"],
                                      err_msg=path)


# ------------------------------------------------------------------ diff
def test_diff_roundtrip(tmp_path):
    model, base, tuned, state, engine = _train_lift(steps=3)
    ck = _save_ckpt(tmp_path, 3, tuned, state, engine)
    a = extract(ck, 3, base)
    # three more steps -> second artifact against the SAME base
    method = T.MethodConfig(
        kind="lift", lift=LiftConfig(rank=8, density=0.05, method="exact",
                                     min_dim=16))
    step_fn = jax.jit(T.make_train_step(model, method,
                                        sa.AdamConfig(lr=1e-2),
                                        T.constant_lr(1e-2)))
    loader = ShardedLoader(generate("arith", 128, 32, seed=7),
                           batch_size=8, seed=7)
    for _ in range(3):
        bt = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
        tuned, state, _ = step_fn(tuned, state, bt)
    ck.save(6, {"params": tuned, "state": state},
            meta={"selection": engine.plan_meta()})
    b = extract(ck, 6, base)

    patch = diff(a, b)
    assert patch["stats"]["index_jaccard"] == 1.0  # fixed mask
    rec = apply_diff(a, patch)
    assert rec.manifest["step"] == 6
    for p in b.tensors:
        assert np.array_equal(rec.tensors[p]["idx"], b.tensors[p]["idx"])
        assert np.array_equal(rec.tensors[p]["val"], b.tensors[p]["val"])
    # diffing across different bases refuses
    a2 = DeltaArtifact(manifest=dict(a.manifest, base_hash="deadbeef"),
                       tensors=a.tensors)
    with pytest.raises(DeltaMismatchError):
        diff(a2, b)


# -------------------------------------------------------- partial reads
def test_restore_leaves_partial(tmp_path):
    ck = CheckpointManager(str(tmp_path / "ckpt"))
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nest": {"b": np.ones((4,), np.int32)}}
    ck.save(1, tree)
    out = ck.restore_leaves(1, ["nest/b"])
    assert set(out) == {"nest/b"}
    assert np.array_equal(out["nest/b"], tree["nest"]["b"])
    with pytest.raises(KeyError):
        ck.restore_leaves(1, ["nope"])


# --------------------------------------------- scatter-merge kernel unit
@pytest.mark.parametrize("mode", ["replace", "add"])
@pytest.mark.parametrize("geom", [(3, 1000, 50), (1, 257, 17),
                                  (2, 4096, 200)])
def test_scatter_merge_kernel_matches_ref(mode, geom):
    ns, N, k = geom
    rng = np.random.default_rng(hash(geom) % 1000)
    base = jnp.asarray(rng.normal(size=(ns, N)).astype(np.float32))
    idx = jnp.asarray(np.sort(np.stack(
        [rng.choice(N, k, replace=False) for _ in range(ns)]), -1)
        .astype(np.int32))
    val = jnp.asarray(rng.normal(size=(ns, k)).astype(np.float32))
    want = ref.sparse_scatter_merge(base, idx, val, mode=mode)
    got = ops.sparse_scatter_merge(base, idx, val, mode=mode, bn=256)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    # capacity=1 forces the exact fallback for almost every entry
    got2 = ops.sparse_scatter_merge(base, idx, val, mode=mode, bn=256,
                                    capacity=1)
    assert np.array_equal(np.asarray(got2), np.asarray(want))


def test_scatter_merge_sentinels_write_nothing():
    rng = np.random.default_rng(3)
    base = jnp.asarray(rng.normal(size=(2, 300)).astype(np.float32))
    idx = np.sort(np.stack([rng.choice(300, 20, replace=False)
                            for _ in range(2)]), -1).astype(np.int32)
    idx[:, -5:] = 2 ** 31 - 1                      # sentinel tail
    val = jnp.asarray(rng.normal(size=(2, 20)).astype(np.float32))
    got = ops.sparse_scatter_merge(base, jnp.asarray(idx), val, bn=128)
    want = ref.sparse_scatter_merge(base, jnp.asarray(idx), val)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    untouched = np.ones((2, 300), bool)
    for s in range(2):
        untouched[s, idx[s][idx[s] < 300]] = False
    assert np.array_equal(np.asarray(got)[untouched],
                          np.asarray(base)[untouched])


def test_scatter_merge_bf16_replace_bitwise():
    rng = np.random.default_rng(5)
    base = jnp.asarray(rng.normal(size=(2, 512)), jnp.bfloat16)
    idx = jnp.asarray(np.sort(np.stack(
        [rng.choice(512, 30, replace=False) for _ in range(2)]), -1)
        .astype(np.int32))
    val = jnp.asarray(rng.normal(size=(2, 30)), jnp.bfloat16)
    got = ops.sparse_scatter_merge(base, idx, val, bn=128)
    want = ref.sparse_scatter_merge(base, idx, val)
    assert got.dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want, np.float32))


# ---------------------------------------------- sharded merge (1/2/8 dev)
SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.kernels import ops, ref
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import sharding_ctx
from repro.deltas.merge import DeltaMerger

rng = np.random.default_rng(1)
ns, rows, cols, k = 3, 64, 96, 128
base = jnp.asarray(rng.normal(size=(ns, rows, cols)).astype(np.float32))
idx = jnp.asarray(np.sort(np.stack(
    [rng.choice(rows * cols, k, replace=False) for _ in range(ns)]), -1)
    .astype(np.int32))
val = jnp.asarray(rng.normal(size=(ns, k)).astype(np.float32))
want = ref.sparse_scatter_merge(base.reshape(ns, -1), idx, val)
want = np.asarray(want.reshape(ns, rows, cols))

for nsh in (1, 2, 8):
    mesh = make_host_mesh(1, nsh)
    body = partial(ops.sparse_scatter_merge_sharded, axis_name="model",
                   n_shards=nsh, cols_global=cols, bn=512)
    out = shard_map(lambda b, i, v: body(b, i, v), mesh=mesh,
                    in_specs=(P(None, None, "model"), P(), P()),
                    out_specs=P(None, None, "model"),
                    check_rep=False)(base, idx, val)
    assert np.array_equal(np.asarray(out), want), nsh
print("KERNEL-SHARDED-OK")

# DeltaMerger picks the shard-local path under a mesh and stays bitwise
meta = {"t": {"shape": [ns, rows, cols], "stack": [ns],
              "rows": rows, "cols": cols, "k": k, "dtype": "float32"}}
tensors = {"t": {"idx": np.asarray(idx), "val": np.asarray(val)}}
from repro.deltas.format import DeltaArtifact, make_manifest
art = DeltaArtifact(
    manifest=make_manifest(mode="replace", base_hash="x", selection=None,
                           tensors_meta=meta, step=0),
    tensors=tensors)
params = {"t": base}
for nsh in (2, 8):
    mesh = make_host_mesh(1, nsh)
    with sharding_ctx(mesh):
        merger = DeltaMerger(meta, backend="kernel")
    assert merger.group_exec[(rows, cols, k)] == "sharded", merger.group_exec
    merged = merger.merge(params, art)
    assert np.array_equal(np.asarray(merged["t"]), want), nsh
print("MERGER-SHARDED-OK")
"""


def test_sharded_merge_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "KERNEL-SHARDED-OK" in out.stdout
    assert "MERGER-SHARDED-OK" in out.stdout
